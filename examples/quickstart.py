"""Quickstart: the paper in ~50 lines.

Batch of n=2 matrix products over Z_{2^32} (machine words!), computed by 8
coded workers, any 4 of which suffice — here 4 workers "die" and the result
is still exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import BatchEPRMFE, make_ring

# the data ring: Z_{2^32} — native uint32 wraparound arithmetic
Z32 = make_ring(2, 32, ())

# Batch-EP_RMFE: n=2 products packed by a (2,3)-RMFE into GR(2^32, 3),
# EP code with u=v=2, w=1 over 8 workers -> recovery threshold R = 4
scheme = BatchEPRMFE(Z32, n=2, N=8, u=2, v=2, w=1)
print(f"extension ring: {scheme.ext}, recovery threshold R={scheme.R} of N=8")

rng = np.random.default_rng(0)
As = Z32.random(rng, (2, 64, 64))   # two 64x64 uint32 matrices
Bs = Z32.random(rng, (2, 64, 64))

# master: pack + encode -> per-worker tasks
FA, GB = scheme.encode(As, Bs)

# workers: local block products over the extension ring (the Pallas kernel
# on TPU; jnp reference here)
H = scheme.worker_compute(FA, GB)

# stragglers: workers 1, 2, 5, 6 never respond
alive = jnp.asarray([0, 3, 4, 7], dtype=jnp.int32)
Cs = scheme.decode(jnp.take(H, alive, axis=0), alive)

# exactness check against the direct products
for i in range(2):
    expect = Z32.matmul(As[i], Bs[i])
    assert np.array_equal(np.asarray(Cs[i]), np.asarray(expect))
print("recovered both products exactly from 4/8 workers ✓")

# compare with GCSA's threshold at the same batch (paper Table 1)
from repro.core import gcsa_cost_model

g = gcsa_cost_model(64, 64, 64, 2, 2, 1, n=2, kappa=2, N=8, m_eff=3)
print(f"GCSA would need R={g.R} of 8 workers; Batch-EP_RMFE needs {scheme.R}")
