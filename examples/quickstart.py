"""Quickstart: the paper in ~50 lines, through the unified CDMM API.

A batch of n=2 matrix products over Z_{2^32} (machine words!) is described
as a ProblemSpec; the cost-model planner ranks every registered scheme
(Batch-EP_RMFE, GCSA, ...) x partition against the paper's Table-1 models
and `coded_matmul` executes the winner — here 4 of 8 workers "die" and the
result is still bit-exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.cdmm import ProblemSpec, coded_matmul, plan
from repro.core import make_ring

# the data ring: Z_{2^32} — native uint32 wraparound arithmetic
Z32 = make_ring(2, 32, ())

# two 64x64 products, 8 workers, must tolerate 4 stragglers
spec = ProblemSpec(t=64, r=64, s=64, n=2, ring=Z32, N=8, straggler_budget=4)

# rank every registered scheme x partition by predicted master upload
# (under "download" every w=1 partition ties and the trivial R=1 replication
# point wins; upload rewards actually splitting the work across workers)
p = plan(spec, objective="upload")
print(p.summary(limit=4))

best = p.best
print(
    f"\nplanner picked {best.scheme} (u,v,w)=({best.u},{best.v},{best.w}): "
    f"recovery threshold R={best.costs.R} of N={spec.N}"
)
# Table 1 headline under the "download" objective: GCSA pays ~n x more
pd = plan(spec, objective="download")
gcsa = pd.by_scheme("gcsa")
print(
    f"downloads (Table 1): gcsa needs "
    f"{gcsa.costs.download / pd.best.costs.download:.1f}x the best RMFE point"
)

rng = np.random.default_rng(0)
As = Z32.random(rng, (2, 64, 64))   # two 64x64 uint32 matrices
Bs = Z32.random(rng, (2, 64, 64))

# stragglers: workers 1, 2, 5, 6 never respond
mask = jnp.asarray([True, False, False, True, True, False, False, True])

# encode -> 8 simulated workers -> any-R decode, in one call
Cs = coded_matmul(As, Bs, p, mask=mask)

# exactness check against the direct products
for i in range(2):
    expect = Z32.matmul(As[i], Bs[i])
    assert np.array_equal(np.asarray(Cs[i]), np.asarray(expect))
print("recovered both products exactly from 4/8 workers ✓")
