"""Coded quantized serving: the paper's technique as a first-class inference
feature.

An int8 FFN matmul is lifted to Z_{2^32} and executed as EP_RMFE-coded tasks
across 8 workers; we kill up to 4 workers per request and verify the
dequantized output is BIT-IDENTICAL to the failure-free run (integer-exact
codes — no approximation under failures, unlike replication/averaging).

    PYTHONPATH=src python examples/coded_inference.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.cdmm import CodedQuantMatmul, quantize_int8

rng = np.random.default_rng(0)
cm = CodedQuantMatmul(N=8, axis_name=None)  # GR(2^32, 3), R=4
print(f"coded int8 matmul: N=8 workers, R={cm.R}, ring {cm.scheme.ring}")

# a "transformer FFN" shaped problem: tokens x d_model @ d_model x d_ff
x = rng.standard_normal((32, 256)).astype(np.float32)
w = rng.standard_normal((256, 512)).astype(np.float32)

y_ref = np.asarray(cm(jnp.asarray(x), jnp.asarray(w), mask=None))

for fail in [1, 2, 3, 4]:
    mask = np.ones(8, dtype=bool)
    dead = rng.choice(8, size=fail, replace=False)
    mask[dead] = False
    y = np.asarray(cm(jnp.asarray(x), jnp.asarray(w), mask=jnp.asarray(mask)))
    ident = np.array_equal(y, y_ref)
    print(f"{fail} dead workers {sorted(map(int, dead))}: bit-identical={ident}")
    assert ident

# quantization (not coding) is the only error source
err = np.abs(y_ref - x @ w).max() / np.abs(x @ w).max()
print(f"int8 quantization rel-err vs fp32: {err:.4f} (coding adds 0.0)")
