"""Distributed coded-matmul service on a real device mesh (SPMD).

Spawns 8 host devices, runs the paper's master/worker protocol under
shard_map with random straggler injection per request, and validates every
response bit-exactly.  This is the standalone data-plane service described
in DESIGN.md §4 (the paper's own deployment model).

    PYTHONPATH=src python examples/coded_matmul_service.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.cdmm import DistributedBatchRMFE, cdmm_shard_map
from repro.core import BatchEPRMFE, make_ring, select_workers, simulate_stragglers

mesh = Mesh(np.array(jax.devices()[:8]).reshape(8), ("workers",))
Z32 = make_ring(2, 32, ())
scheme = BatchEPRMFE(Z32, n=2, N=8, u=2, v=2, w=1)
service = DistributedBatchRMFE(scheme, "workers")
serve = jax.jit(cdmm_shard_map(service, mesh, "workers"))

rng = np.random.default_rng(0)
key = jax.random.PRNGKey(0)
print(f"service up: N=8 workers, R={scheme.R}, ring {scheme.ext}")
for req in range(5):
    As = Z32.random(rng, (2, 64, 64))
    Bs = Z32.random(rng, (2, 64, 64))
    key, k = jax.random.split(key)
    mask, _ = simulate_stragglers(k, 8, fail_prob=0.35, min_live=scheme.R)
    t0 = time.perf_counter()
    Cs = serve(As, Bs, mask)
    jax.block_until_ready(Cs)
    dt = (time.perf_counter() - t0) * 1e3
    ok = all(
        np.array_equal(np.asarray(Cs[i]), np.asarray(Z32.matmul(As[i], Bs[i])))
        for i in range(2)
    )
    dead = [i for i, v in enumerate(np.asarray(mask)) if not v]
    print(f"req {req}: dead workers {dead or 'none'} -> exact={ok} ({dt:.1f} ms)")
