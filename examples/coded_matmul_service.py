"""Distributed coded-matmul service on a REAL multi-process worker pool.

Spawns worker OS processes (``repro.dist.LocalPool``), plans a scheme for
the request spec, and serves concurrent requests through the pool's
admission-controlled scheduler — the paper's master/worker protocol over
actual sockets and processes, with a real SIGKILL mid-stream instead of a
simulated straggler mask.  Every response is validated bit-exactly against
the plain ``A @ B`` oracle.

The in-process ShardMapBackend variant (the previous incarnation of this
example: SPMD over simulated host devices with random straggler masks) is
kept below as a comparison path — same planned scheme, same requests, two
execution substrates.

    PYTHONPATH=src python examples/coded_matmul_service.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm import ProblemSpec, ShardMapBackend, coded_matmul, plan
from repro.core import make_ring, simulate_stragglers
from repro.dist import LocalPool, PoolConfig, PoolScheduler

Z32 = make_ring(2, 32, ())
spec = ProblemSpec(t=64, r=64, s=64, n=2, ring=Z32, N=8, straggler_budget=4)
p = plan(spec, objective="latency")
scheme = p.instantiate()
rng = np.random.default_rng(0)


def requests(n):
    for _ in range(n):
        As = Z32.random(rng, (2, 64, 64))
        Bs = Z32.random(rng, (2, 64, 64))
        yield As, Bs


def check(Cs, As, Bs):
    return all(
        np.array_equal(np.asarray(Cs[i]), np.asarray(Z32.matmul(As[i], Bs[i])))
        for i in range(2)
    )


# -- pool runtime: real worker processes, scheduler, real failure ----------
print(
    f"pool service up: {p.best.scheme} "
    f"(u,v,w)=({p.best.u},{p.best.v},{p.best.w}), N={spec.N} shares, "
    f"R={scheme.R}, ring {scheme.ring}"
)
with LocalPool(config=PoolConfig(workers=6, transport="pack+zlib")) as pool:
    with PoolScheduler(pool.master, max_queue=16, max_inflight=3) as sched:
        # warm round so every worker has jitted the codeword-ring matmul
        As, Bs = next(requests(1))
        sched.submit(As, Bs, scheme=scheme).result(120)

        batch = list(requests(5))
        t0 = time.perf_counter()
        futs = [sched.submit(As, Bs, scheme=scheme) for As, Bs in batch]
        # real failure injection: SIGKILL one worker while requests fly
        killed = pool.kill(1)
        for req, (fut, (As, Bs)) in enumerate(zip(futs, batch)):
            Cs = fut.result(timeout=120)
            print(f"pool req {req}: exact={check(Cs, As, Bs)}")
        dt = (time.perf_counter() - t0) * 1e3
        print(
            f"pool: 5 concurrent requests in {dt:.0f} ms total, "
            f"killed pid {killed} mid-stream, "
            f"{pool.alive_count()}/6 workers alive, "
            f"scheduler stats: {sched.stats.completed} completed / "
            f"{sched.stats.rejected} shed"
        )

# -- comparison path: in-process SPMD emulation (simulated stragglers) -----
backend = ShardMapBackend(axis="workers")
serve = jax.jit(lambda As, Bs, mask: coded_matmul(
    As, Bs, scheme, backend=backend, mask=mask
))
key = jax.random.PRNGKey(0)
for req, (As, Bs) in enumerate(requests(5)):
    key, k = jax.random.split(key)
    mask, _ = simulate_stragglers(k, 8, fail_prob=0.35, min_live=scheme.R)
    t0 = time.perf_counter()
    Cs = serve(As, Bs, mask)
    jax.block_until_ready(Cs)
    dt = (time.perf_counter() - t0) * 1e3
    dead = [i for i, v in enumerate(np.asarray(mask)) if not v]
    print(
        f"shard_map req {req}: dead workers {dead or 'none'} -> "
        f"exact={check(Cs, As, Bs)} ({dt:.1f} ms)"
    )
