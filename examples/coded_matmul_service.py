"""Distributed coded-matmul service on a real device mesh (SPMD).

Spawns 8 host devices, plans a scheme for the request spec, and serves it
with the ShardMapBackend: the paper's master/worker protocol under
shard_map with random straggler injection per request, every response
validated bit-exactly.  This is the standalone data-plane service described
in DESIGN.md §4 (the paper's own deployment model).

    PYTHONPATH=src python examples/coded_matmul_service.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm import ProblemSpec, ShardMapBackend, coded_matmul, plan
from repro.core import make_ring, simulate_stragglers

Z32 = make_ring(2, 32, ())
spec = ProblemSpec(t=64, r=64, s=64, n=2, ring=Z32, N=8, straggler_budget=4)
p = plan(spec, objective="latency")
scheme = p.instantiate()
backend = ShardMapBackend(axis="workers")
serve = jax.jit(lambda As, Bs, mask: coded_matmul(
    As, Bs, scheme, backend=backend, mask=mask
))

rng = np.random.default_rng(0)
key = jax.random.PRNGKey(0)
print(
    f"service up: {p.best.scheme} (u,v,w)=({p.best.u},{p.best.v},{p.best.w}), "
    f"N={spec.N} workers, R={scheme.R}, ring {scheme.ring}"
)
for req in range(5):
    As = Z32.random(rng, (2, 64, 64))
    Bs = Z32.random(rng, (2, 64, 64))
    key, k = jax.random.split(key)
    mask, _ = simulate_stragglers(k, 8, fail_prob=0.35, min_live=scheme.R)
    t0 = time.perf_counter()
    Cs = serve(As, Bs, mask)
    jax.block_until_ready(Cs)
    dt = (time.perf_counter() - t0) * 1e3
    ok = all(
        np.array_equal(np.asarray(Cs[i]), np.asarray(Z32.matmul(As[i], Bs[i])))
        for i in range(2)
    )
    dead = [i for i, v in enumerate(np.asarray(mask)) if not v]
    print(f"req {req}: dead workers {dead or 'none'} -> exact={ok} ({dt:.1f} ms)")
