"""End-to-end training driver: ~100M-param LM, a few hundred steps on CPU,
with periodic async checkpointing and kill-resume support.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    # ctrl-C anywhere, then resume bit-identically:
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs import ARCHS
from repro.launch.train import train

# ~100M params: 50k x 640 embed (32M) + 10 layers x ~6.3M
CFG_100M = dataclasses.replace(
    ARCHS["deepseek-67b"],  # llama-style family as the base
    name="llama-100m",
    num_layers=10,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=50304,
    remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # register the custom config so the generic driver can find it
    ARCHS[CFG_100M.name] = CFG_100M
    shape = ShapeConfig("train_100m", seq_len=128, global_batch=4, kind="train")
    out = train(
        CFG_100M.name,
        smoke=False,
        shape=shape,
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        resume=args.resume,
        log_every=10,
    )
    losses = out["losses"]
    if losses:
        print(
            f"loss: first={losses[0]:.3f} min={min(losses):.3f} last={losses[-1]:.3f}"
        )


if __name__ == "__main__":
    main()
