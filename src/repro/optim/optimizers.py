"""Optimizers: AdamW, Adafactor (factored second moments, for trillion-param
configs) and int8-quantized Adam states (8-bit-optimizer-style, halves state
HBM) — all pure pytree transforms, no external deps.

State memory per parameter (bytes):
    adamw fp32:   8      adamw bf16: 4      adamw int8: 2 (+ per-row scales)
    adafactor:    ~0     (row+col factors for 2D+, full v for 1D)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"  # float32 | bfloat16 | int8


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(np.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_scale(grads, max_norm: float):
    """Global-norm clip factor WITHOUT materializing an f32 copy of every
    gradient (the copy costs +4 bytes/param peak on trillion-param runs)."""
    n = global_norm(grads)
    return jnp.minimum(1.0, max_norm / (n + 1e-9)), n


def clip_by_global_norm(grads, max_norm: float):
    scale, n = clip_scale(grads, max_norm)
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), n


# -- int8 state codec ---------------------------------------------------------


def _q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (dim0) symmetric int8 quantization of an fp32 tensor."""
    if x.ndim == 0:
        x = x[None]
        amax = jnp.max(jnp.abs(x))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        return jnp.round(x / scale).astype(jnp.int8)[0], scale
    red = tuple(range(1, x.ndim))
    amax = jnp.max(jnp.abs(x), axis=red, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    return jnp.round(x / scale).astype(jnp.int8), scale


def _dq8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# -- AdamW ---------------------------------------------------------------------


def adamw_init(cfg: OptConfig, params):
    def one(p):
        # NB: distinct buffers for m and v — sharing one zeros array breaks
        # donation (same buffer donated twice in the jitted train step)
        def z(dt):
            return jnp.zeros(p.shape, dt)

        if cfg.state_dtype == "bfloat16":
            return {"m": z(jnp.bfloat16), "v": z(jnp.bfloat16)}
        if cfg.state_dtype == "int8":
            qm, sm = _q8(z(jnp.float32))
            qv, sv = _q8(z(jnp.float32))
            return {"m": qm, "ms": sm, "v": qv, "vs": sv}
        return {"m": z(jnp.float32), "v": z(jnp.float32)}

    return {"mu": jax.tree.map(one, params), "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    cscale, gnorm = clip_scale(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def one(g, p, s):
        if cfg.state_dtype == "int8":
            m = _dq8(s["m"], s["ms"])
            v = _dq8(s["v"], s["vs"])
        else:
            m = s["m"].astype(jnp.float32)
            v = s["v"].astype(jnp.float32)
        g = g.astype(jnp.float32) * cscale  # fused per-tensor clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if cfg.state_dtype == "bfloat16":
            ns = {"m": m.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
        elif cfg.state_dtype == "int8":
            qm, sm = _q8(m)
            qv, sv = _q8(v)
            ns = {"m": qm, "ms": sm, "v": qv, "vs": sv}
        else:
            ns = {"m": m, "v": v}
        return new_p, ns

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["mu"])
    out = [one(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}, {"lr": lr, "gnorm": gnorm}


# -- Adafactor -------------------------------------------------------------------


def adafactor_init(cfg: OptConfig, params):
    def one(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"mu": jax.tree.map(one, params, is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    cscale, gnorm = clip_scale(grads, cfg.grad_clip)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def one(g, p, s):
        g = g.astype(jnp.float32) * cscale  # fused per-tensor clip
        g2 = jnp.square(g) + 1e-30
        if p.ndim >= 2:
            vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            prec = (
                vr[..., None] * vc[..., None, :] / jnp.maximum(denom[..., None], 1e-30)
            )
            upd = g / jnp.sqrt(prec + 1e-30)
            ns = {"vr": vr, "vc": vc}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            upd = g / jnp.sqrt(v + 1e-30)
            ns = {"v": v}
        # update clipping by RMS (Adafactor d=1.0)
        rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
        upd = upd / jnp.maximum(1.0, rms)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, ns

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["mu"])
    out = [one(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}, {"lr": lr, "gnorm": gnorm}


# -- dry-run state declaration (ParamSpec mirror of opt_init) -------------------


def opt_state_specs(cfg: OptConfig, param_specs):
    """ParamSpec tree for the optimizer state (no allocation — dry-run)."""
    sdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}.get(
        cfg.state_dtype, jnp.float32
    )

    def one(ps: ParamSpec):
        if cfg.name == "adafactor":
            if len(ps.shape) >= 2:
                return {
                    "vr": ParamSpec(ps.shape[:-1], ps.logical[:-1], jnp.float32, "zeros"),
                    "vc": ParamSpec(
                        ps.shape[:-2] + ps.shape[-1:],
                        ps.logical[:-2] + ps.logical[-1:],
                        jnp.float32, "zeros",
                    ),
                }
            return {"v": ParamSpec(ps.shape, ps.logical, jnp.float32, "zeros")}
        return {
            "m": ParamSpec(ps.shape, ps.logical, sdt, "zeros"),
            "v": ParamSpec(ps.shape, ps.logical, sdt, "zeros"),
        }

    def walk(tree):
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return one(tree)

    return {"mu": walk(param_specs), "step": ParamSpec((), (), jnp.int32, "zeros")}


# -- facade ------------------------------------------------------------------------


def opt_init(cfg: OptConfig, params):
    return adafactor_init(cfg, params) if cfg.name == "adafactor" else adamw_init(cfg, params)


def opt_update(cfg: OptConfig, grads, state, params):
    if cfg.name == "adafactor":
        return adafactor_update(cfg, grads, state, params)
    return adamw_update(cfg, grads, state, params)
