"""Optimizers + gradient compression."""
from .optimizers import (
    OptConfig, opt_init, opt_update, opt_state_specs, schedule, global_norm, clip_by_global_norm,
    adamw_init, adamw_update, adafactor_init, adafactor_update,
)
from .compression import compress_tree, init_ef, quantize_ef, dequantize, compressed_psum
