"""Gradient compression for the slow cross-pod axis: int8 quantization with
error feedback (EF-SGD style), plus an int8 all-reduce for shard_map paths.

In the GSPMD train step the compressor is applied as quantize->dequantize
with a persistent error-feedback buffer (mathematically identical to
compressing the pod all-reduce payload when pods hold identical shards);
the shard_map pipeline variant uses ``compressed_psum`` which actually moves
int32-accumulated int8 payloads across the axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_ef(
    g: jnp.ndarray, ef: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """int8 quantize (g + ef); returns (q, scale, new_ef)."""
    x = g.astype(jnp.float32) + ef
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_ef = x - q.astype(jnp.float32) * scale
    return q, scale, new_ef


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, ef_tree):
    """Quantize-dequantize every leaf with error feedback (GSPMD path)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_tree)
    outs, new_ef = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = quantize_ef(g, e)
        outs.append(dequantize(q, s))
        new_ef.append(ne)
    return tdef.unflatten(outs), tdef.unflatten(new_ef)


def init_ef(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(g: jnp.ndarray, ef: jnp.ndarray, axis: str):
    """int8-payload mean-all-reduce across ``axis`` (inside shard_map).

    Payload: int8 values (accumulated as int32 by psum) + one fp32 scale.
    Returns (mean_g, new_ef).
    """
    q, scale, new_ef = quantize_ef(g, ef)
    n = lax.psum(1, axis)
    acc = lax.psum(q.astype(jnp.int32), axis)  # int32 accumulation: exact
    smax = lax.pmax(scale, axis)  # conservative shared scale note: per-shard
    # each shard contributed with its own scale; transmit scales too (tiny)
    scales = lax.all_gather(scale, axis)  # (n,)
    qs = lax.all_gather(q, axis)  # (n, ...) -- reference exact dequant
    mean = jnp.tensordot(scales, qs.astype(jnp.float32), axes=(0, 0)) / n
    del acc, smax
    return mean, new_ef
