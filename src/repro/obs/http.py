"""Embedded HTTP admin plane: ``/metrics``, ``/healthz``, ``/stats``,
``/trace/<request_id>``.

A stdlib-only (``http.server``) scrape endpoint the pool opts into via
``PoolConfig(obs_http_port=...)`` or ``REPRO_OBS_HTTP_PORT`` (port 0 =
ephemeral, the chosen port is on ``server.port``).  Components don't
serve HTTP themselves — they register a named *snapshot source*
(:func:`register_source`, any zero-arg callable returning a
``repro.stats`` snapshot) and optionally a *trace resolver*
(:func:`register_trace_resolver`, mapping a request-id/trace-id string
to a :class:`repro.obs.Timeline`).  The handler merges whatever is
registered at scrape time:

- ``GET /metrics`` — Prometheus text exposition
  (:func:`repro.obs.export.to_prometheus` of the merged snapshot, real
  cumulative histograms, per-worker health gauges);
- ``GET /healthz`` — liveness JSON: ``ok`` (every source answered),
  source names, ``pool_workers_live`` and per-worker health when a pool
  is registered (503 when a source failed);
- ``GET /stats`` — the merged snapshot as JSON, same content the
  ``--stats-every`` console line prints (and what
  ``python -m repro.obs.top`` polls);
- ``GET /trace/<rid>`` — one request's merged span timeline as
  canonical span JSON, or Chrome ``trace_event`` JSON with
  ``?format=chrome`` (open in about://tracing / Perfetto).

Sources/resolvers registration is process-global and independent of the
server lifecycle, so components register unconditionally (harmless when
no server ever starts) and a server started later sees them all.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional
from urllib.parse import parse_qs, urlparse

from repro.obs.export import to_chrome_trace, to_json, to_prometheus
from repro.stats import merge_snapshots

__all__ = [
    "ObsHttpServer",
    "merged_snapshot",
    "register_source",
    "register_trace_resolver",
    "server",
    "start_server",
    "stop_server",
    "unregister_source",
    "unregister_trace_resolver",
]

_lock = threading.Lock()
_sources: Dict[str, Callable[[], Dict]] = {}
_resolvers: List[Callable[[str], Optional[object]]] = []
_server: Optional["ObsHttpServer"] = None


def register_source(name: str, snapshot_fn: Callable[[], Dict]) -> str:
    """Register a named snapshot callable; returns the (deduplicated)
    name actually used — a second ``"pool"`` becomes ``"pool#2"`` so
    two masters in one process both stay scrapeable."""
    with _lock:
        use = name
        n = 1
        while use in _sources:
            n += 1
            use = f"{name}#{n}"
        _sources[use] = snapshot_fn
    return use


def unregister_source(name: str) -> None:
    with _lock:
        _sources.pop(name, None)


def register_trace_resolver(fn: Callable[[str], Optional[object]]) -> None:
    """Register a callable mapping a request-id/trace-id string to a
    Timeline (or None when it doesn't know the id)."""
    with _lock:
        if fn not in _resolvers:
            _resolvers.append(fn)


def unregister_trace_resolver(fn: Callable[[str], Optional[object]]) -> None:
    with _lock:
        if fn in _resolvers:
            _resolvers.remove(fn)


def merged_snapshot() -> Dict[str, object]:
    """Every registered source's snapshot, merged (errors recorded as
    ``obs_source_errors`` instead of failing the scrape)."""
    with _lock:
        sources = list(_sources.items())
    snaps = []
    errors = 0
    for _, fn in sources:
        try:
            snaps.append(fn())
        except Exception:
            errors += 1
    merged = merge_snapshots(*snaps) if snaps else {}
    if errors:
        merged["obs_source_errors"] = errors
    return merged


def _resolve_trace(key: str):
    with _lock:
        resolvers = list(_resolvers)
    for fn in resolvers:
        try:
            timeline = fn(key)
        except Exception:
            continue
        if timeline is not None:
            return timeline
    # fall back to the process tracer: the key may be a raw trace id
    from repro.obs.trace import tracer

    timeline = tracer().timeline(key)
    return timeline if timeline.spans else None


def _healthz() -> Dict[str, object]:
    with _lock:
        sources = list(_sources.items())
    doc: Dict[str, object] = {"ok": True, "sources": []}
    for name, fn in sources:
        try:
            snap = fn()
        except Exception as e:
            doc["ok"] = False
            doc.setdefault("errors", {})[name] = f"{type(e).__name__}: {e}"
            continue
        doc["sources"].append(name)
        live = snap.get("pool_workers_live")
        if live is not None:
            doc["pool_workers_live"] = live
        health = snap.get("pool_worker_health_by_wid")
        if isinstance(health, dict):
            doc["pool_worker_health"] = {
                k: round(float(v), 4) for k, v in health.items()
            }
    return doc


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs/1"

    def log_message(self, *args) -> None:  # silence per-request stderr
        pass

    def _respond(self, code: int, body: str, content_type: str) -> None:
        payload = body.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        try:
            url = urlparse(self.path)
            path = url.path.rstrip("/") or "/"
            if path == "/metrics":
                self._respond(
                    200, to_prometheus(merged_snapshot()),
                    "text/plain; version=0.0.4; charset=utf-8",
                )
            elif path == "/healthz":
                doc = _healthz()
                self._respond(
                    200 if doc["ok"] else 503,
                    json.dumps(doc, sort_keys=True) + "\n",
                    "application/json",
                )
            elif path == "/stats":
                self._respond(
                    200,
                    json.dumps(
                        merged_snapshot(), sort_keys=True, default=str
                    ) + "\n",
                    "application/json",
                )
            elif path.startswith("/trace/"):
                key = path[len("/trace/"):]
                timeline = _resolve_trace(key)
                if timeline is None:
                    self._respond(
                        404, f"no timeline for {key!r}\n", "text/plain"
                    )
                    return
                fmt = parse_qs(url.query).get("format", ["json"])[0]
                if fmt == "chrome":
                    self._respond(
                        200, to_chrome_trace(timeline, indent=1),
                        "application/json",
                    )
                else:
                    self._respond(
                        200, to_json(timeline, indent=1), "application/json"
                    )
            else:
                self._respond(
                    404,
                    "repro obs endpoints: /metrics /healthz /stats "
                    "/trace/<request_id>\n",
                    "text/plain",
                )
        except BrokenPipeError:  # scraper went away mid-write
            pass
        except Exception as e:  # never kill the serving thread
            try:
                self._respond(
                    500, f"{type(e).__name__}: {e}\n", "text/plain"
                )
            except OSError:
                pass


class ObsHttpServer:
    """The admin server: ``ThreadingHTTPServer`` on a daemon thread."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-http", daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=10)


def start_server(port: Optional[int] = None) -> ObsHttpServer:
    """Start (or return the already-running) process-wide admin server.

    ``port=None`` reads ``REPRO_OBS_HTTP_PORT`` (via ``repro.settings``);
    0 binds an ephemeral port.  One server per process: a second caller
    gets the existing instance regardless of the port it asked for.
    """
    global _server
    with _lock:
        if _server is not None:
            return _server
    if port is None:
        from repro import settings

        port = settings.get_int("obs_http_port")
        if port is None:
            port = 0
    srv = ObsHttpServer(port=int(port))
    with _lock:
        if _server is None:
            _server = srv
            return srv
    srv.stop()  # lost the race; serve from the winner
    return _server


def stop_server() -> None:
    global _server
    with _lock:
        srv, _server = _server, None
    if srv is not None:
        srv.stop()


def server() -> Optional[ObsHttpServer]:
    with _lock:
        return _server
