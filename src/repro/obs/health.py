"""Per-worker health scoring from master-observed timing signals.

The master already *sees* everything a health score needs: when each
share was sent, when its result landed (round-trip = comm + compute),
and when heartbeats arrive.  :class:`HealthTracker` folds those into two
EWMA signals per worker —

- ``rtt``: EWMA of share round-trip milliseconds (send -> result at the
  master, so a slow network path scores the same as a slow CPU);
- ``jitter``: EWMA of the absolute deviation of heartbeat inter-arrival
  times from their own EWMA (a worker whose heartbeats stutter is
  struggling even if it hasn't missed the death deadline yet);

— and normalizes each against the *pool median*, so "healthy" means
"like your peers", not an absolute number that would need per-hardware
tuning.  The score is

    score(wid) = min(1, median_rtt / rtt) * min(1, median_jitter / jitter)

clamped to (0, 1]; a worker with no data yet scores 1.0 (innocent until
measured).  The master surfaces scores as ``pool_worker_health{wid=...}``
gauges and consumes them twice: dispatch ordering (shares go to workers
scoring >= :data:`DISPATCH_THRESHOLD` first) and the speculative hedge
deadline — :meth:`hedge_deadline_ms` is the p95 of a retention-windowed
:class:`~repro.obs.metrics.Series` of *pool-wide* share round-trips
times the caller's hedge factor, so "outstanding suspiciously long"
is defined by recent measured behaviour, not a static timeout.

Locking: the tracker has exactly one internal lock and calls nothing
that takes another, so callers may invoke it while holding their own
locks without ordering concerns (the master does not — it reads scores
before taking its dispatch lock).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.obs.metrics import DEFAULT_RETENTION_S, Series

__all__ = ["DISPATCH_THRESHOLD", "HealthTracker"]

# workers scoring below this are dispatched to only when no healthier
# worker is live (they still serve: slow != dead, and the any-R decode
# may yet need their shares)
DISPATCH_THRESHOLD = 0.5

_EPS_MS = 1e-3  # jitter floor so a perfectly steady worker divides cleanly

# the hedge sweep polls far faster than the share window changes shape;
# re-sorting up to 4096 round-trips per poll is pure overhead, so the
# deadline quantile is cached this long
_QUANTILE_TTL_S = 0.05


class _WorkerSignals:
    __slots__ = ("rtt_ewma", "hb_last", "hb_interval_ewma", "jitter_ewma",
                 "samples")

    def __init__(self):
        self.rtt_ewma: Optional[float] = None
        self.hb_last: Optional[float] = None
        self.hb_interval_ewma: Optional[float] = None
        self.jitter_ewma: Optional[float] = None
        self.samples = 0


def _ewma(prev: Optional[float], value: float, alpha: float) -> float:
    return value if prev is None else (1 - alpha) * prev + alpha * value


def _median(vals: Sequence[float]) -> Optional[float]:
    vals = sorted(vals)
    if not vals:
        return None
    mid = len(vals) // 2
    if len(vals) % 2:
        return vals[mid]
    return 0.5 * (vals[mid - 1] + vals[mid])


class HealthTracker:
    """EWMA share-RTT + heartbeat-jitter health per worker id."""

    def __init__(
        self,
        alpha: float = 0.2,
        retention_s: float = DEFAULT_RETENTION_S,
        min_hedge_samples: int = 8,
    ):
        self.alpha = float(alpha)
        self.min_hedge_samples = int(min_hedge_samples)
        self._lock = threading.Lock()
        self._workers: Dict[int, _WorkerSignals] = {}
        # pool-wide share round-trips, retention-windowed: the hedge
        # deadline quantile reads this, so it tracks recent behaviour
        self.share_ms = Series("share_ms", retention_s=retention_s)
        # q -> (t, quantile, window_len): hedge sweeps hit this instead of
        # re-sorting the window on every event-loop poll
        self._q_cache: Dict[float, tuple] = {}

    # -- recording ---------------------------------------------------------

    def _signals(self, wid: int) -> _WorkerSignals:
        # caller holds the lock
        sig = self._workers.get(wid)
        if sig is None:
            sig = self._workers[wid] = _WorkerSignals()
        return sig

    def record_share(self, wid: int, rtt_ms: float) -> None:
        """One share answered: master-observed send->result round-trip."""
        with self._lock:
            sig = self._signals(wid)
            sig.rtt_ewma = _ewma(sig.rtt_ewma, float(rtt_ms), self.alpha)
            sig.samples += 1
        self.share_ms.add(float(rtt_ms))

    def record_heartbeat(self, wid: int, t: Optional[float] = None) -> None:
        t = time.monotonic() if t is None else t
        with self._lock:
            sig = self._signals(wid)
            if sig.hb_last is not None:
                interval = t - sig.hb_last
                if sig.hb_interval_ewma is not None:
                    dev = abs(interval - sig.hb_interval_ewma) * 1e3
                    sig.jitter_ewma = _ewma(
                        sig.jitter_ewma, dev, self.alpha
                    )
                sig.hb_interval_ewma = _ewma(
                    sig.hb_interval_ewma, interval, self.alpha
                )
            sig.hb_last = t

    def forget(self, wid: int) -> None:
        """Worker left the pool: drop its signals (a rejoin starts clean)."""
        with self._lock:
            self._workers.pop(wid, None)

    def reset_scores(self) -> None:
        """Forget per-worker EWMAs but keep the pooled share series.

        The cold-straggler seam for benchmarks: scores return to 1.0 (so
        round-robin dispatch is blind again) while the hedge deadline
        still knows what a normal round-trip costs.
        """
        with self._lock:
            self._workers.clear()

    def clear_window(self) -> None:
        """Drop the pooled share round-trip window (and its quantile
        cache).  Benchmarks call this after a compile-storm warmup so
        the hedge deadline reflects steady-state round-trips only."""
        self.share_ms.clear()
        with self._lock:
            self._q_cache.clear()

    # -- scoring -----------------------------------------------------------

    def scores(self) -> Dict[int, float]:
        """``{wid: score}`` for every worker with any recorded signal."""
        with self._lock:
            rtts = {
                w: s.rtt_ewma for w, s in self._workers.items()
                if s.rtt_ewma is not None
            }
            jitters = {
                w: s.jitter_ewma for w, s in self._workers.items()
                if s.jitter_ewma is not None
            }
            wids = list(self._workers)
        med_rtt = _median(list(rtts.values()))
        med_jit = _median(list(jitters.values()))
        out: Dict[int, float] = {}
        for wid in wids:
            score = 1.0
            rtt = rtts.get(wid)
            if rtt is not None and med_rtt is not None and rtt > 0:
                score *= min(1.0, med_rtt / rtt)
            jit = jitters.get(wid)
            if jit is not None and med_jit is not None:
                score *= min(1.0, (med_jit + _EPS_MS) / (jit + _EPS_MS))
            out[wid] = max(score, 1e-6)
        return out

    def score(self, wid: int) -> float:
        return self.scores().get(wid, 1.0)

    def ranked(self, wids: Sequence[int]) -> List[int]:
        """``wids`` reordered healthiest-first (stable for ties, so the
        all-healthy pool keeps its round-robin order)."""
        scores = self.scores()
        return sorted(wids, key=lambda w: -scores.get(w, 1.0))

    # -- hedge deadline ----------------------------------------------------

    def hedge_deadline_ms(
        self,
        factor: float,
        q: float = 0.95,
        min_ms: float = 1.0,
    ) -> Optional[float]:
        """How long a share may stay outstanding before it is hedged:
        ``p95(recent share round-trips) * factor``.

        None (never hedge) when ``factor`` <= 0 or fewer than
        ``min_hedge_samples`` round-trips are in the retention window —
        hedging on no evidence would re-ship everything during warmup.
        """
        if factor <= 0:
            return None
        now = time.monotonic()
        with self._lock:
            cached = self._q_cache.get(q)
        if cached is not None and now - cached[0] < _QUANTILE_TTL_S:
            _, p, n = cached
        else:
            n = len(self.share_ms)
            p = self.share_ms.quantile(q)
            with self._lock:
                self._q_cache[q] = (now, p, n)
        if n < self.min_hedge_samples or p is None:
            return None
        return max(float(min_ms), p * float(factor))
