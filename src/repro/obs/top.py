"""``python -m repro.obs.top`` — live terminal dashboard over ``/stats``.

Polls the admin HTTP plane (:mod:`repro.obs.http`) of a running pool /
serving engine and renders a compact refresh-in-place view: request and
byte *rates* (differenced between polls), latency quantiles, hedging
and re-dispatch counters, and one row per worker with its live health
score (the same ``pool_worker_health`` gauge Prometheus scrapes).

Usage::

    python -m repro.obs.top --url http://127.0.0.1:9100
    python -m repro.obs.top --url ... --once          # one frame, no clear
    python -m repro.obs.top --url ... --iterations 5  # bounded run (tests)

Stdlib only (urllib + json); exits non-zero when the endpoint never
answers.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.error
import urllib.request
from typing import Dict, List, Optional, Tuple

__all__ = ["fetch_stats", "main", "render"]

# counters whose per-second rate is the interesting number
_RATES = (
    ("pool_requests", "req/s"),
    ("pool_completed", "done/s"),
    ("pool_bytes_out", "tx B/s"),
    ("pool_bytes_in", "rx B/s"),
    ("serve_submitted", "serve req/s"),
    ("serve_completed", "serve done/s"),
)


def fetch_stats(url: str, timeout: float = 5.0) -> Dict[str, object]:
    with urllib.request.urlopen(f"{url}/stats", timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def _fmt(value: object) -> str:
    if isinstance(value, float):
        return f"{value:,.1f}"
    if isinstance(value, int):
        return f"{value:,}"
    return str(value)


def _bar(score: float, width: int = 20) -> str:
    filled = max(0, min(width, int(round(score * width))))
    return "#" * filled + "." * (width - filled)


def render(
    snap: Dict[str, object],
    prev: Optional[Tuple[float, Dict[str, object]]] = None,
    now: Optional[float] = None,
) -> str:
    """One dashboard frame (pure text; the caller decides how to paint).

    ``prev`` is ``(t, snapshot)`` of the previous poll, used to difference
    cumulative counters into rates; rates render as ``-`` on the first
    frame.
    """
    now = time.time() if now is None else now
    lines: List[str] = []
    live = snap.get("pool_workers_live", "-")
    lines.append(
        f"repro.obs.top  {time.strftime('%H:%M:%S', time.localtime(now))}"
        f"  workers live: {_fmt(live)}"
    )

    dt = None
    if prev is not None and now > prev[0]:
        dt = now - prev[0]
    rate_bits = []
    for key, label in _RATES:
        cur = snap.get(key)
        if not isinstance(cur, (int, float)):
            continue
        if dt is None or not isinstance(prev[1].get(key), (int, float)):
            rate_bits.append(f"{label} -")
        else:
            rate_bits.append(f"{label} {(cur - prev[1][key]) / dt:,.1f}")
    if rate_bits:
        lines.append("  " + "   ".join(rate_bits))

    totals = []
    for key in (
        "pool_requests", "pool_completed", "pool_failed",
        "pool_redispatched", "pool_hedged", "pool_hedge_wasted",
        "serve_batches", "serve_mean_fill", "scheduler_completed",
    ):
        val = snap.get(key)
        if isinstance(val, (int, float)):
            totals.append(f"{key.split('_', 1)[1]}={_fmt(val)}")
    if totals:
        lines.append("  " + "  ".join(totals))

    lats = []
    for key in (
        "pool_time_to_R_ms_p50", "pool_time_to_R_ms_p99",
        "pool_wall_ms_p50", "pool_wall_ms_p99", "serve_wait_ms_p50",
        "serve_wait_ms_p99", "pool_share_ms_window_p95",
    ):
        val = snap.get(key)
        if isinstance(val, (int, float)):
            lats.append(f"{key[len('pool_'):] if key.startswith('pool_') else key}"
                        f"={val:,.2f}")
    if lats:
        lines.append("  " + "  ".join(lats))

    health = snap.get("pool_worker_health_by_wid")
    tasks = snap.get("pool_worker_tasks_done_by_wid") or {}
    if isinstance(health, dict) and health:
        lines.append("  worker  health                speed  tasks")
        for wid in sorted(health, key=lambda w: (len(w), w)):
            score = float(health[wid])
            done = tasks.get(wid, "-") if isinstance(tasks, dict) else "-"
            lines.append(
                f"  {wid:>6}  [{_bar(score)}] {score:5.2f}  {_fmt(done):>5}"
            )
    return "\n".join(lines)


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--url", default="http://127.0.0.1:9100",
        help="admin-plane base URL (see REPRO_OBS_HTTP_PORT)",
    )
    ap.add_argument("--interval", type=float, default=1.0, metavar="SECONDS")
    ap.add_argument(
        "--once", action="store_true",
        help="print a single frame and exit (no screen clearing)",
    )
    ap.add_argument(
        "--iterations", type=int, default=0, metavar="N",
        help="stop after N frames (0 = run until interrupted)",
    )
    args = ap.parse_args(argv)
    prev: Optional[Tuple[float, Dict[str, object]]] = None
    frames = 0
    while True:
        try:
            snap = fetch_stats(args.url)
        except (urllib.error.URLError, OSError, ValueError) as e:
            print(f"repro.obs.top: cannot scrape {args.url}/stats: {e}",
                  file=sys.stderr)
            return 1
        now = time.time()
        frame = render(snap, prev, now=now)
        if args.once:
            print(frame)
            return 0
        # ANSI clear + home keeps the frame in place without curses
        sys.stdout.write("\x1b[2J\x1b[H" + frame + "\n")
        sys.stdout.flush()
        prev = (now, snap)
        frames += 1
        if args.iterations and frames >= args.iterations:
            return 0
        try:
            time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0


if __name__ == "__main__":
    sys.exit(main())
