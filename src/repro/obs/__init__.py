"""repro.obs — structured tracing, live metrics and the HTTP admin plane.

See :mod:`repro.obs.trace` for the span model,
:mod:`repro.obs.metrics` for the push-based time-series registry the
pool/scheduler/serve components publish into, :mod:`repro.obs.health`
for per-worker health scoring (EWMA round-trips + heartbeat jitter)
feeding dispatch order and hedged re-dispatch,
:mod:`repro.obs.http` for the embedded ``/metrics`` ``/healthz``
``/stats`` ``/trace/<id>`` server, and :mod:`repro.obs.export` for the
JSON / Chrome trace_event / Prometheus output formats
(:func:`parse_prometheus` validates the exposition text strictly —
CI's scrape oracle).  ``python -m repro.obs.top`` is a live terminal
dashboard over ``/stats``.
"""
from repro.obs.export import (
    parse_prometheus,
    to_chrome_trace,
    to_json,
    to_prometheus,
    validate_timeline,
)
from repro.obs.health import HealthTracker
from repro.obs.http import (
    ObsHttpServer,
    register_source,
    register_trace_resolver,
    start_server,
    stop_server,
    unregister_source,
    unregister_trace_resolver,
)
from repro.obs.metrics import Counter, Gauge, MetricsRegistry, Series
from repro.obs.trace import (
    Span,
    Timeline,
    TraceContext,
    Tracer,
    enabled,
    maybe_context,
    new_trace_id,
    now,
    set_enabled,
    spans_from_wire,
    spans_to_wire,
    tracer,
)

__all__ = [
    "Counter",
    "Gauge",
    "HealthTracker",
    "MetricsRegistry",
    "ObsHttpServer",
    "Series",
    "Span",
    "Timeline",
    "TraceContext",
    "Tracer",
    "enabled",
    "maybe_context",
    "new_trace_id",
    "now",
    "parse_prometheus",
    "register_source",
    "register_trace_resolver",
    "set_enabled",
    "spans_from_wire",
    "spans_to_wire",
    "start_server",
    "stop_server",
    "to_chrome_trace",
    "to_json",
    "to_prometheus",
    "tracer",
    "unregister_source",
    "unregister_trace_resolver",
    "validate_timeline",
]
