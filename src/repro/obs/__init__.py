"""repro.obs — structured per-request tracing and exporters.

See :mod:`repro.obs.trace` for the span model and
:mod:`repro.obs.export` for the JSON / Chrome trace_event / Prometheus
output formats.
"""
from repro.obs.export import (
    to_chrome_trace,
    to_json,
    to_prometheus,
    validate_timeline,
)
from repro.obs.trace import (
    Span,
    Timeline,
    TraceContext,
    Tracer,
    enabled,
    maybe_context,
    new_trace_id,
    now,
    set_enabled,
    spans_from_wire,
    spans_to_wire,
    tracer,
)

__all__ = [
    "Span",
    "Timeline",
    "TraceContext",
    "Tracer",
    "enabled",
    "maybe_context",
    "new_trace_id",
    "now",
    "set_enabled",
    "spans_from_wire",
    "spans_to_wire",
    "to_chrome_trace",
    "to_json",
    "to_prometheus",
    "tracer",
    "validate_timeline",
]
