"""Live metrics registry: cheap in-line recording, scrape-shaped reads.

Before this module, every runtime surface kept its own ad-hoc counter
dict behind its own lock and materialized numbers only when someone
called ``snapshot()`` — pull-only observability.  The registry inverts
that: ``Master``, ``PoolScheduler`` and ``ServeScheduler`` each own a
:class:`MetricsRegistry` and record into typed instruments *as events
happen* (a counter ``inc`` is one lock + one add), and ``snapshot()``
becomes a cheap read of state that already exists — the same numbers the
HTTP plane (:mod:`repro.obs.http`) serves continuously at ``/metrics``
and ``/stats``.

Instruments:

- :class:`Counter` — monotone float/int accumulator (``inc``);
- :class:`Gauge` — last-write-wins scalar, optionally *labeled*
  (``gauge("worker_health", label="wid")`` snapshots as a
  ``worker_health_by_wid`` dict, which the Prometheus exporter turns
  into one ``{wid="..."}``-labeled sample per key);
- histograms are :class:`repro.stats.Histogram` — the shared
  ``*_hist``/``*_p50``/``*_p99``/``*_sum`` schema, so registry
  snapshots merge with legacy ones via ``merge_snapshots``;
- :class:`Series` — a ring buffer of ``(t, value)`` observations with a
  retention window, for *windowed* quantiles over recent behaviour
  (the health tracker's hedge deadline is ``series.quantile(0.95)``
  over the last few minutes of share round-trips, not over the whole
  process lifetime).

``snapshot()`` emits the component-prefixed :class:`repro.stats`
schema, so everything downstream (``merge_snapshots``, ``--stats-every``
consumers, the Prometheus exporter) works unchanged.  The snapshot also
carries per-key type and doc maps (``_types`` / ``_docs`` attributes)
that :func:`repro.obs.export.to_prometheus` consults for ``# TYPE`` /
``# HELP`` lines.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.stats import BUCKETS_MS, Histogram, StatsSnapshot, namespaced

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "Series",
]

DEFAULT_RETENTION_S = 300.0  # series window when REPRO_OBS_RETENTION unset
DEFAULT_SERIES_CAP = 4096  # hard bound per series regardless of window


class Counter:
    """Monotone accumulator.  ``inc`` is the hot-path call: one lock, one
    add — cheap enough to live inline in dispatch/result paths."""

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, by: float = 1) -> None:
        with self._lock:
            self._value += by

    @property
    def value(self) -> float:
        with self._lock:
            v = self._value
        # counters bumped only by ints stay ints in snapshots
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-write-wins scalar, optionally labeled.

    A plain gauge snapshots as ``{name: value}``.  A labeled gauge
    (``label="wid"``) snapshots as ``{f"{name}_by_{label}": {key: value}}``
    — the ``_by_<label>`` convention the Prometheus exporter unpacks into
    one labeled sample per key (``repro_pool_worker_health{wid="0"} ...``).
    """

    def __init__(self, name: str, label: Optional[str] = None):
        self.name = name
        self.label = label
        self._value: Optional[float] = None
        self._labeled: Dict[str, float] = {}
        self._lock = threading.Lock()

    def set(self, value: float, key: Optional[object] = None) -> None:
        with self._lock:
            if key is None:
                self._value = value
            else:
                if self.label is None:
                    raise ValueError(
                        f"gauge {self.name!r} was not declared with a label"
                    )
                self._labeled[str(key)] = value

    def clear_labels(self, keep: Sequence[object] = ()) -> None:
        """Drop labeled entries not in ``keep`` (dead workers leave the
        health gauge instead of freezing at their last score)."""
        keepset = {str(k) for k in keep}
        with self._lock:
            self._labeled = {
                k: v for k, v in self._labeled.items() if k in keepset
            }

    def snapshot_items(self) -> Dict[str, object]:
        with self._lock:
            out: Dict[str, object] = {}
            if self._value is not None:
                out[self.name] = self._value
            if self.label is not None:
                out[f"{self.name}_by_{self.label}"] = dict(self._labeled)
        return out


class Series:
    """Ring buffer of ``(t, value)`` observations with a retention window.

    ``quantile(q)`` answers over the retained window only — "p95 share
    round-trip over the last five minutes", not over process lifetime —
    which is what a hedge deadline must track when worker behaviour
    drifts.  Bounded twice: by ``retention_s`` (old points pruned on
    every add/read) and ``capacity`` (hard memory cap).
    """

    def __init__(
        self,
        name: str,
        retention_s: float = DEFAULT_RETENTION_S,
        capacity: int = DEFAULT_SERIES_CAP,
    ):
        self.name = name
        self.retention_s = float(retention_s)
        self._points: "deque" = deque(maxlen=capacity)
        self._lock = threading.Lock()

    def add(self, value: float, t: Optional[float] = None) -> None:
        t = time.monotonic() if t is None else t
        with self._lock:
            self._points.append((t, float(value)))
            self._prune(t)

    def _prune(self, now: float) -> None:
        # caller holds the lock
        horizon = now - self.retention_s
        while self._points and self._points[0][0] < horizon:
            self._points.popleft()

    def clear(self) -> None:
        """Drop every retained point (e.g. discard compile-storm warmup
        round-trips so windowed quantiles reflect steady state only)."""
        with self._lock:
            self._points.clear()

    def values(self, window_s: Optional[float] = None) -> List[float]:
        now = time.monotonic()
        with self._lock:
            self._prune(now)
            pts = list(self._points)
        if window_s is not None:
            pts = [p for p in pts if p[0] >= now - window_s]
        return [v for _, v in pts]

    def __len__(self) -> int:
        with self._lock:
            self._prune(time.monotonic())
            return len(self._points)

    def quantile(
        self, q: float, window_s: Optional[float] = None
    ) -> Optional[float]:
        vals = sorted(self.values(window_s))
        if not vals:
            return None
        idx = min(len(vals) - 1, max(0, int(q * len(vals))))
        return vals[idx]


class MetricsRegistry:
    """One component's instruments, snapshotting in the shared schema.

    Get-or-create accessors (``counter``/``gauge``/``histogram``/
    ``series``) are idempotent by name, so recording sites never need a
    registration phase.  ``snapshot()`` returns the same
    component-prefixed :class:`repro.stats.StatsSnapshot` the legacy
    ``snapshot()`` methods produced, annotated with ``_types``/``_docs``
    for the Prometheus exporter.
    """

    def __init__(
        self,
        component: str,
        retention_s: float = DEFAULT_RETENTION_S,
    ):
        self.component = component
        self.retention_s = float(retention_s)
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._series: Dict[str, Series] = {}
        self._docs: Dict[str, str] = {}

    def _doc(self, name: str, doc: str) -> None:
        if doc:
            self._docs[name] = doc

    def counter(self, name: str, doc: str = "") -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
                self._doc(name, doc)
        return c

    def gauge(self, name: str, doc: str = "",
              label: Optional[str] = None) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, label=label)
                self._doc(name, doc)
        return g

    def histogram(self, name: str, doc: str = "",
                  bounds: Sequence[float] = BUCKETS_MS) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(bounds)
                self._doc(name, doc)
        return h

    def series(self, name: str, doc: str = "",
               retention_s: Optional[float] = None) -> Series:
        with self._lock:
            s = self._series.get(name)
            if s is None:
                s = self._series[name] = Series(
                    name,
                    retention_s=(self.retention_s if retention_s is None
                                 else retention_s),
                )
                self._doc(name, doc)
        return s

    def snapshot(
        self, extra: Optional[Dict[str, object]] = None
    ) -> StatsSnapshot:
        """Everything recorded so far, component-prefixed.

        ``extra`` merges derived, caller-computed keys (mean fill,
        amortized cost ...) into the same snapshot before prefixing.
        Series are summarized (count + windowed p50/p95) rather than
        dumped — raw points are an internal signal, not a stat.
        """
        with self._lock:
            counters = list(self._counters.items())
            gauges = list(self._gauges.values())
            hists = list(self._hists.items())
            series = list(self._series.items())
        data: Dict[str, object] = {}
        types: Dict[str, str] = {}
        for name, c in counters:
            data[name] = c.value
            types[name] = "counter"
        for g in gauges:
            items = g.snapshot_items()
            data.update(items)
            for key in items:
                types[key] = "gauge"
        for name, h in hists:
            data.update(h.snapshot(name))
            types[f"{name}_hist"] = "histogram"
        for name, s in series:
            data[f"{name}_window_count"] = len(s)
            p50 = s.quantile(0.50)
            p95 = s.quantile(0.95)
            if p50 is not None:
                data[f"{name}_window_p50"] = round(p50, 3)
                types[f"{name}_window_p50"] = "gauge"
            if p95 is not None:
                data[f"{name}_window_p95"] = round(p95, 3)
                types[f"{name}_window_p95"] = "gauge"
        if extra:
            data.update(extra)
        snap = namespaced(self.component, data)
        prefix = f"{self.component}_"

        def _canon(key: str) -> str:
            return key if key.startswith(prefix) else prefix + key

        snap._types = {_canon(k): v for k, v in types.items()}
        snap._docs = {_canon(k): v for k, v in self._docs.items()}
        return snap
