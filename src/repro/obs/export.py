"""Exporters and validators for :class:`repro.obs.Timeline`.

Three output formats, one input schema (the span JSON emitted by
``Timeline.to_json``):

- :func:`to_json` — the canonical schema, round-trippable via
  ``Timeline.from_json``;
- :func:`to_chrome_trace` — Chrome ``trace_event`` JSON for
  ``about://tracing`` / https://ui.perfetto.dev: complete ("X") events,
  one process lane per component and one thread lane per worker, so the
  any-R race is visible as R+ overlapping compute bars;
- :func:`to_prometheus` — text exposition of a ``repro.stats`` snapshot
  (counters as ``counter``, ``*_hist`` buckets as cumulative
  ``histogram`` series) for scrape-style consumers.

:func:`validate_timeline` is the schema check CI runs on ``--trace``
smoke exports: spans non-empty, every span time-ordered and carrying the
required fields, and per-worker compute spans present for at least the
R responders that fed decode.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import Timeline

__all__ = [
    "parse_prometheus",
    "to_chrome_trace",
    "to_json",
    "to_prometheus",
    "validate_timeline",
]


def to_json(timeline: Timeline, indent: Optional[int] = None) -> str:
    """The canonical span-JSON document (see ``Timeline.to_json``)."""
    return json.dumps(timeline.to_json(), indent=indent, sort_keys=True)


def _chrome_tid(span) -> str:
    wid = span.tags.get("wid")
    return f"worker {wid}" if wid is not None else "main"


def to_chrome_trace(timeline: Timeline, indent: Optional[int] = None) -> str:
    """Chrome ``trace_event`` JSON: load in about://tracing or Perfetto.

    Lanes: pid = component, tid = worker id (or "main").  Timestamps are
    microseconds relative to the timeline's first span so the viewer
    opens at t=0 instead of the 2026 epoch.
    """
    t0 = timeline.t_start
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    events: List[Dict] = []
    for span in timeline.spans:
        pid = pids.setdefault(span.component, len(pids) + 1)
        tid = tids.setdefault((span.component, _chrome_tid(span)),
                              len(tids) + 1)
        events.append({
            "name": span.name,
            "cat": span.component,
            "ph": "X",
            "ts": (span.t_start - t0) * 1e6,
            "dur": span.duration_s * 1e6,
            "pid": pid,
            "tid": tid,
            "args": {k: v for k, v in span.tags.items()},
        })
    for component, pid in pids.items():
        events.append({
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": component},
        })
    for (component, label), tid in tids.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": pids[component],
            "tid": tid, "args": {"name": label},
        })
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"trace_id": timeline.trace_id},
    }
    return json.dumps(doc, indent=indent)


_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")

# gauge-shaped snapshot keys that aren't quantiles: last-write-wins
# signals where "sum across restarts" would be meaningless
_GAUGE_SUFFIXES = ("_live", "_fill", "_health", "_score", "_window_count")


def _prom_name(key: str) -> str:
    return "repro_" + _NAME_SANITIZE.sub("_", key)


def _escape_label(value: object) -> str:
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace('"', '\\"')
        .replace("\n", "\\n")
    )


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _hist_bound(bucket: str) -> float:
    if bucket == "inf":
        return float("inf")
    return float(bucket[2:] if bucket.startswith("<=") else bucket)


class _Emitter:
    """Collects exposition lines, guarding family-name collisions.

    Distinct snapshot keys can sanitize to the same metric name
    (``wall.ms`` and ``wall_ms`` both become ``repro_wall_ms``); a
    duplicate family in the exposition is invalid, so the first key
    wins and colliders are skipped with a comment naming them.
    """

    def __init__(self):
        self.lines: List[str] = []
        self._families: Dict[str, str] = {}  # family name -> source key

    def family(self, name: str, key: str, typ: str, help_text: str) -> bool:
        owner = self._families.get(name)
        if owner is not None and owner != key:
            self.lines.append(
                f"# collision: snapshot key {key!r} also sanitizes to "
                f"{name}; skipped (kept {owner!r})"
            )
            return False
        if owner is None:
            self._families[name] = key
            self.lines.append(f"# HELP {name} {_escape_help(help_text)}")
            self.lines.append(f"# TYPE {name} {typ}")
        return True


def to_prometheus(
    snapshot: Dict[str, object],
    docs: Optional[Dict[str, str]] = None,
) -> str:
    """Prometheus text exposition of a ``repro.stats`` snapshot.

    - ``*_hist`` dicts become real cumulative histogram families:
      ``<name>_bucket{le="..."}`` (accumulated — snapshot buckets are
      per-bucket counts), ``<name>_sum`` (from the snapshot's matching
      ``*_sum`` key when present) and ``<name>_count``;
    - ``*_p50``/``*_p99`` and registry gauges become ``gauge`` samples;
    - ``*_by_<label>`` dicts (labeled gauges from
      :class:`repro.obs.metrics.Gauge`) become one ``{label="key"}``
      sample per entry, label values escaped;
    - other scalars become ``counter`` samples; bools and non-numerics
      are skipped.

    Every family gets ``# HELP`` (from ``docs`` and the snapshot's own
    ``_docs`` annotation when present) and ``# TYPE`` lines, and
    distinct keys colliding after name sanitization are skipped (first
    wins) instead of silently overwriting.  ``_types`` annotations from
    :meth:`repro.obs.metrics.MetricsRegistry.snapshot` override the
    suffix heuristics for counter-vs-gauge.
    """
    all_docs = dict(getattr(snapshot, "_docs", {}) or {})
    if docs:
        all_docs.update(docs)
    types = dict(getattr(snapshot, "_types", {}) or {})
    em = _Emitter()
    consumed_sums = {
        key[: -len("_hist")] + "_sum"
        for key, val in snapshot.items()
        if key.endswith("_hist") and isinstance(val, dict)
    }
    for key in sorted(snapshot):
        val = snapshot[key]
        help_text = all_docs.get(key, f"repro stats key {key}")
        if key.endswith("_hist") and isinstance(val, dict):
            base_key = key[: -len("_hist")]
            name = _prom_name(base_key)
            if not em.family(name, key, "histogram",
                             all_docs.get(key, f"repro stats key {base_key}")):
                continue
            buckets = sorted(
                (
                    (b, int(c)) for b, c in val.items()
                    if isinstance(c, (int, float))
                ),
                key=lambda bc: _hist_bound(bc[0]),
            )
            cum = 0
            for bucket, count in buckets:
                cum += count
                bound = _hist_bound(bucket)
                le = "+Inf" if bound == float("inf") else f"{bound:g}"
                em.lines.append(f'{name}_bucket{{le="{le}"}} {cum}')
            if not buckets or _hist_bound(buckets[-1][0]) != float("inf"):
                em.lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
            total_sum = snapshot.get(f"{base_key}_sum", 0)
            if not isinstance(total_sum, (int, float)):
                total_sum = 0
            em.lines.append(f"{name}_sum {total_sum}")
            em.lines.append(f"{name}_count {cum}")
        elif key in consumed_sums:
            continue  # folded into its histogram family above
        elif "_by_" in key and isinstance(val, dict):
            base_key, _, label = key.rpartition("_by_")
            if not base_key or not label:
                continue
            name = _prom_name(base_key)
            if not em.family(name, key, "gauge", help_text):
                continue
            for lkey in sorted(val):
                lval = val[lkey]
                if isinstance(lval, bool) or not isinstance(
                    lval, (int, float)
                ):
                    continue
                em.lines.append(
                    f'{name}{{{label}="{_escape_label(lkey)}"}} {lval}'
                )
        elif isinstance(val, bool):
            continue
        elif isinstance(val, (int, float)):
            name = _prom_name(key)
            typ = types.get(key)
            if typ is None:
                typ = (
                    "gauge"
                    if key.endswith(("_p50", "_p95", "_p99")
                                    ) or key.endswith(_GAUGE_SUFFIXES)
                    else "counter"
                )
            if not em.family(name, key, typ, help_text):
                continue
            em.lines.append(f"{name} {val}")
    return "\n".join(em.lines) + "\n"


# -- strict exposition parsing (the CI metrics-smoke gate) -----------------

_HELP_RE = re.compile(r"^# HELP ([a-zA-Z_:][a-zA-Z0-9_:]*) (.*)$")
_TYPE_RE = re.compile(r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (\w+)$")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"  # metric name
    r"(?:\{(.*)\})?"  # optional label block
    r"\s+(\S+)"  # value
    r"(?:\s+(-?\d+))?$"  # optional timestamp
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_VALID_TYPES = {
    "counter", "gauge", "histogram", "summary", "untyped",
}


def _parse_labels(block: str, lineno: int) -> Dict[str, str]:
    labels: Dict[str, str] = {}
    rest = block
    while rest:
        m = _LABEL_RE.match(rest)
        if m is None:
            raise ValueError(
                f"line {lineno}: malformed label block {block!r}"
            )
        labels[m.group(1)] = (
            m.group(2)
            .replace("\\n", "\n")
            .replace('\\"', '"')
            .replace("\\\\", "\\")
        )
        rest = rest[m.end():]
        if rest.startswith(","):
            rest = rest[1:]
        elif rest:
            raise ValueError(
                f"line {lineno}: junk after label pair in {block!r}"
            )
    return labels


def _family_of(name: str) -> str:
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def parse_prometheus(text: str) -> Dict[str, Dict]:
    """Strictly parse Prometheus text exposition (format 0.0.4).

    Returns ``{family: {"type", "help", "samples": [(name, labels,
    value)]}}`` and raises ``ValueError`` on anything malformed: bad
    metric/label syntax, unknown TYPE, TYPE redeclared or declared after
    the family's samples, duplicate (name, labelset) samples, histogram
    families missing their ``+Inf`` bucket, non-monotone cumulative
    bucket counts, or ``_count`` disagreeing with the ``+Inf`` bucket.
    This is the gate CI's ``metrics-smoke`` runs on a live ``/metrics``
    scrape, so it prefers false alarms over leniency.
    """
    families: Dict[str, Dict] = {}
    seen_samples: set = set()
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            m = _HELP_RE.match(line)
            if m is not None:
                fam = families.setdefault(
                    m.group(1), {"type": None, "help": None, "samples": []}
                )
                fam["help"] = m.group(2)
                continue
            m = _TYPE_RE.match(line)
            if m is not None:
                name, typ = m.group(1), m.group(2)
                if typ not in _VALID_TYPES:
                    raise ValueError(
                        f"line {lineno}: unknown metric type {typ!r}"
                    )
                fam = families.setdefault(
                    name, {"type": None, "help": None, "samples": []}
                )
                if fam["type"] is not None:
                    raise ValueError(
                        f"line {lineno}: duplicate TYPE for {name}"
                    )
                if fam["samples"]:
                    raise ValueError(
                        f"line {lineno}: TYPE for {name} after its samples"
                    )
                fam["type"] = typ
                continue
            continue  # plain comment
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        name, label_block, value_s = m.group(1), m.group(2), m.group(3)
        labels = (
            _parse_labels(label_block, lineno) if label_block else {}
        )
        try:
            value = float(value_s)
        except ValueError:
            raise ValueError(
                f"line {lineno}: unparsable value {value_s!r}"
            ) from None
        sample_key = (name, tuple(sorted(labels.items())))
        if sample_key in seen_samples:
            raise ValueError(
                f"line {lineno}: duplicate sample {name}{labels}"
            )
        seen_samples.add(sample_key)
        family = _family_of(name)
        fam = families.setdefault(
            family, {"type": None, "help": None, "samples": []}
        )
        if fam["type"] is None and family != name:
            # _bucket/_sum/_count of an undeclared family: the bare name
            # is its own (untyped) family
            fam = families.setdefault(
                name, {"type": None, "help": None, "samples": []}
            )
        fam["samples"].append((name, labels, value))
    for family, fam in families.items():
        if fam["type"] != "histogram":
            continue
        buckets: List[Tuple[float, float]] = []
        count_val: Optional[float] = None
        for name, labels, value in fam["samples"]:
            if name == f"{family}_bucket":
                if "le" not in labels:
                    raise ValueError(
                        f"{family}: bucket sample without le label"
                    )
                le = labels["le"]
                bound = float("inf") if le == "+Inf" else float(le)
                buckets.append((bound, value))
            elif name == f"{family}_count":
                count_val = value
        if not buckets:
            raise ValueError(f"{family}: histogram with no buckets")
        buckets.sort(key=lambda bv: bv[0])
        if buckets[-1][0] != float("inf"):
            raise ValueError(f"{family}: histogram missing +Inf bucket")
        prev = 0.0
        for bound, value in buckets:
            if value < prev:
                raise ValueError(
                    f"{family}: bucket counts not cumulative at le={bound}"
                )
            prev = value
        if count_val is not None and count_val != buckets[-1][1]:
            raise ValueError(
                f"{family}: _count {count_val} != +Inf bucket "
                f"{buckets[-1][1]}"
            )
    return families


_REQUIRED_SPAN_FIELDS = ("trace_id", "name", "component", "t_start", "t_end")


def validate_timeline(
    doc: Dict,
    min_workers: int = 0,
    require_components: Sequence[str] = (),
) -> List[str]:
    """Schema-check an exported span-JSON document.

    Returns a list of human-readable problems (empty = valid):
    spans present, every span carrying the required fields with
    ``t_end >= t_start``, at least ``min_workers`` distinct worker ids
    among compute spans, and every component in ``require_components``
    represented.
    """
    problems: List[str] = []
    spans = doc.get("spans")
    if not isinstance(spans, list) or not spans:
        return ["timeline has no spans"]
    wids = set()
    components = set()
    for i, s in enumerate(spans):
        if not isinstance(s, dict):
            problems.append(f"span[{i}] is not an object")
            continue
        missing = [f for f in _REQUIRED_SPAN_FIELDS if f not in s]
        if missing:
            problems.append(f"span[{i}] missing fields {missing}")
            continue
        if not (isinstance(s["t_start"], (int, float))
                and isinstance(s["t_end"], (int, float))):
            problems.append(f"span[{i}] has non-numeric times")
            continue
        if s["t_end"] < s["t_start"]:
            problems.append(
                f"span[{i}] ({s['name']}) ends before it starts: "
                f"{s['t_end']} < {s['t_start']}"
            )
        components.add(s["component"])
        tags = s.get("tags", {})
        if s["name"] == "compute" and "wid" in tags:
            wids.add(tags["wid"])
    if len(wids) < min_workers:
        problems.append(
            f"expected compute spans from >= {min_workers} workers, "
            f"saw {len(wids)} ({sorted(map(str, wids))})"
        )
    for comp in require_components:
        if comp not in components:
            problems.append(f"no spans from component {comp!r}")
    return problems
