"""Exporters and validators for :class:`repro.obs.Timeline`.

Three output formats, one input schema (the span JSON emitted by
``Timeline.to_json``):

- :func:`to_json` — the canonical schema, round-trippable via
  ``Timeline.from_json``;
- :func:`to_chrome_trace` — Chrome ``trace_event`` JSON for
  ``about://tracing`` / https://ui.perfetto.dev: complete ("X") events,
  one process lane per component and one thread lane per worker, so the
  any-R race is visible as R+ overlapping compute bars;
- :func:`to_prometheus` — text exposition of a ``repro.stats`` snapshot
  (counters as ``counter``, ``*_hist`` buckets as cumulative
  ``histogram`` series) for scrape-style consumers.

:func:`validate_timeline` is the schema check CI runs on ``--trace``
smoke exports: spans non-empty, every span time-ordered and carrying the
required fields, and per-worker compute spans present for at least the
R responders that fed decode.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from repro.obs.trace import Timeline

__all__ = [
    "to_chrome_trace",
    "to_json",
    "to_prometheus",
    "validate_timeline",
]


def to_json(timeline: Timeline, indent: Optional[int] = None) -> str:
    """The canonical span-JSON document (see ``Timeline.to_json``)."""
    return json.dumps(timeline.to_json(), indent=indent, sort_keys=True)


def _chrome_tid(span) -> str:
    wid = span.tags.get("wid")
    return f"worker {wid}" if wid is not None else "main"


def to_chrome_trace(timeline: Timeline, indent: Optional[int] = None) -> str:
    """Chrome ``trace_event`` JSON: load in about://tracing or Perfetto.

    Lanes: pid = component, tid = worker id (or "main").  Timestamps are
    microseconds relative to the timeline's first span so the viewer
    opens at t=0 instead of the 2026 epoch.
    """
    t0 = timeline.t_start
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    events: List[Dict] = []
    for span in timeline.spans:
        pid = pids.setdefault(span.component, len(pids) + 1)
        tid = tids.setdefault((span.component, _chrome_tid(span)),
                              len(tids) + 1)
        events.append({
            "name": span.name,
            "cat": span.component,
            "ph": "X",
            "ts": (span.t_start - t0) * 1e6,
            "dur": span.duration_s * 1e6,
            "pid": pid,
            "tid": tid,
            "args": {k: v for k, v in span.tags.items()},
        })
    for component, pid in pids.items():
        events.append({
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": component},
        })
    for (component, label), tid in tids.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": pids[component],
            "tid": tid, "args": {"name": label},
        })
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"trace_id": timeline.trace_id},
    }
    return json.dumps(doc, indent=indent)


def _prom_name(key: str) -> str:
    return "repro_" + key.replace(".", "_")


def to_prometheus(snapshot: Dict[str, object]) -> str:
    """Prometheus text exposition of a ``repro.stats`` snapshot.

    Scalar numbers become ``counter`` samples; ``*_hist`` dicts become
    cumulative ``histogram`` bucket series (the snapshot's per-bucket
    counts are non-cumulative, so we accumulate here); ``*_p50``/``*_p99``
    become ``gauge`` samples.  Non-numeric values are skipped.
    """
    lines: List[str] = []
    for key in sorted(snapshot):
        val = snapshot[key]
        if key.endswith("_hist") and isinstance(val, dict):
            base = _prom_name(key[: -len("_hist")]) + "_ms"
            lines.append(f"# TYPE {base} histogram")
            cum = 0
            total = 0
            for bucket, count in val.items():
                if not isinstance(count, (int, float)):
                    continue
                total += count
                le = bucket[2:] if bucket.startswith("<=") else bucket
                if bucket == "inf" or le == "inf":
                    continue
                cum += count
                lines.append(f'{base}_bucket{{le="{le}"}} {cum}')
            lines.append(f'{base}_bucket{{le="+Inf"}} {total}')
            lines.append(f"{base}_count {total}")
        elif key.endswith(("_p50", "_p99")) and isinstance(val, (int, float)):
            name = _prom_name(key)
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {val}")
        elif isinstance(val, bool):
            continue
        elif isinstance(val, (int, float)):
            name = _prom_name(key)
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name} {val}")
    return "\n".join(lines) + "\n"


_REQUIRED_SPAN_FIELDS = ("trace_id", "name", "component", "t_start", "t_end")


def validate_timeline(
    doc: Dict,
    min_workers: int = 0,
    require_components: Sequence[str] = (),
) -> List[str]:
    """Schema-check an exported span-JSON document.

    Returns a list of human-readable problems (empty = valid):
    spans present, every span carrying the required fields with
    ``t_end >= t_start``, at least ``min_workers`` distinct worker ids
    among compute spans, and every component in ``require_components``
    represented.
    """
    problems: List[str] = []
    spans = doc.get("spans")
    if not isinstance(spans, list) or not spans:
        return ["timeline has no spans"]
    wids = set()
    components = set()
    for i, s in enumerate(spans):
        if not isinstance(s, dict):
            problems.append(f"span[{i}] is not an object")
            continue
        missing = [f for f in _REQUIRED_SPAN_FIELDS if f not in s]
        if missing:
            problems.append(f"span[{i}] missing fields {missing}")
            continue
        if not (isinstance(s["t_start"], (int, float))
                and isinstance(s["t_end"], (int, float))):
            problems.append(f"span[{i}] has non-numeric times")
            continue
        if s["t_end"] < s["t_start"]:
            problems.append(
                f"span[{i}] ({s['name']}) ends before it starts: "
                f"{s['t_end']} < {s['t_start']}"
            )
        components.add(s["component"])
        tags = s.get("tags", {})
        if s["name"] == "compute" and "wid" in tags:
            wids.add(tags["wid"])
    if len(wids) < min_workers:
        problems.append(
            f"expected compute spans from >= {min_workers} workers, "
            f"saw {len(wids)} ({sorted(map(str, wids))})"
        )
    for comp in require_components:
        if comp not in components:
            problems.append(f"no spans from component {comp!r}")
    return problems
