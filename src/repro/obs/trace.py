"""Structured request tracing: spans, trace contexts, a bounded tracer.

The paper's performance claim is about *time-to-R* — any R of N workers
suffice — and aggregate histograms can't show where one request's latency
went (coalesce wait vs. encode vs. wire vs. the R-th worker's straggle
vs. decode).  This module is the per-request evidence layer:

- :class:`Span` — one timed operation: name, component (``serve`` /
  ``scheduler`` / ``pool`` / ``worker`` / ``local`` / ``elastic``),
  epoch-aligned start/end seconds, and free-form tags (worker id, share
  index, byte counts, host pid);
- :class:`TraceContext` — a trace id plus the span-name stack, carried
  explicitly through the request path (the path hops threads and
  processes, so ambient context vars can't follow it);
- :class:`Tracer` — the process-local collector: a thread-safe ring
  buffer (capacity from ``REPRO_TRACE_BUFFER``) so a long-lived serving
  process never grows without bound;
- :class:`Timeline` — every span of one trace id (plus any linked
  carrier trace — a coalesced batch records its pool spans once, under
  the carrier), sorted by start time, exportable via
  :mod:`repro.obs.export`.

Timestamps come from :func:`now`: ``perf_counter`` anchored to the epoch
once per process — monotone within a process, comparable across
processes on one host (cross-host spans carry their host's clock; tags
identify the origin, and skew is the reader's problem, as in any
distributed trace).

Tracing is off by default; enable with ``REPRO_TRACE=1``, a ``--trace``
flag on the entry points, or :func:`set_enabled`.  Every recording path
is gated on a live :class:`TraceContext`, created only when enabled, so
the disabled overhead is one ``None`` check per request.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from repro import settings

__all__ = [
    "Span",
    "Timeline",
    "TraceContext",
    "Tracer",
    "enabled",
    "maybe_context",
    "new_trace_id",
    "now",
    "set_enabled",
    "tracer",
]

# epoch-aligned monotonic clock: perf_counter anchored once per process
_EPOCH = time.time() - time.perf_counter()


def now() -> float:
    """Epoch-aligned seconds, monotone within this process."""
    return _EPOCH + time.perf_counter()


_ids = itertools.count()
_PID = os.getpid()


def new_trace_id(prefix: str = "t") -> str:
    """Process-unique trace id (pid + counter; no RNG, no syscalls)."""
    return f"{prefix}-{_PID:x}-{next(_ids):x}"


@dataclass(frozen=True)
class Span:
    """One timed operation inside a trace."""

    trace_id: str
    name: str  # "encode", "send", "compute", "decode", "coalesce_wait"...
    component: str  # "serve" | "scheduler" | "pool" | "worker" | ...
    t_start: float  # epoch seconds (see now())
    t_end: float
    tags: Dict[str, object] = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    def to_json(self) -> Dict[str, object]:
        return {
            "trace_id": self.trace_id,
            "name": self.name,
            "component": self.component,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "tags": dict(self.tags),
        }

    @classmethod
    def from_json(cls, obj: Dict) -> "Span":
        return cls(
            trace_id=str(obj["trace_id"]),
            name=str(obj["name"]),
            component=str(obj["component"]),
            t_start=float(obj["t_start"]),
            t_end=float(obj["t_end"]),
            tags=dict(obj.get("tags", {})),
        )


@dataclass
class TraceContext:
    """A trace id plus the active span-name stack.

    Passed explicitly along the request path (admission queue -> coalesce
    thread -> executor -> pool master -> wire).  The stack only feeds the
    ``parent`` tag of nested spans — Chrome's trace viewer lanes spans by
    component/worker, so no span tree is needed.
    """

    trace_id: str
    request_id: Optional[int] = None
    stack: List[str] = field(default_factory=list)

    @classmethod
    def new(cls, prefix: str = "t",
            request_id: Optional[int] = None) -> "TraceContext":
        return cls(trace_id=new_trace_id(prefix), request_id=request_id)


@dataclass(frozen=True)
class Timeline:
    """Every recorded span of one trace, sorted by start time."""

    trace_id: str
    spans: List[Span]

    @property
    def t_start(self) -> float:
        return min(s.t_start for s in self.spans) if self.spans else 0.0

    @property
    def t_end(self) -> float:
        return max(s.t_end for s in self.spans) if self.spans else 0.0

    @property
    def wall_s(self) -> float:
        return self.t_end - self.t_start

    def by_component(self, component: str) -> List[Span]:
        return [s for s in self.spans if s.component == component]

    def to_json(self) -> Dict[str, object]:
        return {
            "trace_id": self.trace_id,
            "wall_s": self.wall_s,
            "spans": [s.to_json() for s in self.spans],
        }

    @classmethod
    def from_json(cls, obj: Dict) -> "Timeline":
        return cls(
            trace_id=str(obj["trace_id"]),
            spans=[Span.from_json(s) for s in obj.get("spans", [])],
        )


# --------------------------------------------------------------------------
# enablement
# --------------------------------------------------------------------------

_enabled_override: Optional[bool] = None


def set_enabled(value: Optional[bool]) -> None:
    """Force tracing on/off for this process; ``None`` re-reads the
    ``REPRO_TRACE`` setting."""
    global _enabled_override
    _enabled_override = value


def enabled() -> bool:
    if _enabled_override is not None:
        return _enabled_override
    return settings.get_bool("trace")


def maybe_context(
    prefix: str = "t", request_id: Optional[int] = None
) -> Optional[TraceContext]:
    """A fresh TraceContext when tracing is enabled, else None — the one
    branch every instrumented entry point takes per request."""
    if not enabled():
        return None
    return TraceContext.new(prefix, request_id=request_id)


# --------------------------------------------------------------------------
# the process-local tracer
# --------------------------------------------------------------------------


class Tracer:
    """Thread-safe bounded span collector (one per process via
    :func:`tracer`)."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is None:
            capacity = settings.get_int("trace_buffer") or 8192
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._spans: "deque[Span]" = deque(maxlen=self.capacity)

    def record(self, span: Span) -> Span:
        with self._lock:
            self._spans.append(span)
        return span

    def add(
        self,
        ctx: Optional[TraceContext],
        name: str,
        component: str,
        t_start: float,
        t_end: float,
        **tags: object,
    ) -> Optional[Span]:
        """Record one finished span under ``ctx`` (no-op when ctx is None,
        so call sites never branch)."""
        if ctx is None:
            return None
        return self.record(Span(
            trace_id=ctx.trace_id, name=name, component=component,
            t_start=t_start, t_end=t_end, tags=tags,
        ))

    @contextmanager
    def span(
        self, ctx: Optional[TraceContext], name: str, component: str,
        **tags: object,
    ):
        """Time a block as one span; yields a mutable tag dict so the block
        can attach results (byte counts, worker ids) before close."""
        if ctx is None:
            yield {}
            return
        parent = ctx.stack[-1] if ctx.stack else None
        ctx.stack.append(name)
        live_tags: Dict[str, object] = dict(tags)
        if parent is not None:
            live_tags.setdefault("parent", parent)
        t0 = now()
        try:
            yield live_tags
        finally:
            ctx.stack.pop()
            self.record(Span(
                trace_id=ctx.trace_id, name=name, component=component,
                t_start=t0, t_end=now(), tags=live_tags,
            ))

    def spans(self, *trace_ids: str) -> List[Span]:
        """Every retained span of the given trace ids, in recording order."""
        wanted = set(trace_ids)
        with self._lock:
            return [s for s in self._spans if s.trace_id in wanted]

    def timeline(self, trace_id: str, *linked: str) -> Timeline:
        """The merged timeline of ``trace_id`` plus any linked (carrier)
        traces, sorted by span start."""
        spans = sorted(
            self.spans(trace_id, *linked),
            key=lambda s: (s.t_start, s.t_end),
        )
        return Timeline(trace_id=trace_id, spans=spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)


_tracer: Optional[Tracer] = None
_tracer_lock = threading.Lock()


def tracer() -> Tracer:
    """The process-local tracer (created on first use)."""
    global _tracer
    if _tracer is None:
        with _tracer_lock:
            if _tracer is None:
                _tracer = Tracer()
    return _tracer


def spans_to_wire(spans: Iterable[Span]) -> List[Dict[str, object]]:
    """Compact wire form for piggybacking worker spans on response frames
    (trace_id omitted — the receiver stamps its request's id back on)."""
    return [
        {"name": s.name, "t0": s.t_start, "t1": s.t_end, "tags": dict(s.tags)}
        for s in spans
    ]


def spans_from_wire(
    entries: Iterable[Dict], trace_id: str, component: str = "worker",
    **extra_tags: object,
) -> List[Span]:
    """Inverse of :func:`spans_to_wire`: rebuild spans under the receiving
    request's trace id, folding in receiver-side tags (worker id, share)."""
    out = []
    for e in entries or ():
        tags = dict(e.get("tags", {}))
        tags.update(extra_tags)
        out.append(Span(
            trace_id=trace_id, name=str(e.get("name", "span")),
            component=component, t_start=float(e.get("t0", 0.0)),
            t_end=float(e.get("t1", 0.0)), tags=tags,
        ))
    return out
