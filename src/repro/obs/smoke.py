"""Telemetry-plane smoke: scrape a live pool mid-load, then hedge a
parked straggler and check the bits.

The CI ``metrics-smoke`` job runs this as the merge gate for the live
telemetry plane::

    python -m repro.obs.smoke --workers 4

It spawns a ``--workers``-process LocalPool with the embedded admin
server on an ephemeral port and gates, in order:

1. **mid-load scrape** — with every worker parked and a zero-slack
   request in flight, ``GET /metrics`` must pass the strict exposition
   parser (:func:`repro.obs.parse_prometheus`) and carry one
   ``pool_worker_health{wid=...}`` gauge per worker, ``/healthz`` must
   answer ok, and ``/stats`` must serve the merged JSON snapshot;
2. **hedged straggler** — one worker's compute stays parked on a scheme
   with R == N (every share needed); with ``hedge_factor=2`` the overdue
   share must actually re-ship (``stats.hedged >= 1``), the decode must
   equal the ``A @ B`` oracle bit for bit, and the hedge counters must
   surface in the next ``/stats`` scrape;
3. **trace plane** — a traced request's timeline must come back over
   ``GET /trace/<trace_id>`` in both canonical span JSON and Chrome
   ``trace_event`` form;
4. **dashboard** — ``repro.obs.top --once`` must render a frame from the
   same ``/stats`` endpoint.

Exit code 0 = pass.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
import urllib.request
from typing import Optional

import numpy as np


def _fetch_json(url: str) -> dict:
    with urllib.request.urlopen(url, timeout=10) as resp:
        return json.loads(resp.read().decode())


def run_smoke(
    workers: int = 4,
    size: int = 32,
    delay_ms: float = 400.0,
    seed: int = 0,
) -> int:
    from repro import obs
    from repro.cdmm import ProblemSpec, coded_matmul, plan
    from repro.core import make_ring
    from repro.dist import LocalPool, PoolConfig
    from repro.dist.smoke import _scrape_obs
    from repro.obs import http as obs_http
    from repro.obs import top as obs_top

    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=0,
    )
    # zero slack: the candidate with the LARGEST R (== N), so one parked
    # worker stalls the decode until its share is hedged to a spare
    p = plan(spec, objective="threshold")
    rank = max(range(len(p.candidates)), key=lambda i: p.candidates[i].costs.R)
    scheme = p.instantiate(rank)
    if not (scheme.R == scheme.N == workers):
        print(f"FAIL: no zero-slack scheme at N={workers} "
              f"(got R={scheme.R}, N={scheme.N})")
        return 1
    rng = np.random.default_rng(seed)
    A = Z32.random(rng, (size, size))
    B = Z32.random(rng, (size, size))
    oracle = np.asarray(coded_matmul(A, B, scheme, backend="local"))

    cfg = PoolConfig(workers=workers).with_(obs_http_port=0)
    with LocalPool(config=cfg) as pool:
        master = pool.master
        url = obs_http.server().url
        print(f"pool up: {workers} workers, scheme {scheme.name} "
              f"N={scheme.N} R={scheme.R}, admin plane {url}")

        # warm: jit every worker's matmul, then purge the compile-storm
        # round-trips and re-seed the hedge window at steady state
        master.hedge_factor = 0.0
        for _ in range(3):
            master.execute(scheme, A, B)
        master.health.clear_window()
        for _ in range(2):
            master.execute(scheme, A, B)

        # -- 1. scrape mid-load: all workers parked, request in flight ----
        for wid in master.live_workers():
            master.task_delay_ms[wid] = delay_ms
        result: dict = {}

        def _request():
            try:
                C, result["stats"] = master.execute(scheme, A, B)
                result["C"] = np.asarray(C)
            except Exception as e:
                result["err"] = e

        t = threading.Thread(target=_request)
        t.start()
        time.sleep(delay_ms / 4e3)
        problems = _scrape_obs(url, min_workers=workers)
        stats_doc = _fetch_json(f"{url}/stats")
        for key in ("pool_requests", "pool_workers_live",
                    "pool_worker_health_by_wid"):
            if key not in stats_doc:
                problems.append(f"/stats missing {key}")
        health = stats_doc.get("pool_worker_health_by_wid")
        if isinstance(health, dict) and len(health) < workers:
            problems.append(
                f"/stats has {len(health)} worker health scores, "
                f"expected {workers}"
            )
        if problems:
            for msg in problems:
                print(f"FAIL obs: {msg}")
            return 1
        print(f"mid-load scrape OK: {url}/metrics parsed strictly, "
              f"/healthz ok, /stats has {workers} worker health scores")
        master.task_delay_ms.clear()
        t.join(timeout=120)
        if "err" in result:
            print(f"FAIL: mid-load request raised {result['err']!r}")
            return 1
        if not np.array_equal(result["C"], oracle):
            print("FAIL: mid-load decode != oracle")
            return 1

        # -- 2. hedged straggler: parked share must re-ship and decode ----
        # the all-parked mid-load round-trips (~delay_ms each) dominate
        # the hedge window now; purge and re-seed at steady state so the
        # p95-derived deadline sits well under the injected park
        master.health.clear_window()
        for _ in range(2):
            master.execute(scheme, A, B)
        victim = master.live_workers()[0]
        master.task_delay_ms[victim] = delay_ms
        try:
            master.health.reset_scores()  # round-robin is blind again
            master.hedge_factor = 2.0
            C_hedged, st = master.execute(scheme, A, B)
        finally:
            master.hedge_factor = 0.0
            master.task_delay_ms.pop(victim, None)
        if not np.array_equal(np.asarray(C_hedged), oracle):
            print("FAIL: hedged decode != oracle")
            return 1
        if st.hedged < 1:
            print(f"FAIL: straggler parked {delay_ms} ms but no share "
                  f"was hedged (time_to_R {st.time_to_R_ms:.0f} ms)")
            return 1
        hedged_total = _fetch_json(f"{url}/stats").get("pool_hedged", 0)
        if not hedged_total:
            print("FAIL: /stats pool_hedged still 0 after a hedged race")
            return 1
        print(f"hedged straggler OK: {st.hedged} share(s) re-shipped, "
              f"time-to-R {st.time_to_R_ms:.0f} ms vs {delay_ms:.0f} ms "
              f"park, decode bit-identical")

        # -- 3. trace plane: /trace/<id> in both formats ------------------
        obs.set_enabled(True)
        try:
            ctx = obs.TraceContext.new("obs-smoke")
            C_traced, _ = master.execute(scheme, A, B, trace=ctx)
        finally:
            obs.set_enabled(None)
        if not np.array_equal(np.asarray(C_traced), oracle):
            print("FAIL: traced decode != oracle")
            return 1
        doc = _fetch_json(f"{url}/trace/{ctx.trace_id}")
        if not doc.get("spans"):
            print(f"FAIL: /trace/{ctx.trace_id} returned no spans")
            return 1
        chrome = _fetch_json(f"{url}/trace/{ctx.trace_id}?format=chrome")
        events = chrome.get("traceEvents", chrome)
        if not events:
            print("FAIL: chrome trace export is empty")
            return 1
        print(f"trace plane OK: {len(doc['spans'])} spans over HTTP, "
              f"{len(events)} chrome trace events")

        # -- 4. dashboard: one rendered frame from /stats -----------------
        if obs_top.main(["--url", url, "--once"]) != 0:
            print("FAIL: repro.obs.top --once could not render a frame")
            return 1
    print(f"METRICS SMOKE OK: scrape + hedge + trace + top over {url}")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--delay-ms", type=float, default=400.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_smoke(args.workers, args.size, args.delay_ms, args.seed)


if __name__ == "__main__":
    sys.exit(main())
