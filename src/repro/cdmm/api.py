"""Unified CDMM scheme API: one protocol, one registry, every code.

The paper's value proposition is *choosing the right code* — EP vs
EP_RMFE-I/II vs Batch-EP_RMFE vs GCSA trade recovery threshold, upload,
download and encode/decode work per ring and batch size (Thm III.2,
Table 1).  The legacy classes each grew their own surface
(``EPCode.encode_a/decode``, ``BatchEPRMFE.pack/run``, ``EPRMFE_I.split``,
``CSACode.run``...); this module normalizes all of them behind a single
master/worker protocol so planners, backends, benchmarks and services can
treat any scheme interchangeably:

    encode_a(A, key=None) -> (N, ...)   per-worker A shares (master-side)
    encode_b(B, key=None) -> (N, ...)   per-worker B shares
    encode_a_at(A, i, key=None)         worker i's share only (at-worker)
    encode_b_at(B, i, key=None)
    worker_compute(FA, GB)              vmapped over the leading worker axis
    decode(H, idx)                      recover C from ANY R responses
    costs(spec) -> EPCosts              the analytic Table-1 cost model

``key`` is the masked-randomness seam: secure (T-private) schemes derive
their mask coefficients from it (same key => bit-identical codewords on
every backend), non-secure schemes must tolerate and ignore it.  Every
scheme advertises ``privacy_t`` — the number of colluding workers whose
shares reveal nothing about the inputs (0 for all non-secure families).

Shape convention: schemes with ``batch == 1`` consume a single product
``A (t, r, D0), B (r, s, D0) -> C (t, s, D0)`` over the *data* ring
``scheme.base``; schemes with ``batch == n > 1`` consume a batch
``As (n, t, r, D0), Bs (n, r, s, D0) -> Cs (n, t, s, D0)``.  ``scheme.ring``
is the codeword (extension) ring workers compute in.

Scheme families register via :func:`register_scheme` with an analytic
``predict`` (used by the planner to rank candidates without paying host-side
Vandermonde/RMFE construction) and a ``build`` that instantiates the
executable adapter for the chosen partition.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from math import ceil, gcd, log
from typing import (
    Callable,
    Dict,
    Iterable,
    Optional,
    Protocol,
    Tuple,
    runtime_checkable,
)

import jax
import jax.numpy as jnp

from repro.core.batch_rmfe import BatchEPRMFE
from repro.core.ep_codes import (
    EPCode,
    EPCosts,
    PlainCDMM,
    ep_cost_model,
    smallest_embedding_ext,
)
from repro.core.galois import Ring
from repro.core.gcsa import CSACode, GCSACode, gcsa_cost_model
from repro.core.secure import (
    SecureBatchEPRMFE,
    SecureEP,
    secure_recovery_threshold,
)
from repro.core.single_rmfe import EPRMFE_I, EPRMFE_II

__all__ = [
    "ProblemSpec",
    "CdmmScheme",
    "SchemeFamily",
    "register_scheme",
    "get_scheme",
    "registered_schemes",
    "EPCosts",
    "EPSchemeAdapter",
    "PlainCDMMAdapter",
    "EPRMFE1Adapter",
    "EPRMFE2Adapter",
    "BatchRMFEAdapter",
    "CSAAdapter",
    "GCSAGeneralAdapter",
    "SecureEPAdapter",
    "SecureBatchRMFEAdapter",
]


@dataclass(frozen=True)
class ProblemSpec:
    """One (batch) matrix-multiplication problem to be coded.

    ``n`` products of shape ``(t, r) @ (r, s)`` over the data ring ``ring``,
    distributed over ``N`` workers of which up to ``straggler_budget`` may
    never respond (so the chosen scheme needs R <= N - straggler_budget).
    ``privacy_t > 0`` additionally demands T-collusion privacy: any
    ``privacy_t`` workers' shares must be statistically independent of A and
    B, which restricts the plan to secure scheme families (and raises their
    recovery threshold by the mask interference terms).
    """

    t: int
    r: int
    s: int
    n: int = 1
    ring: Optional[Ring] = None
    N: int = 8
    straggler_budget: int = 0
    privacy_t: int = 0

    def with_batch(self, n: int) -> "ProblemSpec":
        """The same per-request problem at batch arity ``n``.

        This is the coalescing seam: a serving engine that groups ``n``
        concurrent requests of one (t, r, s) shape plans the batched spec
        ``spec.with_batch(n)`` (objective ``"amortized"``) and lets the
        ranking decide whether one RMFE-batch job beats ``n`` single jobs.
        """
        if n < 1:
            raise ValueError(f"batch arity must be >= 1, got {n}")
        from dataclasses import replace

        return replace(self, n=n)

    def validate(self) -> None:
        if self.ring is None:
            raise ValueError("ProblemSpec.ring is required")
        if min(self.t, self.r, self.s, self.n) < 1:
            raise ValueError(f"degenerate problem shape {self}")
        if self.N < 1:
            raise ValueError(f"need at least one worker, got N={self.N}")
        if not 0 <= self.straggler_budget < self.N:
            raise ValueError(
                f"straggler_budget={self.straggler_budget} out of [0, N={self.N})"
            )
        if self.privacy_t < 0:
            raise ValueError(f"privacy_t={self.privacy_t} must be >= 0")
        if self.privacy_t > 0:
            # cheapest secure configuration is u=v=w=1: R = 2T + 1
            min_R = secure_recovery_threshold(1, 1, 1, self.privacy_t)
            if min_R > self.N - self.straggler_budget:
                raise ValueError(
                    f"privacy_t={self.privacy_t} needs recovery threshold "
                    f">= {min_R} but straggler_budget="
                    f"{self.straggler_budget} leaves only "
                    f"N - budget = {self.N - self.straggler_budget} "
                    f"guaranteed responders; raise N or relax the budgets"
                )


@runtime_checkable
class CdmmScheme(Protocol):
    """Uniform master/worker surface every registered scheme adapter exposes."""

    name: str
    N: int
    R: int
    ring: Ring  # codeword (extension) ring
    base: Ring  # data ring
    batch: int  # products consumed per execution (1 = single DMM)
    privacy_t: int  # collusion tolerance (0 = no privacy)

    # ``key`` is optional keyed-encode randomness: secure schemes require it
    # (mask derivation), every other adapter accepts and ignores it
    def encode_a(self, A: jnp.ndarray, key=None) -> jnp.ndarray: ...

    def encode_b(self, B: jnp.ndarray, key=None) -> jnp.ndarray: ...

    # encode-at-worker: worker i's share only (i may be a tracer) — an SPMD
    # shard computes its own codeword instead of materialising all N
    def encode_a_at(self, A: jnp.ndarray, i, key=None) -> jnp.ndarray: ...

    def encode_b_at(self, B: jnp.ndarray, i, key=None) -> jnp.ndarray: ...

    def worker_compute(self, FA: jnp.ndarray, GB: jnp.ndarray) -> jnp.ndarray: ...

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray: ...

    # per-subset decode operator: a jitted closure specialized to one live
    # set, LRU-cached by index tuple — the elastic backend fires it the
    # moment the R-th response lands (no per-call retrace/re-lowering)
    def decode_op(self, idx: Tuple[int, ...]) -> Callable[[jnp.ndarray], jnp.ndarray]: ...

    def costs(self, spec: ProblemSpec) -> EPCosts: ...


class DecodeOpsMixin:
    """Shared ``decode_op`` implementation for every scheme adapter.

    ``decode_op((3, 5, 6))`` returns a jitted decoder for exactly that live
    set: ``dec(H_subset) -> C`` where ``H_subset`` stacks the responses of
    workers 3, 5, 6 in that order.  Operators are LRU-cached per scheme
    instance (key = the live-index tuple) so an elastic master that sees the
    same membership pattern twice pays the Vandermonde-solve trace once.
    """

    DECODE_OP_CACHE_SIZE = 64
    privacy_t = 0  # non-secure default; secure adapters override

    def decode_op(self, idx: Tuple[int, ...]) -> Callable[[jnp.ndarray], jnp.ndarray]:
        idx = tuple(int(i) for i in idx)
        if len(idx) != self.R:
            raise ValueError(
                f"{self.name}: decode_op needs exactly R={self.R} live "
                f"workers, got {len(idx)}"
            )
        if len(set(idx)) != len(idx) or not all(0 <= i < self.N for i in idx):
            raise ValueError(f"{self.name}: invalid live set {idx} for N={self.N}")
        cache = self.__dict__.setdefault("_decode_ops", OrderedDict())
        op = cache.pop(idx, None)
        if op is None:
            iarr = jnp.asarray(idx, dtype=jnp.int32)
            op = jax.jit(lambda H: self.decode(H, iarr))
            while len(cache) >= self.DECODE_OP_CACHE_SIZE:
                cache.popitem(last=False)
        cache[idx] = op  # re-insert = mark most-recently-used
        return op


# ---------------------------------------------------------------------------
# conformance adapters over the legacy scheme classes
# ---------------------------------------------------------------------------


class EPSchemeAdapter(DecodeOpsMixin):
    """Plain EP code: data already lives in a ring with >= N points."""

    name = "ep"

    def __init__(self, ring: Ring, N: int, u: int, v: int, w: int):
        self.code = EPCode(ring, N, u, v, w)
        self.base = ring
        self.ring = ring
        self.N, self.R, self.batch = N, self.code.R, 1
        self.partition = (u, v, w)

    def encode_a(self, A, key=None):
        return self.code.encode_a(A)

    def encode_b(self, B, key=None):
        return self.code.encode_b(B)

    def encode_a_at(self, A, i, key=None):
        return self.code.encode_a_at(A, i)

    def encode_b_at(self, B, i, key=None):
        return self.code.encode_b_at(B, i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.code.decode(H, idx)

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.code.costs(spec.t, spec.r, spec.s, self.base)


class PlainCDMMAdapter(DecodeOpsMixin):
    """Lemma III.1 baseline: embed the base ring into an extension, run EP."""

    name = "plain"

    def __init__(self, base: Ring, N: int, u: int, v: int, w: int):
        self.inner = PlainCDMM(base, N, u, v, w)
        self.code = self.inner.code
        self.base = base
        self.ring = self.inner.ext
        self.N, self.R, self.batch = N, self.inner.R, 1
        self.partition = (u, v, w)

    def encode_a(self, A, key=None):
        return self.code.encode_a(self.ring.embed_base(A, self.base))

    def encode_b(self, B, key=None):
        return self.code.encode_b(self.ring.embed_base(B, self.base))

    def encode_a_at(self, A, i, key=None):
        return self.code.encode_a_at(self.ring.embed_base(A, self.base), i)

    def encode_b_at(self, B, i, key=None):
        return self.code.encode_b_at(self.ring.embed_base(B, self.base), i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        # products of embedded elements stay in the embedded base ring
        return self.code.decode(H, idx)[..., : self.base.D]

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.inner.costs(spec.t, spec.r, spec.s)


class EPRMFE1Adapter(DecodeOpsMixin):
    """EP_RMFE-I (Cor IV.1): MatDot-style split of r into n RMFE-packed
    sub-products; decode sums them back into one C."""

    name = "ep_rmfe1"

    def __init__(self, base: Ring, n: int, N: int, u: int, v: int, w: int):
        self.inner = EPRMFE_I(base, n, N, u, v, w)
        self.code = self.inner.code
        self.base, self.n = base, n
        self.ring = self.inner.ext
        self.N, self.R, self.batch = N, self.inner.R, 1
        self.partition = (u, v, w)

    def _pack_a(self, A):
        return self.inner.batch.pack(self.inner.split_a(A))

    def _pack_b(self, B):
        return self.inner.batch.pack(self.inner.split_b(B))

    def encode_a(self, A, key=None):
        return self.code.encode_a(self._pack_a(A))

    def encode_b(self, B, key=None):
        return self.code.encode_b(self._pack_b(B))

    def encode_a_at(self, A, i, key=None):
        return self.code.encode_a_at(self._pack_a(A), i)

    def encode_b_at(self, B, i, key=None):
        return self.code.encode_b_at(self._pack_b(B), i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        Cs = self.inner.batch.decode(H, idx)  # (n, t, s, D0)
        acc = Cs[0]
        for i in range(1, self.n):
            acc = self.base.add(acc, Cs[i])
        return acc

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.inner.costs(spec.t, spec.r, spec.s)


class EPRMFE2Adapter(DecodeOpsMixin):
    """EP_RMFE-II (Cor IV.2), in the paper's measured §V configuration:
    B column-split and packed through phi_1, A embedded (split_a=False)."""

    name = "ep_rmfe2"

    def __init__(
        self, base: Ring, n: int, N: int, u: int, v: int, w: int,
        split_a: bool = False,
    ):
        self.inner = EPRMFE_II(base, n, N, u, v, w, split_a=split_a)
        self.code = self.inner.code
        self.base, self.n = base, n
        self.ring = self.inner.top
        self.N, self.R, self.batch = N, self.inner.R, 1
        self.partition = (u, v, w)

    def encode_a(self, A, key=None):
        return self.code.encode_a(self.inner.pack_a(A))

    def encode_b(self, B, key=None):
        return self.code.encode_b(self.inner.pack_b(B))

    def encode_a_at(self, A, i, key=None):
        return self.code.encode_a_at(self.inner.pack_a(A), i)

    def encode_b_at(self, B, i, key=None):
        return self.code.encode_b_at(self.inner.pack_b(B), i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.inner.unpack(self.code.decode(H, idx))

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.inner.costs(spec.t, spec.r, spec.s)


class BatchRMFEAdapter(DecodeOpsMixin):
    """Batch-EP_RMFE (Thm III.2): n products packed positionwise into one
    extension-ring product."""

    name = "batch_ep_rmfe"

    def __init__(self, base: Ring, n: int, N: int, u: int, v: int, w: int):
        self.inner = BatchEPRMFE(base, n, N, u, v, w)
        self.code = self.inner.code
        self.base = base
        self.ring = self.inner.ext
        self.N, self.R = N, self.inner.R
        self.batch = self.inner.rmfe.n  # actual packed batch (>= requested n)
        self.partition = (u, v, w)

    def encode_a(self, As, key=None):
        return self.code.encode_a(self.inner.pack(As))

    def encode_b(self, Bs, key=None):
        return self.code.encode_b(self.inner.pack(Bs))

    def encode_a_at(self, As, i, key=None):
        return self.code.encode_a_at(self.inner.pack(As), i)

    def encode_b_at(self, Bs, i, key=None):
        return self.code.encode_b_at(self.inner.pack(Bs), i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.inner.decode(H, idx)

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.inner.costs(spec.t, spec.r, spec.s)


class CSAAdapter(DecodeOpsMixin):
    """Executable GCSA point (u=v=w=1, kappa=n): the CSA batch code, run
    over the smallest embedding extension with >= n + N exceptional points."""

    name = "gcsa"

    def __init__(self, base: Ring, n: int, N: int):
        ext = smallest_embedding_ext(base, n + N)
        self.base, self.ring = base, ext
        self.code = CSACode(ext, L=n, N=N)
        self.N, self.R, self.batch = N, self.code.R, n
        self.partition = (1, 1, 1)

    def encode_a(self, As, key=None):
        return self.code.encode_a(self.ring.embed_base(As, self.base))

    def encode_b(self, Bs, key=None):
        return self.code.encode_b(self.ring.embed_base(Bs, self.base))

    def encode_a_at(self, As, i, key=None):
        return self.code.encode_a_at(self.ring.embed_base(As, self.base), i)

    def encode_b_at(self, Bs, i, key=None):
        return self.code.encode_b_at(self.ring.embed_base(Bs, self.base), i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.code.decode(H, idx)[..., : self.base.D]

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.code.costs(spec)


class GCSAGeneralAdapter(DecodeOpsMixin):
    """Executable general-(u, v, w, kappa) GCSA: EP inner partitioning
    composed with the CSA outer Cauchy structure over kappa-grouped
    batches, run over the smallest embedding extension with >= n + N
    exceptional points.  R = uvw(n + kappa - 1) + w - 1.

    The registry's packing slot carries kappa (any divisor of the batch),
    so the planner sweeps group sizes the same way it sweeps RMFE packing
    factors — kappa = n is the CSA communication-optimal end, kappa = 1
    the per-product-poles end."""

    name = "gcsa_general"

    def __init__(
        self, base: Ring, n: int, N: int, u: int, v: int, w: int, kappa: int
    ):
        ext = smallest_embedding_ext(base, n + N)
        self.base, self.ring = base, ext
        self.code = GCSACode(ext, L=n, N=N, u=u, v=v, w=w, kappa=kappa)
        self.N, self.R, self.batch = N, self.code.R, n
        self.partition = (u, v, w)
        self.kappa = kappa

    def encode_a(self, As, key=None):
        return self.code.encode_a(self.ring.embed_base(As, self.base))

    def encode_b(self, Bs, key=None):
        return self.code.encode_b(self.ring.embed_base(Bs, self.base))

    def encode_a_at(self, As, i, key=None):
        return self.code.encode_a_at(self.ring.embed_base(As, self.base), i)

    def encode_b_at(self, Bs, i, key=None):
        return self.code.encode_b_at(self.ring.embed_base(Bs, self.base), i)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.code.decode(H, idx)[..., : self.base.D]

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.code.costs(spec)


class SecureEPAdapter(DecodeOpsMixin):
    """T-private EP code (secure single DMM): the base ring is embedded into
    the smallest extension with >= N + 1 exceptional points and a masked EP
    code runs there.  ``encode_*`` REQUIRE a jax.random key."""

    name = "ep_secure"

    def __init__(self, base: Ring, N: int, u: int, v: int, w: int, T: int):
        self.inner = SecureEP(base, N, u, v, w, T)
        self.code = self.inner.code
        self.base = base
        self.ring = self.inner.ext
        self.N, self.R, self.batch = N, self.inner.R, 1
        self.privacy_t = T
        self.partition = (u, v, w)

    def encode_a(self, A, key=None):
        return self.code.encode_a(self.inner.embed(A), key=key)

    def encode_b(self, B, key=None):
        return self.code.encode_b(self.inner.embed(B), key=key)

    def encode_a_at(self, A, i, key=None):
        return self.code.encode_a_at(self.inner.embed(A), i, key=key)

    def encode_b_at(self, B, i, key=None):
        return self.code.encode_b_at(self.inner.embed(B), i, key=key)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.inner.decode(H, idx)

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.inner.costs(spec.t, spec.r, spec.s)


class SecureBatchRMFEAdapter(DecodeOpsMixin):
    """T-private Batch-EP_RMFE (secure batch DMM): n products RMFE-packed
    into one extension-ring product, computed by a masked EP code whose
    extension carries >= N + 1 exceptional points."""

    name = "ep_rmfe_secure"

    def __init__(
        self, base: Ring, n: int, N: int, u: int, v: int, w: int, T: int
    ):
        self.inner = SecureBatchEPRMFE(base, n, N, u, v, w, T)
        self.code = self.inner.code
        self.base = base
        self.ring = self.inner.ext
        self.N, self.R = N, self.inner.R
        self.batch = self.inner.rmfe.n  # actual packed batch (>= requested n)
        self.privacy_t = T
        self.partition = (u, v, w)

    def encode_a(self, As, key=None):
        return self.code.encode_a(self.inner.pack(As), key=key)

    def encode_b(self, Bs, key=None):
        return self.code.encode_b(self.inner.pack(Bs), key=key)

    def encode_a_at(self, As, i, key=None):
        return self.code.encode_a_at(self.inner.pack(As), i, key=key)

    def encode_b_at(self, Bs, i, key=None):
        return self.code.encode_b_at(self.inner.pack(Bs), i, key=key)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.inner.decode(H, idx)

    def costs(self, spec: ProblemSpec) -> EPCosts:
        return self.inner.costs(spec.t, spec.r, spec.s)


# ---------------------------------------------------------------------------
# analytic feasibility / cost prediction (no host-side construction)
# ---------------------------------------------------------------------------


def _coprime_bump(m: int, D0: int) -> int:
    """Mirror Ring.extend: smallest m' >= m with gcd(m', D0) == 1."""
    while gcd(m, D0) != 1:
        m += 1
    return m


def _embed_ext_D(p: int, D0: int, npoints: int) -> int:
    """Tower degree of the smallest embedding extension with >= npoints
    exceptional points (analytic mirror of ``smallest_embedding_ext``)."""
    if p**D0 >= npoints:
        return D0
    m = 1
    while p ** (D0 * m) < npoints:
        m += 1
    D = D0 * _coprime_bump(m, D0)
    while p**D < npoints:
        m += 1
        D = D0 * _coprime_bump(m, D0)
    return D


def _rmfe_ext_D(p: int, D0: int, n: int, min_m: int):
    """(tower degree, actual packed batch) of build_rmfe(base, n, min_m)."""
    T = p**D0
    if n <= T:
        return D0 * _coprime_bump(max(2 * n - 1, min_m, 2), D0), n
    n2 = T
    n1 = -(-n // n2)
    midD = D0 * _coprime_bump(max(2 * n2 - 1, 2), D0)
    return midD * _coprime_bump(max(2 * n1 - 1, 2), midD), n1 * n2


def _min_m_for_points(p: int, D0: int, N: int) -> int:
    return ceil(log(max(N, 2)) / (log(p) * D0))


def _predict_ep(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    if n != 1 or p**D0 < spec.N:
        return None
    if spec.t % u or spec.r % w or spec.s % v:
        return None
    return ep_cost_model(spec.t, spec.r, spec.s, u, v, w, spec.N, m_eff=1.0)


def _predict_plain(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    if n != 1:
        return None
    if spec.t % u or spec.r % w or spec.s % v:
        return None
    m_eff = _embed_ext_D(p, D0, spec.N) / D0
    return ep_cost_model(spec.t, spec.r, spec.s, u, v, w, spec.N, m_eff=m_eff)


def _predict_rmfe1(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    if n < 2 or spec.r % n:
        return None
    rb = spec.r // n
    if spec.t % u or rb % w or spec.s % v:
        return None
    extD, actual = _rmfe_ext_D(p, D0, n, _min_m_for_points(p, D0, spec.N))
    if actual != n or p**extD < spec.N:
        return None
    # one EP run on (t, r/n, s): the r-shrink carries the 1/n saving
    return ep_cost_model(spec.t, rb, spec.s, u, v, w, spec.N, extD / D0)


def _predict_rmfe2(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    # split_a=False configuration: level-1 RMFE needs n <= |T(base)|
    if n < 2 or n > p**D0 or spec.s % n:
        return None
    sb = spec.s // n
    if spec.t % u or spec.r % w or sb % v:
        return None
    min_m = _min_m_for_points(p, D0, spec.N)
    midD = D0 * _coprime_bump(max(2 * n - 1, min_m, 2), D0)
    if p**midD < spec.N:
        return None
    return ep_cost_model(spec.t, spec.r, sb, u, v, w, spec.N, midD / D0)


def _predict_batch(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    if n != spec.n:
        return None
    if spec.t % u or spec.r % w or spec.s % v:
        return None
    extD, actual = _rmfe_ext_D(p, D0, n, _min_m_for_points(p, D0, spec.N))
    if actual != n or p**extD < spec.N:
        return None
    return ep_cost_model(
        spec.t, spec.r, spec.s, u, v, w, spec.N, extD / D0, batch=n
    )


def _predict_gcsa(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    # executable CSA point: (u, v, w) = (1, 1, 1), kappa = n — the GCSA
    # configuration with the family's best communication costs
    if (u, v, w) != (1, 1, 1) or n != spec.n:
        return None
    m_eff = _embed_ext_D(p, D0, spec.N + n) / D0
    return gcsa_cost_model(spec.t, spec.r, spec.s, 1, 1, 1, n, n, spec.N, m_eff)


def _gcsa_packings(spec: ProblemSpec) -> Tuple[int, ...]:
    """Packing candidates for gcsa_general: the group size kappa, any
    divisor of the batch (kappa = n recovers the CSA point)."""
    return tuple(d for d in range(1, spec.n + 1) if spec.n % d == 0)


def _predict_gcsa_general(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    kappa = n  # the packing slot carries the group size
    if spec.n < 2 or kappa < 1 or spec.n % kappa:
        return None
    if spec.t % u or spec.r % w or spec.s % v:
        return None
    m_eff = _embed_ext_D(p, D0, spec.N + spec.n) / D0
    return gcsa_cost_model(
        spec.t, spec.r, spec.s, u, v, w, spec.n, kappa, spec.N, m_eff
    )


def _predict_ep_secure(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    T = spec.privacy_t
    if T < 1 or n != 1:
        return None  # secure families only serve privacy_t >= 1 specs
    if spec.t % u or spec.r % w or spec.s % v:
        return None
    # evaluation skips the zero point, so the embedding needs N + 1 points
    m_eff = _embed_ext_D(p, D0, spec.N + 1) / D0
    return ep_cost_model(
        spec.t, spec.r, spec.s, u, v, w, spec.N, m_eff, privacy_t=T
    )


def _predict_rmfe_secure(spec: ProblemSpec, u, v, w, n) -> Optional[EPCosts]:
    p, D0 = spec.ring.p, spec.ring.D
    T = spec.privacy_t
    if T < 1 or n != spec.n:
        return None
    if spec.t % u or spec.r % w or spec.s % v:
        return None
    extD, actual = _rmfe_ext_D(p, D0, n, _min_m_for_points(p, D0, spec.N + 1))
    if actual != n or p**extD < spec.N + 1:
        return None
    return ep_cost_model(
        spec.t, spec.r, spec.s, u, v, w, spec.N, extD / D0, batch=n,
        privacy_t=T,
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SchemeFamily:
    """A registered scheme family.

    ``batched`` families consume ``spec.n`` products per execution; single
    families consume one product (their ``n`` is an internal packing factor).
    ``predict(spec, u, v, w, n)`` returns the analytic EPCosts or None when
    the configuration is infeasible; ``build`` constructs the executable
    adapter for a feasible configuration.

    ``packing`` (optional) enumerates the family's candidate values for the
    4th build/predict parameter given a spec.  When absent the planner uses
    its defaults: ``(spec.n,)`` for batched families, divisors of the
    operand dimensions for single families.  Batched families whose 4th
    parameter is NOT the batch size (gcsa_general reads it as the group
    size kappa) must supply it.
    """

    name: str
    batched: bool
    build: Callable[[ProblemSpec, int, int, int, int], CdmmScheme]
    predict: Callable[[ProblemSpec, int, int, int, int], Optional[EPCosts]]
    packing: Optional[Callable[[ProblemSpec], Iterable[int]]] = None


_REGISTRY: Dict[str, SchemeFamily] = {}


def register_scheme(family: SchemeFamily) -> SchemeFamily:
    _REGISTRY[family.name] = family
    return family


def get_scheme(name: str) -> SchemeFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scheme {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_schemes() -> Dict[str, SchemeFamily]:
    return dict(_REGISTRY)


register_scheme(SchemeFamily(
    "ep", False,
    lambda spec, u, v, w, n: EPSchemeAdapter(spec.ring, spec.N, u, v, w),
    _predict_ep,
))
register_scheme(SchemeFamily(
    "plain", False,
    lambda spec, u, v, w, n: PlainCDMMAdapter(spec.ring, spec.N, u, v, w),
    _predict_plain,
))
register_scheme(SchemeFamily(
    "ep_rmfe1", False,
    lambda spec, u, v, w, n: EPRMFE1Adapter(spec.ring, n, spec.N, u, v, w),
    _predict_rmfe1,
))
register_scheme(SchemeFamily(
    "ep_rmfe2", False,
    lambda spec, u, v, w, n: EPRMFE2Adapter(spec.ring, n, spec.N, u, v, w),
    _predict_rmfe2,
))
register_scheme(SchemeFamily(
    "batch_ep_rmfe", True,
    lambda spec, u, v, w, n: BatchRMFEAdapter(spec.ring, n, spec.N, u, v, w),
    _predict_batch,
))
register_scheme(SchemeFamily(
    "gcsa", True,
    lambda spec, u, v, w, n: CSAAdapter(spec.ring, n, spec.N),
    _predict_gcsa,
))
register_scheme(SchemeFamily(
    "gcsa_general", True,
    lambda spec, u, v, w, n: GCSAGeneralAdapter(
        spec.ring, spec.n, spec.N, u, v, w, n
    ),
    _predict_gcsa_general,
    packing=_gcsa_packings,
))
register_scheme(SchemeFamily(
    "ep_secure", False,
    lambda spec, u, v, w, n: SecureEPAdapter(
        spec.ring, spec.N, u, v, w, spec.privacy_t
    ),
    _predict_ep_secure,
))
register_scheme(SchemeFamily(
    "ep_rmfe_secure", True,
    lambda spec, u, v, w, n: SecureBatchRMFEAdapter(
        spec.ring, n, spec.N, u, v, w, spec.privacy_t
    ),
    _predict_rmfe_secure,
))
