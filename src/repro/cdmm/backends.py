"""Pluggable execution backends behind one entry point: ``coded_matmul``.

Every backend runs the same four-stage protocol against the unified
:class:`~repro.cdmm.api.CdmmScheme` surface — encode, worker compute,
response gather, any-R decode — so a Plan chosen by the planner executes
identically everywhere.  Because every registered scheme is integer-exact,
all three backends are bit-identical; they differ only in *when* the master
gets its answer:

===========  ===========================  ======================  ==============
backend      execution model              completion time         when to use
===========  ===========================  ======================  ==============
local        all N workers vmapped in     one XLA program (no     tests, small
             one process; straggler       straggler savings —     problems, any
             mask applied at decode       everyone computes)      machine
shard_map    SPMD over a mesh axis, one   barrier: all-gather     real meshes /
             device per worker; encode-   waits for the slowest   multi-device
             at-worker, all-gather,       of the N shards         runs
             decode from first R live
elastic      event-driven master loop     R-th fastest response:  straggler-y or
             (``repro.cdmm.elastic``);    stragglers are raced    elastic worker
             threaded per-worker          past, late joiners      pools; batch
             dispatch, decode fires on    admitted, leavers       streams that
             the R-th response            tolerated up to N - R   rescale
===========  ===========================  ======================  ==============

Determinism: ``local`` and ``shard_map`` always decode from the *first R
live* workers (stable order), so repeated calls are bitwise-reproducible.
``elastic`` decodes from the first R *arrivals* — a different-but-valid
subset per run under a randomized trace — and still returns the same bits,
because the any-R decode is exact for every subset (that invariant is
property-tested in tests/test_elastic.py).

All shard_map calls route through the ``repro.compat`` shim.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.straggler import select_workers
from repro.kernels import gr_matmul, kernel_auto_enabled, kernel_supported

from .api import CdmmScheme
from .planner import Plan

__all__ = [
    "LocalSimBackend",
    "ShardMapBackend",
    "shard_worker_body",
    "coded_matmul",
    "get_backend",
    "register_backend",
    "live_indices",
    "encode_all",
    "decode_from",
]


# --------------------------------------------------------------------------
# shared protocol helpers (used by every backend, incl. cdmm.elastic)
# --------------------------------------------------------------------------


def live_indices(scheme: CdmmScheme, mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """First-R live worker indices under ``mask`` (all-live when None)."""
    if mask is None:
        return jnp.arange(scheme.R, dtype=jnp.int32)
    return select_workers(mask, scheme.R)


def encode_all(
    scheme: CdmmScheme,
    A: jnp.ndarray,
    B: jnp.ndarray,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Master-side encode of both operands: (N, ...) share stacks.

    ``key`` is the masked-randomness seam for secure schemes (they derive
    independent A/B-side masks from it internally); non-secure schemes
    ignore it.
    """
    return scheme.encode_a(A, key=key), scheme.encode_b(B, key=key)


def decode_from(
    scheme: CdmmScheme, H: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Any-R decode from the responses of workers ``idx`` (rows of ``H``
    indexed by worker, i.e. the full (N, ...) response stack)."""
    return scheme.decode(jnp.take(H, idx, axis=0), idx)


class LocalSimBackend:
    """Simulate all N workers locally (vmapped); decode from the first R
    responsive workers under ``mask``."""

    name = "local"

    def __call__(
        self,
        scheme: CdmmScheme,
        A: jnp.ndarray,
        B: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        # same span schema as the pool path (repro.obs), so a "local"
        # trace reads like a pool trace with one worker lane per share
        from repro.obs import trace as obs

        ctx = obs.maybe_context("local")
        tracer = obs.tracer()
        with tracer.span(ctx, "encode", "local", scheme=scheme.name):
            FA, GB = encode_all(scheme, A, B, key=key)
        with tracer.span(ctx, "compute", "local", N=int(scheme.N)):
            H = scheme.worker_compute(FA, GB)
        with tracer.span(ctx, "decode", "local", scheme=scheme.name):
            return decode_from(scheme, H, live_indices(scheme, mask))


def shard_worker_body(
    scheme: CdmmScheme,
    axis: str,
    A: jnp.ndarray,
    B: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    use_kernel: Optional[bool] = None,
    key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Per-shard master/worker protocol: call inside shard_map over ``axis``
    with all operands replicated.

    Each shard encodes only its own codeword pair (encode-at-worker: the
    broadcast-blocks upload model — no shard materialises all N shares),
    computes the local block product (Pallas kernel when supported), then
    all-gathers responses and decodes from the first R live workers.
    ``use_kernel=None`` auto-enables the kernel whenever it would actually
    compile for the scheme's ring (``kernel_auto_enabled``); True forces it
    (interpret mode on CPU), False pins the XLA reference.
    ``key`` (replicated) feeds every shard the SAME mask randomness, so the
    secure codeword polynomial is consistent across workers.
    """
    if use_kernel is None:
        use_kernel = kernel_auto_enabled(scheme.ring)
    i = lax.axis_index(axis)
    fa = scheme.encode_a_at(A, i, key=key)
    gb = scheme.encode_b_at(B, i, key=key)
    if use_kernel and kernel_supported(scheme.ring):
        h = gr_matmul(fa, gb, scheme.ring)
    else:
        h = scheme.worker_compute(fa[None], gb[None])[0]
    H = lax.all_gather(h, axis)  # (N, ...)
    idx = select_workers(mask, scheme.R)
    return scheme.decode(jnp.take(H, idx, axis=0), idx)


class ShardMapBackend:
    """Run the protocol SPMD over a mesh axis with one device per worker."""

    name = "shard_map"

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        axis: str = "workers",
        use_kernel: Optional[bool] = None,
    ):
        # None = auto: tuned Pallas kernel wherever it compiles for the
        # scheme's ring (see shard_worker_body)
        self.mesh, self.axis, self.use_kernel = mesh, axis, use_kernel

    def _mesh_for(self, N: int) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        devs = jax.devices()
        if len(devs) < N:
            raise ValueError(
                f"ShardMapBackend needs {N} devices for N={N} workers, "
                f"have {len(devs)} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={N} to simulate)"
            )
        return Mesh(np.array(devs[:N]).reshape(N), (self.axis,))

    def __call__(
        self,
        scheme: CdmmScheme,
        A: jnp.ndarray,
        B: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        mesh = self._mesh_for(scheme.N)
        if mask is None:
            mask = jnp.ones(scheme.N, dtype=bool)
        spec = P()  # CDMM redundancy is in the computation: operands replicated
        # the key rides in as a closure constant, replicated to every shard
        f = shard_map(
            lambda a, b, m: shard_worker_body(
                scheme, self.axis, a, b, m,
                use_kernel=self.use_kernel, key=key,
            ),
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=spec,
            check=False,
        )
        return f(A, B, mask)


_BACKENDS: dict = {
    "local": LocalSimBackend,
    "shard_map": ShardMapBackend,
}

# backends registered by modules that are deliberately not imported at
# repro.cdmm import time: name -> module whose import registers it.  "pool"
# spawns threads/subprocess machinery, so it only loads on first use —
# which is what keeps coded_matmul(..., backend="pool") a one-line switch
# without a mandatory `import repro.dist`.
_LAZY_BACKENDS: dict = {
    "pool": "repro.dist",
}


def register_backend(name: str, factory: Callable[[], object]) -> None:
    """Register a backend factory under ``name`` (used by coded_matmul)."""
    _BACKENDS[name] = factory


def get_backend(backend: Union[None, str, object]):
    """Normalize a backend argument: instance, name, or None (local)."""
    if backend is None:
        return LocalSimBackend()
    if isinstance(backend, str):
        if backend not in _BACKENDS and backend in _LAZY_BACKENDS:
            import importlib

            importlib.import_module(_LAZY_BACKENDS[backend])
        try:
            return _BACKENDS[backend]()
        except KeyError:
            raise ValueError(
                f"unknown backend {backend!r}; one of "
                f"{sorted(set(_BACKENDS) | set(_LAZY_BACKENDS))}"
            ) from None
    return backend


def coded_matmul(
    A: jnp.ndarray,
    B: jnp.ndarray,
    plan: Union[Plan, CdmmScheme],
    *,
    backend: Union[None, str, object] = None,
    mask: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    pool_config=None,
) -> jnp.ndarray:
    """Execute a planned coded matmul: ``C = A @ B`` over ``plan.spec.ring``.

    ``plan`` is a :class:`Plan` from :func:`repro.cdmm.planner.plan` (its
    best candidate is instantiated and memoized) or an already-built scheme.
    Shapes follow the scheme's arity: single schemes take ``(t, r, D0)`` x
    ``(r, s, D0)``; batch schemes take ``(n, t, r, D0)`` x ``(n, r, s, D0)``.
    ``mask`` is an (N,)-bool liveness vector; dead workers' responses are
    provably never read by the any-R decode.

    ``key`` is a ``jax.random`` key feeding the masked-randomness seam of
    secure (``privacy_t > 0``) schemes — REQUIRED for them, ignored by the
    rest.  The same key yields bit-identical codewords (hence decodes) on
    every backend; privacy requires a fresh key per call.

    ``pool_config`` (a :class:`repro.dist.PoolConfig`) shapes the worker
    pool when ``backend="pool"``: worker count/hostfile, wire codec and
    compression, streaming chunk size, timeouts.  The pool it implies is
    brought up for this call and torn down after — callers that issue many
    requests should build a ``PoolBackend(config=...)`` (or a pool +
    ``PoolBackend(pool)``) once and pass it as ``backend`` instead.
    """
    scheme = plan.instantiate() if isinstance(plan, Plan) else plan
    if pool_config is not None:
        if not (backend is None or backend == "pool"):
            raise ValueError(
                f"pool_config= only applies to backend='pool', "
                f"got backend={backend!r}"
            )
        from repro.dist import PoolBackend

        be = PoolBackend(config=pool_config)
        try:
            if key is None:
                return be(scheme, A, B, mask)
            return be(scheme, A, B, mask, key=key)
        finally:
            be.close()
    be = get_backend(backend)
    if key is None:
        # keep the pre-keyed-encode 4-argument backend protocol working:
        # externally registered backends that never learned ``key=`` still
        # serve every non-secure call
        return be(scheme, A, B, mask)
    return be(scheme, A, B, mask, key=key)
