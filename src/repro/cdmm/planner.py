"""Cost-model planner: enumerate registered schemes x partitions, rank them.

``plan(spec, objective)`` walks every registered scheme family and every
valid EP partition (u, v, w) admitted by the straggler budget (the caps are
lossless: R = uvw + w - 1 bounds u, v by R and w by (R+1)/2), plus RMFE
packing factors n <= MAX_PACKING for the single-DMM variants, scores the
analytic cost models, and returns a ranked :class:`Plan`.  Candidate enumeration never constructs a scheme — the
``predict`` hooks are pure arithmetic — so planning is cheap even for large
worker counts; only ``Plan.instantiate()`` pays the host-side Vandermonde /
RMFE precompute, for the one configuration actually chosen.

Objectives:
  * ``"threshold"`` — minimize the recovery threshold R (maximize straggler
    tolerance at fixed N),
  * ``"download"``  — minimize master download volume (Table 1's headline:
    Batch-EP_RMFE beats GCSA by ~1/n here),
  * ``"upload"``    — minimize master upload volume,
  * ``"latency"``   — minimize predicted wall time.  With a fitted
    calibration (``repro.cdmm.calibrate``; the committed
    ``benchmarks/calibration.json`` loads automatically) the score is
    measured us-per-op coefficients times the cost-model terms; without
    one it falls back to the historical op-count proxy
    (encode + worker + decode ops + upload + download elements),
  * ``"amortized"``  — minimize predicted *per-request* cost at batch fill:
    the latency score, but compared ACROSS batch arities.  For a spec with
    n > 1 the candidate set contains both the batch families (whose
    Table-1 costs are already amortized over the n products one coded job
    carries) and the single-DMM families (priced at one full execution per
    request, i.e. n sequential jobs serve the batch).  This is the serving
    objective: ``repro.serve`` plans the coalesced batch spec at the
    expected concurrency and the ranking decides whether coalescing into
    one RMFE-batch job beats dispatching single-EP jobs per request —
    e.g. over Z_{2^32} the extension forced by the exceptional-point
    shortage doubles as RMFE packing space, so ``batch_ep_rmfe`` with
    n = 2 rides the embedding the single schemes pay anyway and wins;
    at n = 4 the two-level RMFE tower outgrows the saving and the
    single families win back.  NOTE: when a single family wins, the
    planned scheme consumes ONE product per execution — callers that
    batched their operands must dispatch per request (the coalescing
    engine does exactly that),
  * ``"time_to_R"`` — minimize expected completion under the straggler
    latency model (``core.straggler.straggler_latencies``): the elastic
    backend finishes at the R-th fastest response, so the score is the
    Monte-Carlo mean of the R-th order statistic of N heavy-tailed worker
    latencies, with a log-compressed serial-work epsilon tie-break —
    grounded in the calibrated serial master work (encode + decode +
    communication, measured us) when a calibration is loaded, in raw op
    counts otherwise.  The order statistic stays the leading term either
    way: the synthetic straggler clock and the measured machine clock are
    different axes, so the measured term never outvotes resilience.

``plan(..., calibration=...)`` pins an explicit
:class:`~repro.cdmm.calibrate.CalibrationSet` (or ``False`` to force the
analytic proxy); ``backend`` names which backend's coefficients score the
candidates.  Set ``REPRO_CALIBRATION=off`` to disable auto-loading
globally (deterministic CI tiers).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from math import log1p
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.ep_codes import EPCosts

from .api import CdmmScheme, ProblemSpec, get_scheme, registered_schemes
from .calibrate import (
    COEF_NAMES,
    Calibration,
    CalibrationSet,
    load_calibration,
)

__all__ = ["plan", "Plan", "PlanCandidate", "OBJECTIVES", "expected_time_to_R"]


_LATENCY_TRIALS = 256


@lru_cache(maxsize=32)
def _sorted_latency_sample(N: int) -> np.ndarray:
    """(trials, N) rows of sorted straggler latencies, fixed seed (the
    planner must be deterministic run to run)."""
    import jax  # deferred: keep planner importable without jax init cost

    from repro.core.straggler import straggler_latencies

    keys = jax.random.split(jax.random.PRNGKey(0), _LATENCY_TRIALS)
    lat = jax.vmap(lambda k: straggler_latencies(k, N))(keys)
    return np.sort(np.asarray(lat, dtype=float), axis=1)


def expected_time_to_R(N: int, R: int) -> float:
    """E[R-th order statistic of N worker latencies] in model-ms: the
    expected wall-clock at which an elastic master can decode."""
    return float(_sorted_latency_sample(N)[:, R - 1].mean())


OBJECTIVES: Dict[str, callable] = {
    "threshold": lambda c: float(c.R),
    "download": lambda c: c.download,
    "upload": lambda c: c.upload,
    "latency": lambda c: (
        c.encode_ops + c.worker_ops + c.decode_ops + c.upload + c.download
    ),
    # per-request cost at batch fill: the cost models of batch families are
    # already amortized over the n products one execution carries, and the
    # single families keep their one-request-per-execution costs — the same
    # proxy therefore compares "one coalesced RMFE-batch job" against "n
    # sequential single-EP jobs" per request served (see module docstring)
    "amortized": lambda c: (
        c.encode_ops + c.worker_ops + c.decode_ops + c.upload + c.download
    ),
    # expected elastic completion; serial-work proxy breaks ties among
    # configurations with equal (N, R).  The tie-break is log-compressed so
    # it stays orders of magnitude below any E[t_R] gap even for huge
    # problems (log1p(1e12 ops) * 1e-6 ~ 3e-5 model-ms) while remaining
    # monotone in the serial work
    "time_to_R": lambda c: (
        expected_time_to_R(c.N, c.R)
        + 1e-6 * log1p(c.encode_ops + c.decode_ops + c.upload + c.download)
    ),
}

# objectives whose analytic form is replaced by measured coefficients when a
# calibration is available (the rest are pure counts — already exact)
_CALIBRATED_OBJECTIVES = ("latency", "time_to_R", "amortized")


def _calibrated_score_fn(objective: str, cal: Calibration):
    """Measured-wall-time score for one objective, or None to keep the
    analytic proxy (calibration carries no useful coefficients)."""
    if not cal.coef:
        return None
    if objective in ("latency", "amortized"):
        # amortized candidates carry per-request cost terms (batch families
        # divide by their fill), so the same measured us-per-op fit prices
        # them directly as us per request served
        return cal.predict_us
    if objective == "time_to_R":
        # E[t_R] is in *model*-ms (synthetic straggler scale), the fitted
        # serial master work in machine-us — different clocks, so the
        # measured term must stay a tie-break (log-compressed like the
        # analytic one) or big problems would drown the order statistic
        # and the objective would stop rewarding straggler resilience.
        # Calibration still improves the tie-break: encode/decode/comm are
        # weighed by measured us instead of raw op counts.
        return lambda c: (
            expected_time_to_R(c.N, c.R)
            + 1e-6 * log1p(cal.serial_master_us(c))
        )
    return None


@dataclass(frozen=True)
class PlanCandidate:
    """One feasible (scheme, partition, packing) configuration, scored."""

    scheme: str
    u: int
    v: int
    w: int
    n: int  # packing/batch factor handed to the family's build
    costs: EPCosts
    score: float

    def instantiate(self, spec: ProblemSpec) -> CdmmScheme:
        return get_scheme(self.scheme).build(spec, self.u, self.v, self.w, self.n)


@dataclass(frozen=True)
class Plan:
    """Ranked feasible configurations for one ProblemSpec."""

    spec: ProblemSpec
    objective: str
    candidates: Tuple[PlanCandidate, ...]
    _cache: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def best(self) -> PlanCandidate:
        return self.candidates[0]

    def by_scheme(self, name: str) -> Optional[PlanCandidate]:
        """Best-ranked candidate of a given scheme family, if any."""
        for c in self.candidates:
            if c.scheme == name:
                return c
        return None

    def instantiate(self, rank: int = 0) -> CdmmScheme:
        """Build (and memoize) the executable scheme at the given rank."""
        if rank not in self._cache:
            self._cache[rank] = self.candidates[rank].instantiate(self.spec)
        return self._cache[rank]

    def summary(self, limit: int = 8) -> str:
        lines = [
            f"Plan[{self.objective}] for {self.spec.n}x "
            f"({self.spec.t}x{self.spec.r})@({self.spec.r}x{self.spec.s}) "
            f"over {self.spec.ring}, N={self.spec.N} "
            f"(straggler budget {self.spec.straggler_budget}"
            + (f", privacy_t={self.spec.privacy_t}" if self.spec.privacy_t else "")
            + "):"
        ]
        for i, c in enumerate(self.candidates[:limit]):
            lines.append(
                f"  #{i} {c.scheme:<14} (u,v,w)=({c.u},{c.v},{c.w}) n={c.n} "
                f"R={c.costs.R} m_eff={c.costs.m_eff:.1f} "
                f"up={c.costs.upload:.3g} down={c.costs.download:.3g} "
                f"score={c.score:.3g}"
            )
        return "\n".join(lines)


MAX_PACKING = 8  # RMFE packing factors searched for single-DMM variants


def _divisors(x: int, cap: int) -> List[int]:
    return [d for d in range(1, min(x, cap) + 1) if x % d == 0]


def _packing_candidates(spec: ProblemSpec, fam) -> Iterable[int]:
    if fam.packing is not None:
        # family-supplied enumeration of the 4th build/predict parameter
        # (gcsa_general: group sizes kappa dividing the batch)
        return tuple(fam.packing(spec))
    if fam.batched:
        return (spec.n,)
    # internal packing factors for the single-DMM RMFE variants; n=1 covers
    # the unpacked families (their predicts reject n != 1 / n < 2 anyway).
    # Bounded at MAX_PACKING: the extension degree grows like 2n-1, so the
    # per-element saving flattens out while encode cost keeps rising.
    dims = (set(_divisors(spec.r, cap=MAX_PACKING))
            | set(_divisors(spec.s, cap=MAX_PACKING)))
    return sorted(dims)


def plan(
    spec: ProblemSpec,
    objective: str = "latency",
    schemes: Optional[Sequence[str]] = None,
    top_k: Optional[int] = None,
    calibration: Union[None, bool, CalibrationSet] = None,
    backend: str = "local",
) -> Plan:
    """Rank every feasible (scheme, u, v, w, n) configuration for ``spec``.

    ``schemes`` restricts the search to the named families (default: all
    registered families matching the spec's batch arity); ``top_k`` caps the
    returned ranking (default: keep every feasible candidate, so losing
    schemes remain inspectable via ``Plan.by_scheme``).  Raises
    ``ValueError`` when no configuration satisfies R <= N - straggler_budget.

    ``calibration`` grounds the ``"latency"`` / ``"time_to_R"`` scores in
    measured wall-time coefficients: ``None`` auto-loads the committed
    ``benchmarks/calibration.json`` (no-op when absent or disabled via
    ``REPRO_CALIBRATION=off``), ``False`` forces the analytic proxy, and an
    explicit :class:`~repro.cdmm.calibrate.CalibrationSet` pins the
    coefficients (what the ranking-flip tests use).  ``backend`` selects
    whose coefficients apply ("local" timings are the fallback for
    backends without their own fit).

    When ``spec.privacy_t > 0`` only configurations whose cost model
    advertises ``privacy_t >= spec.privacy_t`` are feasible — i.e. only the
    secure scheme families; a plan can never silently downgrade a privacy
    requirement to an insecure scheme.  Budget combinations that exhaust N
    (``2*privacy_t + 1 > N - straggler_budget`` even at the cheapest secure
    partition) raise a ValueError naming both budgets.
    """
    spec.validate()
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; one of {sorted(OBJECTIVES)}"
        )
    score_fn = OBJECTIVES[objective]
    if objective in _CALIBRATED_OBJECTIVES and calibration is not False:
        pinned = isinstance(calibration, CalibrationSet)
        cal_set = calibration if pinned else load_calibration()
        cal = cal_set.for_backend(backend) if cal_set is not None else None
        if cal is not None and not pinned:
            # auto-loaded files are held to a higher bar than an explicitly
            # pinned set: the coefficients must describe this hardware and
            # cover every cost term — a partial fit would silently score
            # the missing term (e.g. communication) as free
            if not cal_set.matches_device() or set(cal.coef) != set(
                COEF_NAMES
            ):
                cal = None
        if cal is not None:
            score_fn = _calibrated_score_fn(objective, cal) or score_fn

    requested = registered_schemes()
    if schemes is not None:
        requested = {name: get_scheme(name) for name in schemes}
    # single-DMM families serve n=1 specs, batch families serve n>1 specs.
    # The "amortized" objective is the one cross-arity comparison: a batched
    # spec also admits the single families, priced at one execution per
    # request (their predicts never read spec.n — the packing factor they
    # receive is the internal RMFE split, not the request batch).
    if objective == "amortized":
        families = {
            name: fam for name, fam in requested.items()
            if spec.n > 1 or not fam.batched
        }
    else:
        families = {
            name: fam for name, fam in requested.items()
            if fam.batched == (spec.n > 1)
        }
    if not families:
        kind = "a batched" if spec.n > 1 else "a single-product"
        serving = sorted(
            name for name, fam in registered_schemes().items()
            if fam.batched == (spec.n > 1)
        )
        raise ValueError(
            f"none of the schemes {sorted(requested)} serves {kind} spec "
            f"(n={spec.n}); families that do: {serving}"
        )

    budgeted_R = spec.N - spec.straggler_budget
    found: List[PlanCandidate] = []
    # partition caps are lossless: R = uvw + w - 1 means u, v <= R <= N and
    # w <= (R + 1) / 2, so nothing beyond them can pass the budget filter
    for name, fam in sorted(families.items()):
        for n in _packing_candidates(spec, fam):
            for u in _divisors(spec.t, cap=budgeted_R):
                for v in _divisors(spec.s, cap=budgeted_R):
                    for w in _divisors(spec.r, cap=(budgeted_R + 1) // 2):
                        costs = fam.predict(spec, u, v, w, n)
                        if costs is None or costs.R > budgeted_R:
                            continue
                        if costs.privacy_t < spec.privacy_t:
                            continue  # never hand back an insecure scheme
                        found.append(PlanCandidate(
                            name, u, v, w, n, costs, score_fn(costs)
                        ))

    if not found:
        privacy = (
            f" meeting privacy_t={spec.privacy_t} (secure schemes need "
            f"R >= 2*privacy_t + 1 and N + 1 exceptional points)"
            if spec.privacy_t > 0 else ""
        )
        raise ValueError(
            f"no feasible scheme for {spec}: every registered configuration"
            f"{privacy} needs R > N - straggler_budget = {budgeted_R}"
        )
    found.sort(key=lambda c: (c.score, c.costs.R, c.scheme, c.u, c.v, c.w, c.n))
    if top_k is not None:
        found = found[:top_k]
    return Plan(spec, objective, tuple(found))
