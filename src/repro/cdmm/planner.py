"""Cost-model planner: enumerate registered schemes x partitions, rank them.

``plan(spec, objective)`` walks every registered scheme family and every
valid EP partition (u, v, w) admitted by the straggler budget (the caps are
lossless: R = uvw + w - 1 bounds u, v by R and w by (R+1)/2), plus RMFE
packing factors n <= MAX_PACKING for the single-DMM variants, scores the
analytic cost models, and returns a ranked :class:`Plan`.  Candidate enumeration never constructs a scheme — the
``predict`` hooks are pure arithmetic — so planning is cheap even for large
worker counts; only ``Plan.instantiate()`` pays the host-side Vandermonde /
RMFE precompute, for the one configuration actually chosen.

Objectives:
  * ``"threshold"`` — minimize the recovery threshold R (maximize straggler
    tolerance at fixed N),
  * ``"download"``  — minimize master download volume (Table 1's headline:
    Batch-EP_RMFE beats GCSA by ~1/n here),
  * ``"upload"``    — minimize master upload volume,
  * ``"latency"``   — minimize a serial-path proxy
    (encode + worker + decode ops + upload + download elements),
  * ``"time_to_R"`` — minimize expected completion under the straggler
    latency model (``core.straggler.straggler_latencies``): the elastic
    backend finishes at the R-th fastest response, so the score is the
    Monte-Carlo mean of the R-th order statistic of N heavy-tailed worker
    latencies, with the serial-work proxy as an epsilon tie-break.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from math import log1p
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ep_codes import EPCosts

from .api import CdmmScheme, ProblemSpec, get_scheme, registered_schemes

__all__ = ["plan", "Plan", "PlanCandidate", "OBJECTIVES", "expected_time_to_R"]


_LATENCY_TRIALS = 256


@lru_cache(maxsize=32)
def _sorted_latency_sample(N: int) -> np.ndarray:
    """(trials, N) rows of sorted straggler latencies, fixed seed (the
    planner must be deterministic run to run)."""
    import jax  # deferred: keep planner importable without jax init cost

    from repro.core.straggler import straggler_latencies

    keys = jax.random.split(jax.random.PRNGKey(0), _LATENCY_TRIALS)
    lat = jax.vmap(lambda k: straggler_latencies(k, N))(keys)
    return np.sort(np.asarray(lat, dtype=float), axis=1)


def expected_time_to_R(N: int, R: int) -> float:
    """E[R-th order statistic of N worker latencies] in model-ms: the
    expected wall-clock at which an elastic master can decode."""
    return float(_sorted_latency_sample(N)[:, R - 1].mean())


OBJECTIVES: Dict[str, callable] = {
    "threshold": lambda c: float(c.R),
    "download": lambda c: c.download,
    "upload": lambda c: c.upload,
    "latency": lambda c: (
        c.encode_ops + c.worker_ops + c.decode_ops + c.upload + c.download
    ),
    # expected elastic completion; serial-work proxy breaks ties among
    # configurations with equal (N, R).  The tie-break is log-compressed so
    # it stays orders of magnitude below any E[t_R] gap even for huge
    # problems (log1p(1e12 ops) * 1e-6 ~ 3e-5 model-ms) while remaining
    # monotone in the serial work
    "time_to_R": lambda c: (
        expected_time_to_R(c.N, c.R)
        + 1e-6 * log1p(c.encode_ops + c.decode_ops + c.upload + c.download)
    ),
}


@dataclass(frozen=True)
class PlanCandidate:
    """One feasible (scheme, partition, packing) configuration, scored."""

    scheme: str
    u: int
    v: int
    w: int
    n: int  # packing/batch factor handed to the family's build
    costs: EPCosts
    score: float

    def instantiate(self, spec: ProblemSpec) -> CdmmScheme:
        return get_scheme(self.scheme).build(spec, self.u, self.v, self.w, self.n)


@dataclass(frozen=True)
class Plan:
    """Ranked feasible configurations for one ProblemSpec."""

    spec: ProblemSpec
    objective: str
    candidates: Tuple[PlanCandidate, ...]
    _cache: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def best(self) -> PlanCandidate:
        return self.candidates[0]

    def by_scheme(self, name: str) -> Optional[PlanCandidate]:
        """Best-ranked candidate of a given scheme family, if any."""
        for c in self.candidates:
            if c.scheme == name:
                return c
        return None

    def instantiate(self, rank: int = 0) -> CdmmScheme:
        """Build (and memoize) the executable scheme at the given rank."""
        if rank not in self._cache:
            self._cache[rank] = self.candidates[rank].instantiate(self.spec)
        return self._cache[rank]

    def summary(self, limit: int = 8) -> str:
        lines = [
            f"Plan[{self.objective}] for {self.spec.n}x "
            f"({self.spec.t}x{self.spec.r})@({self.spec.r}x{self.spec.s}) "
            f"over {self.spec.ring}, N={self.spec.N} "
            f"(straggler budget {self.spec.straggler_budget}"
            + (f", privacy_t={self.spec.privacy_t}" if self.spec.privacy_t else "")
            + "):"
        ]
        for i, c in enumerate(self.candidates[:limit]):
            lines.append(
                f"  #{i} {c.scheme:<14} (u,v,w)=({c.u},{c.v},{c.w}) n={c.n} "
                f"R={c.costs.R} m_eff={c.costs.m_eff:.1f} "
                f"up={c.costs.upload:.3g} down={c.costs.download:.3g} "
                f"score={c.score:.3g}"
            )
        return "\n".join(lines)


MAX_PACKING = 8  # RMFE packing factors searched for single-DMM variants


def _divisors(x: int, cap: int) -> List[int]:
    return [d for d in range(1, min(x, cap) + 1) if x % d == 0]


def _packing_candidates(spec: ProblemSpec, batched: bool) -> Iterable[int]:
    if batched:
        return (spec.n,)
    # internal packing factors for the single-DMM RMFE variants; n=1 covers
    # the unpacked families (their predicts reject n != 1 / n < 2 anyway).
    # Bounded at MAX_PACKING: the extension degree grows like 2n-1, so the
    # per-element saving flattens out while encode cost keeps rising.
    dims = (set(_divisors(spec.r, cap=MAX_PACKING))
            | set(_divisors(spec.s, cap=MAX_PACKING)))
    return sorted(dims)


def plan(
    spec: ProblemSpec,
    objective: str = "latency",
    schemes: Optional[Sequence[str]] = None,
    top_k: Optional[int] = None,
) -> Plan:
    """Rank every feasible (scheme, u, v, w, n) configuration for ``spec``.

    ``schemes`` restricts the search to the named families (default: all
    registered families matching the spec's batch arity); ``top_k`` caps the
    returned ranking (default: keep every feasible candidate, so losing
    schemes remain inspectable via ``Plan.by_scheme``).  Raises
    ``ValueError`` when no configuration satisfies R <= N - straggler_budget.

    When ``spec.privacy_t > 0`` only configurations whose cost model
    advertises ``privacy_t >= spec.privacy_t`` are feasible — i.e. only the
    secure scheme families; a plan can never silently downgrade a privacy
    requirement to an insecure scheme.  Budget combinations that exhaust N
    (``2*privacy_t + 1 > N - straggler_budget`` even at the cheapest secure
    partition) raise a ValueError naming both budgets.
    """
    spec.validate()
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; one of {sorted(OBJECTIVES)}"
        )
    score_fn = OBJECTIVES[objective]

    requested = registered_schemes()
    if schemes is not None:
        requested = {name: get_scheme(name) for name in schemes}
    # single-DMM families serve n=1 specs, batch families serve n>1 specs
    families = {
        name: fam for name, fam in requested.items()
        if fam.batched == (spec.n > 1)
    }
    if not families:
        kind = "a batched" if spec.n > 1 else "a single-product"
        serving = sorted(
            name for name, fam in registered_schemes().items()
            if fam.batched == (spec.n > 1)
        )
        raise ValueError(
            f"none of the schemes {sorted(requested)} serves {kind} spec "
            f"(n={spec.n}); families that do: {serving}"
        )

    budgeted_R = spec.N - spec.straggler_budget
    found: List[PlanCandidate] = []
    # partition caps are lossless: R = uvw + w - 1 means u, v <= R <= N and
    # w <= (R + 1) / 2, so nothing beyond them can pass the budget filter
    for name, fam in sorted(families.items()):
        for n in _packing_candidates(spec, fam.batched):
            for u in _divisors(spec.t, cap=budgeted_R):
                for v in _divisors(spec.s, cap=budgeted_R):
                    for w in _divisors(spec.r, cap=(budgeted_R + 1) // 2):
                        costs = fam.predict(spec, u, v, w, n)
                        if costs is None or costs.R > budgeted_R:
                            continue
                        if costs.privacy_t < spec.privacy_t:
                            continue  # never hand back an insecure scheme
                        found.append(PlanCandidate(
                            name, u, v, w, n, costs, score_fn(costs)
                        ))

    if not found:
        privacy = (
            f" meeting privacy_t={spec.privacy_t} (secure schemes need "
            f"R >= 2*privacy_t + 1 and N + 1 exceptional points)"
            if spec.privacy_t > 0 else ""
        )
        raise ValueError(
            f"no feasible scheme for {spec}: every registered configuration"
            f"{privacy} needs R > N - straggler_budget = {budgeted_R}"
        )
    found.sort(key=lambda c: (c.score, c.costs.R, c.scheme, c.u, c.v, c.w, c.n))
    if top_k is not None:
        found = found[:top_k]
    return Plan(spec, objective, tuple(found))
