"""Elastic event-driven execution backend: decode at the R-th response.

The synchronous backends (``local``, ``shard_map``) run encode -> compute-all
-> gather -> decode behind a barrier, so a single straggler costs wall-clock
even though any R of N responses suffice.  :class:`ElasticBackend` is the
repo's first execution path whose completion time depends on R rather than N
— the paper's recovery-threshold claim made operational:

  * the master encodes per-worker shares (``encode_*_at``) and dispatches
    each worker's compute to a thread pool the moment that worker is
    scheduled, so later encodes overlap earlier computes;
  * worker results land on a response queue; the any-R decode fires the
    moment the R-th response arrives, through a per-subset decode operator
    (jitted once per live set, LRU-cached on the scheme — see
    ``CdmmScheme.decode_op``);
  * membership is a :class:`~repro.core.straggler.WorkerTrace`: workers may
    join late, leave mid-batch (never responding) or run slow; the master
    races past anything outside the R fastest responders;
  * :class:`ElasticStream` scales the model to batch workloads that rescale
    mid-stream: the live pool is carved into groups of ``group_size``
    workers, each group runs one coded execution per wave, and on every
    membership change the per-group batch is re-chunked via
    ``repro.runtime.elastic.replan_batch`` and the planner re-ranks schemes
    for the new batch size.

Determinism: the decoded subset varies with the trace (first R *arrivals*,
not first R indices), but every registered scheme's any-R decode is
integer-exact, so the output is bit-identical to ``LocalSimBackend`` for
every valid trace — property-tested in tests/test_elastic.py.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.galois import Ring
from repro.core.straggler import WorkerTrace
from repro.kernels import gr_matmul, kernel_auto_enabled, kernel_supported
from repro.runtime.elastic import replan_batch

from .api import CdmmScheme, ProblemSpec
from .backends import encode_all, register_backend
from .planner import plan

__all__ = [
    "ElasticBackend",
    "ElasticStats",
    "ElasticStream",
    "NotEnoughResponders",
    "decode_responses",
    "worker_closures",
]


class NotEnoughResponders(RuntimeError):
    """Raised when a trace/mask leaves fewer than R workers ever responding:
    the any-R decode is mathematically impossible, and decoding from repeated
    indices would return garbage silently."""


@dataclass(frozen=True)
class ElasticStats:
    """Per-call accounting of one elastic execution (virtual-time model)."""

    fast_path: bool  # all-live vectorized path, no thread pool
    dispatched: Tuple[int, ...]  # workers whose compute was launched
    live_idx: Tuple[int, ...]  # the R-subset actually decoded from
    n_responders: int  # workers whose response would eventually land
    time_to_R_ms: float  # virtual arrival of the R-th response
    time_to_all_ms: float  # virtual arrival of the last response (inf if
    #                         any worker never responds — the sync barrier)
    wall_ms: float  # measured master wall-clock for the call


def _response_order(resp_ms: np.ndarray) -> np.ndarray:
    """Worker indices sorted by virtual arrival (ties -> lower index)."""
    return np.lexsort((np.arange(len(resp_ms)), resp_ms))


def decode_responses(
    scheme: CdmmScheme, got: Dict[int, jnp.ndarray]
) -> jnp.ndarray:
    """The shared response-ordering/decode tail of every any-R master.

    ``got`` maps worker index -> response for (at least) R workers.  The
    live set is canonicalized to sorted order — the any-R decode is
    subset-order agnostic as long as rows match ``idx``, and a canonical
    order maximizes ``decode_op`` cache reuse across membership patterns.
    Both the in-process elastic master and the multi-process pool master
    (``repro.dist.master``) decode through here, so they are bit-identical
    by construction.
    """
    if len(got) < scheme.R:
        raise NotEnoughResponders(
            f"{scheme.name}: decode needs R={scheme.R} responses, "
            f"have {len(got)}"
        )
    idx = tuple(sorted(int(i) for i in got))[: scheme.R]
    return scheme.decode_op(idx)(jnp.stack([got[i] for i in idx]))


def worker_closures(
    scheme: CdmmScheme, keyed: bool = False, use_kernel: Optional[bool] = None
):
    """Jitted (encode_at, compute) closures, cached per scheme instance so
    repeated elastic calls never re-trace.  The worker id is a traced scalar
    (one compilation covers all N workers); worker shares are donated to the
    compute (single-use buffers; donation is a warn-only no-op on CPU).
    ``keyed`` selects the keyed-encode variant (the masked-randomness seam:
    the PRNG key is a traced argument so rekeying never re-compiles).
    ``use_kernel`` (None = auto via ``kernel_auto_enabled``) routes each
    worker's block product through the tuned Pallas kernel — every
    registered scheme's ``worker_compute`` is exactly the ring matmul of
    its two shares, so the substitution is scheme-agnostic and exact."""
    if use_kernel is None:
        use_kernel = kernel_auto_enabled(scheme.ring)
    use_kernel = use_kernel and kernel_supported(scheme.ring)
    ops = scheme.__dict__.setdefault("_elastic_ops", {})
    ename = "encode_keyed" if keyed else "encode"
    if ename not in ops:
        if keyed:
            ops[ename] = jax.jit(lambda a, b, i, k: (
                scheme.encode_a_at(a, i, key=k),
                scheme.encode_b_at(b, i, key=k),
            ))
        else:
            ops[ename] = jax.jit(lambda a, b, i: (
                scheme.encode_a_at(a, i), scheme.encode_b_at(b, i)
            ))
    cname = "compute_kernel" if use_kernel else "compute"
    if cname not in ops:
        if use_kernel:
            body = lambda fa, gb: gr_matmul(fa, gb, scheme.ring)  # noqa: E731
        else:
            body = lambda fa, gb: (  # noqa: E731
                scheme.worker_compute(fa[None], gb[None])[0]
            )
        ops[cname] = jax.jit(
            body,
            donate_argnums=() if jax.default_backend() == "cpu" else (0, 1),
        )
    return ops[ename], ops[cname]


class ElasticBackend:
    """Event-driven elastic execution of one coded matmul.

    ``trace`` fixes the membership realization (default: everyone live and
    instant — the fast path).  An (N,)-bool ``mask`` passed at call time is
    composed with the trace (masked-out workers never respond).
    ``simulate_ms_scale > 0`` makes worker threads sleep
    ``response_ms * scale / 1000`` seconds so *real* wall-clock exhibits the
    race past stragglers (benchmarks); leave at 0 for tests.
    """

    name = "elastic"

    def __init__(
        self,
        trace: Optional[WorkerTrace] = None,
        max_threads: Optional[int] = None,
        simulate_ms_scale: float = 0.0,
        use_kernel: Optional[bool] = None,
    ):
        self.trace = trace
        self.max_threads = max_threads
        self.simulate_ms_scale = simulate_ms_scale
        # None = auto: workers use the tuned Pallas kernel wherever it
        # compiles for the scheme's ring (see worker_closures)
        self.use_kernel = use_kernel
        self.last_stats: Optional[ElasticStats] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_size = 0

    def _worker_pool(self, n: int) -> ThreadPoolExecutor:
        # one pool per backend instance: repeated calls (serving loops,
        # ElasticStream waves) must not pay thread spawn per matmul.  Sized
        # to the scheme's worker count — a cap below N would serialize
        # dispatch and make simulated stragglers block fast workers' slots,
        # inflating wall-clock toward the t_N barrier the backend exists to
        # beat.  Grown (never shrunk) if a bigger scheme shows up.
        want = self.max_threads or max(n, 8)
        if self._pool is None or self._pool_size < want:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
            self._pool = ThreadPoolExecutor(
                max_workers=want, thread_name_prefix="cdmm-elastic"
            )
            self._pool_size = want
        return self._pool

    def close(self) -> None:
        """Release the worker thread pool (idempotent).  In-flight straggler
        tasks are abandoned, not joined — ``done`` is already set by the time
        any caller closes."""
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
            self._pool_size = 0

    def __enter__(self) -> "ElasticBackend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- protocol entry point ------------------------------------------------

    def __call__(
        self,
        scheme: CdmmScheme,
        A: jnp.ndarray,
        B: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        C, self.last_stats = self.run(scheme, A, B, mask, key=key)
        return C

    def run(
        self,
        scheme: CdmmScheme,
        A: jnp.ndarray,
        B: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
        key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, ElasticStats]:
        t0 = time.perf_counter()
        if self.trace is None and mask is None:
            return self._run_all_live(scheme, A, B, t0, key)
        trace = self.trace or WorkerTrace.all_live(scheme.N)
        if trace.N != scheme.N:
            raise ValueError(
                f"trace has N={trace.N} workers, scheme needs N={scheme.N}"
            )
        if mask is not None:
            trace = trace.restrict(np.asarray(mask, dtype=bool))
        return self._run_traced(scheme, A, B, trace, t0, key)

    # -- all-live fast path --------------------------------------------------

    def _run_all_live(self, scheme, A, B, t0, key=None):
        """Everyone present and instant: one vmapped XLA program, but the
        decode still routes through the cached per-subset operator so the
        warm path shares compilations with the event loop."""
        from repro.obs import trace as obs

        ctx = obs.maybe_context("elastic")
        tracer = obs.tracer()
        with tracer.span(ctx, "encode", "elastic", scheme=scheme.name):
            FA, GB = encode_all(scheme, A, B, key=key)
        with tracer.span(ctx, "compute", "elastic", N=int(scheme.N)):
            H = scheme.worker_compute(FA, GB)
        idx = tuple(range(scheme.R))
        with tracer.span(ctx, "decode", "elastic", scheme=scheme.name):
            C = scheme.decode_op(idx)(H[: scheme.R])
        stats = ElasticStats(
            fast_path=True,
            dispatched=tuple(range(scheme.N)),
            live_idx=idx,
            n_responders=scheme.N,
            time_to_R_ms=0.0,
            time_to_all_ms=0.0,
            wall_ms=(time.perf_counter() - t0) * 1e3,
        )
        return C, stats

    # -- event-driven master loop --------------------------------------------

    def _run_traced(self, scheme, A, B, trace: WorkerTrace, t0, key=None):
        N, R = scheme.N, scheme.R
        resp = trace.response_ms()
        responders = np.flatnonzero(np.isfinite(resp))
        if len(responders) < R:
            raise NotEnoughResponders(
                f"{scheme.name}: only {len(responders)} of N={N} workers "
                f"ever respond, decode needs R={R}"
            )
        # the R virtually-fastest responders; the master is done at t_R and
        # never even dispatches workers that join after that
        order = _response_order(resp)
        fastR = order[:R]
        t_R = trace.time_to_kth_response(R)
        t_all = trace.time_to_kth_response(N)
        dispatch = [i for i in np.argsort(trace.join_ms, kind="stable")
                    if trace.join_ms[i] <= t_R]

        encode_at, compute = worker_closures(
            scheme, keyed=key is not None, use_kernel=self.use_kernel
        )

        from repro.obs import trace as obs

        ctx = obs.maybe_context("elastic")
        tracer = obs.tracer()

        q: "queue.Queue" = queue.Queue()
        scale = self.simulate_ms_scale
        done = threading.Event()  # master finished: stragglers stop early

        def worker_task(i: int, fa, gb):
            try:
                t_c = obs.now()
                h = compute(fa, gb)
                h.block_until_ready()
                tracer.add(ctx, "compute", "worker", t_c, obs.now(),
                           wid=int(i), share=int(i), simulated=True)
                if scale > 0.0 and np.isfinite(resp[i]):
                    # simulated latency; cut short the moment the master
                    # decodes so stragglers never block pool reuse or exit
                    done.wait(resp[i] * scale / 1e3)
                q.put((i, h, None))
            except Exception as e:  # surfaced on the master thread
                q.put((i, None, e))

        needed = set(int(i) for i in fastR)
        got: Dict[int, jnp.ndarray] = {}
        pool = self._worker_pool(len(dispatch))
        # dispatch in join order; encode of worker k overlaps the pool's
        # compute of workers < k (the master thread never blocks here)
        for i in dispatch:
            t_e = obs.now()
            if key is None:
                fa, gb = encode_at(A, B, jnp.int32(i))
            else:
                fa, gb = encode_at(A, B, jnp.int32(i), key)
            tracer.add(ctx, "encode", "elastic", t_e, obs.now(),
                       share=int(i))
            pool.submit(worker_task, int(i), fa, gb)
        # response queue: consume until the R-th needed response lands;
        # straggler tasks drain into the dead queue after `done` fires
        t_w = obs.now()
        try:
            while needed - set(got):
                i, h, err = q.get()
                if err is not None:
                    raise err
                if i in needed:
                    got[i] = h
        finally:
            done.set()  # race past stragglers: wake any simulated sleeps
        tracer.add(ctx, "wait_R", "elastic", t_w, obs.now(),
                   R=int(R), responders=sorted(int(i) for i in got))

        t_d = obs.now()
        C = decode_responses(scheme, got)
        tracer.add(ctx, "decode", "elastic", t_d, obs.now(),
                   scheme=scheme.name)
        idx = tuple(sorted(int(i) for i in fastR))
        stats = ElasticStats(
            fast_path=False,
            dispatched=tuple(int(i) for i in dispatch),
            live_idx=idx,
            n_responders=len(responders),
            time_to_R_ms=t_R,
            time_to_all_ms=t_all,
            wall_ms=(time.perf_counter() - t0) * 1e3,
        )
        return C, stats


register_backend("elastic", ElasticBackend)


# --------------------------------------------------------------------------
# batch streams that rescale mid-stream
# --------------------------------------------------------------------------


class ElasticStream:
    """Run a stream of batch matmuls over a worker pool that rescales.

    The live pool is carved into ``live // group_size`` independent groups;
    each wave, every group executes one planner-chosen coded scheme over its
    chunk of the global batch.  On a membership change the per-group batch
    is re-chunked with :func:`repro.runtime.elastic.replan_batch` (ceil —
    the trailing chunk is zero-padded and trimmed after decode) and the
    planner re-ranks schemes for the new batch size.  Plans are memoized per
    chunk size, so oscillating pools don't re-pay scheme construction.
    """

    def __init__(
        self,
        t: int,
        r: int,
        s: int,
        ring: Ring,
        group_size: int = 8,
        objective: str = "latency",
        straggler_budget: int = 0,
        backend: Optional[ElasticBackend] = None,
    ):
        self.t, self.r, self.s, self.ring = t, r, s, ring
        self.group_size = group_size
        self.objective = objective
        self.straggler_budget = straggler_budget
        self.backend = backend or ElasticBackend()
        self._schemes: Dict[int, CdmmScheme] = {}
        self.last_replan: Optional[Tuple[int, int]] = None  # (groups, per)

    def _scheme_for(self, per: int) -> CdmmScheme:
        if per not in self._schemes:
            spec = ProblemSpec(
                self.t, self.r, self.s, n=per, ring=self.ring,
                N=self.group_size, straggler_budget=self.straggler_budget,
            )
            self._schemes[per] = plan(spec, objective=self.objective).instantiate()
        return self._schemes[per]

    def step(self, As: jnp.ndarray, Bs: jnp.ndarray, live: int) -> jnp.ndarray:
        """One wave: ``As (n, t, r, D0) @ Bs (n, r, s, D0)`` with ``live``
        workers currently in the pool.  Returns ``Cs (n, t, s, D0)``."""
        nprod = int(As.shape[0])
        groups = live // self.group_size
        if groups < 1:
            raise NotEnoughResponders(
                f"pool of {live} live workers cannot form one group of "
                f"{self.group_size}"
            )
        per = replan_batch(nprod, groups)
        self.last_replan = (groups, per)
        scheme = self._scheme_for(per)
        chunk = scheme.batch  # may exceed `per` (RMFE packs up, never down)

        outs = []
        for lo in range(0, nprod, chunk):
            Ac, Bc = As[lo : lo + chunk], Bs[lo : lo + chunk]
            pad = chunk - Ac.shape[0]
            if pad:
                Ac = jnp.concatenate([Ac, jnp.zeros((pad, *As.shape[1:]), As.dtype)])
                Bc = jnp.concatenate([Bc, jnp.zeros((pad, *Bs.shape[1:]), Bs.dtype)])
            if chunk == 1:
                outs.append(self.backend(scheme, Ac[0], Bc[0])[None])
            else:
                outs.append(self.backend(scheme, Ac, Bc))
        return jnp.concatenate(outs, axis=0)[:nprod]
