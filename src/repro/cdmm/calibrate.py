"""Planner calibration: fit measured wall-time coefficients per backend.

The planner's ``"latency"`` objective was an op-count proxy — fine for
ordering schemes with wildly different asymptotics, blind to the machine
constants that decide real races (XLA's uint32 matmul throughput vs the
Vandermonde encode's, memcpy bandwidth for share movement...).  This module
closes the loop: :func:`fit_rows` ingests the machine-readable rows
``benchmarks/run.py --json`` emits (stage rows tagged with their cost-model
features — ``encode_ops``/``worker_ops``/``decode_ops``/``comm_elems`` — and
a ``backend`` name), fits one linear coefficient per term by least squares
through the origin, and :func:`save_calibration` persists the result to a
committed ``benchmarks/calibration.json``.  ``plan(spec, objective=
"latency")`` then scores candidates by *predicted wall time*

    t_us = c_enc * encode_ops + c_comp * worker_ops
         + c_dec * decode_ops + c_comm * (upload + download)

falling back to the analytic op-count proxy whenever no calibration is
available (missing file, unknown backend, or ``REPRO_CALIBRATION=off``).
``"time_to_R"`` keeps the straggler order-statistic as its leading term and
swaps its log-compressed tie-break for the calibrated serial master work.

Regenerate after hardware or kernel changes:

    python -m benchmarks.run --only figs --json BENCH_ci.json
    python -m repro.cdmm.calibrate --bench BENCH_ci.json \
        --out benchmarks/calibration.json
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro import settings
from repro.core.ep_codes import EPCosts

__all__ = [
    "Calibration",
    "CalibrationSet",
    "DEFAULT_CALIBRATION_PATH",
    "fit_rows",
    "load_calibration",
    "rows_from_timeline",
    "save_calibration",
]

# committed next to the benchmark baselines; resolved relative to the repo
# checkout (src/repro/cdmm -> repo root), overridable via REPRO_CALIBRATION
DEFAULT_CALIBRATION_PATH = (
    Path(__file__).resolve().parents[3] / "benchmarks" / "calibration.json"
)
CALIBRATION_VERSION = 1

# stage-row suffix -> (feature key in the row's derived dict, coef name)
STAGE_FEATURES: Dict[str, Tuple[str, str]] = {
    "encode": ("encode_ops", "encode"),
    "worker": ("worker_ops", "compute"),
    "decode": ("decode_ops", "decode"),
    "comm": ("comm_elems", "comm"),
}
COEF_NAMES = ("encode", "compute", "decode", "comm")


@dataclass(frozen=True)
class Calibration:
    """Fitted us-per-unit coefficients for one backend.

    ``coef[name]`` multiplies the matching EPCosts term; a term never
    observed in the fit keeps coefficient 0.0 (it then contributes nothing
    to predictions — the analytic fallback still covers pure-proxy use).
    """

    backend: str
    coef: Dict[str, float]
    nrows: int = 0
    r2: Dict[str, float] = field(default_factory=dict)

    def predict_us(self, costs: EPCosts) -> float:
        """Predicted serial wall time (us) of one coded execution."""
        c = self.coef
        return (
            c.get("encode", 0.0) * costs.encode_ops
            + c.get("compute", 0.0) * costs.worker_ops
            + c.get("decode", 0.0) * costs.decode_ops
            + c.get("comm", 0.0) * (costs.upload + costs.download)
        )

    def serial_master_us(self, costs: EPCosts) -> float:
        """Master-side serial work only (encode + decode + communication):
        the piece an elastic master cannot overlap with worker compute."""
        c = self.coef
        return (
            c.get("encode", 0.0) * costs.encode_ops
            + c.get("decode", 0.0) * costs.decode_ops
            + c.get("comm", 0.0) * (costs.upload + costs.download)
        )


@dataclass(frozen=True)
class CalibrationSet:
    """Per-backend calibrations with a fallback chain: exact backend name,
    then "local" (stage timings are the same jitted calls everywhere),
    then None (caller reverts to the analytic proxy).

    ``device`` namespaces the fit by the hardware it was measured on
    (``jax.default_backend()`` at fit time): coefficients from one
    machine's CPU must not silently rank plans on a TPU host.  ``None``
    means device-agnostic — hand-built sets (tests, explicit overrides)
    apply anywhere.
    """

    backends: Dict[str, Calibration]
    device: Optional[str] = None

    def for_backend(self, backend: str = "local") -> Optional[Calibration]:
        cal = self.backends.get(backend)
        if cal is None:
            cal = self.backends.get("local")
        return cal

    def matches_device(self) -> bool:
        """Do these coefficients describe the executing hardware?"""
        if self.device is None:
            return True
        import jax  # deferred: keep module importable without jax init

        return self.device == jax.default_backend()

    def to_payload(self) -> dict:
        return {
            "version": CALIBRATION_VERSION,
            "device": self.device,
            "backends": {
                name: {"coef": cal.coef, "nrows": cal.nrows, "r2": cal.r2}
                for name, cal in sorted(self.backends.items())
            },
        }

    @classmethod
    def from_payload(cls, payload: Mapping) -> "CalibrationSet":
        if payload.get("version") != CALIBRATION_VERSION:
            raise ValueError(
                f"calibration version {payload.get('version')!r} != "
                f"{CALIBRATION_VERSION}"
            )
        backends = {}
        for name, entry in payload.get("backends", {}).items():
            coef = {k: float(v) for k, v in entry["coef"].items()}
            bad = set(coef) - set(COEF_NAMES)
            if bad:
                raise ValueError(f"unknown coefficient(s) {sorted(bad)}")
            backends[name] = Calibration(
                backend=name,
                coef=coef,
                nrows=int(entry.get("nrows", 0)),
                r2={k: float(v) for k, v in entry.get("r2", {}).items()},
            )
        return cls(backends=backends, device=payload.get("device"))


def _stage_of(name: str) -> Optional[str]:
    tail = name.rsplit("_", 1)[-1]
    return tail if tail in STAGE_FEATURES else None


def fit_rows(rows: Iterable[Mapping]) -> CalibrationSet:
    """Fit per-backend coefficients from benchmark JSON rows.

    A row participates when it is timed (``us > 0``), its name ends in a
    known stage suffix, and its ``derived`` dict carries that stage's
    feature and a ``backend`` tag.  Each coefficient is the least-squares
    slope through the origin, ``sum(us * x) / sum(x^2)`` — one observation
    per (backend, stage) would make an exact fit; more average out noise.
    """
    # (backend, coef_name) -> [(feature, us)]
    samples: Dict[Tuple[str, str], List[Tuple[float, float]]] = {}
    nrows: Dict[str, int] = {}
    for row in rows:
        us = float(row.get("us", 0.0))
        stage = _stage_of(str(row.get("name", "")))
        if us <= 0.0 or stage is None:
            continue
        derived = row.get("derived", {})
        feature_key, coef_name = STAGE_FEATURES[stage]
        if feature_key not in derived:
            continue
        x = float(derived[feature_key])
        if x <= 0.0:
            continue
        backend = str(derived.get("backend", "local"))
        samples.setdefault((backend, coef_name), []).append((x, us))
        nrows[backend] = nrows.get(backend, 0) + 1

    backends: Dict[str, Calibration] = {}
    for backend in sorted(nrows):
        coef: Dict[str, float] = {}
        r2: Dict[str, float] = {}
        for name in COEF_NAMES:
            pts = samples.get((backend, name), [])
            if not pts:
                continue
            sxx = sum(x * x for x, _ in pts)
            sxy = sum(x * y for x, y in pts)
            c = max(sxy / sxx, 0.0) if sxx > 0 else 0.0
            coef[name] = c
            sy = sum(y for _, y in pts) / len(pts)
            ss_res = sum((y - c * x) ** 2 for x, y in pts)
            ss_tot = sum((y - sy) ** 2 for _, y in pts)
            r2[name] = round(1.0 - ss_res / ss_tot, 4) if ss_tot > 0 else 1.0
        backends[backend] = Calibration(
            backend=backend, coef=coef, nrows=nrows[backend], r2=r2
        )
    try:
        import jax

        device = jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        device = None
    return CalibrationSet(backends=backends, device=device)


# trace span name -> (stage suffix, how durations aggregate)
_TRACE_STAGES: Dict[str, Tuple[str, str]] = {
    "encode": ("encode", "sum"),  # per-share encodes are serial master work
    "compute": ("worker", "each"),  # one observation per worker matmul
    "decode": ("decode", "sum"),
    "send": ("comm", "sum"),  # wire time both directions pools into comm
    "recv": ("comm", "sum"),
}


def rows_from_timeline(
    timeline, costs: EPCosts, backend: str = "pool"
) -> List[Dict]:
    """Fit-compatible rows from one traced request's measured spans.

    The alternative to the benchmark harness: a ``--trace`` run of the
    real pool already times every stage of a real request, so its
    :class:`repro.obs.Timeline` plus the plan's :class:`EPCosts` yields
    the same ``(us, feature, backend)`` rows ``fit_rows`` consumes.
    Encode/decode/wire spans sum into one serial observation each (that
    is what the master actually spent); each per-worker ``compute`` span
    is its own observation of ``worker_ops``.  Feed several timelines'
    rows to :func:`fit_rows` to average out noise.
    """
    feature_of = {
        "encode": float(costs.encode_ops),
        "worker": float(costs.worker_ops),
        "decode": float(costs.decode_ops),
        "comm": float(costs.upload + costs.download),
    }
    sums: Dict[str, float] = {}
    rows: List[Dict] = []

    def _row(stage: str, us: float) -> Dict:
        feature_key, _ = STAGE_FEATURES[stage]
        return {
            "name": f"trace_{backend}_{stage}",
            "us": us,
            "derived": {feature_key: feature_of[stage], "backend": backend},
        }

    for span in timeline.spans:
        mapped = _TRACE_STAGES.get(span.name)
        if mapped is None:
            continue
        stage, mode = mapped
        us = span.duration_s * 1e6
        if us <= 0.0 or feature_of[stage] <= 0.0:
            continue
        if mode == "each":
            rows.append(_row(stage, us))
        else:
            sums[stage] = sums.get(stage, 0.0) + us
    for stage, us in sorted(sums.items()):
        rows.append(_row(stage, us))
    return rows


def save_calibration(
    cal: CalibrationSet, path: Optional[Path] = None
) -> Path:
    p = Path(path) if path else DEFAULT_CALIBRATION_PATH
    with open(p, "w") as f:
        json.dump(cal.to_payload(), f, indent=1, sort_keys=True)
        f.write("\n")
    return p


_LOADED: Dict[str, Optional[CalibrationSet]] = {}


def load_calibration(
    path: Optional[Path] = None, *, cache: bool = True
) -> Optional[CalibrationSet]:
    """Load the committed calibration, or None when unavailable.

    Resolution order: explicit ``path`` argument, the ``REPRO_CALIBRATION``
    env var (the value ``off``/``0``/empty disables calibration entirely —
    the deterministic analytic proxy for tests), then the committed
    ``benchmarks/calibration.json``.  Parsed files are memoized per path.
    """
    if path is None:
        env = settings.get("calibration")
        if env is not None:
            if str(env).strip().lower() in ("", "0", "off", "none"):
                return None
            path = Path(env)
        else:
            path = DEFAULT_CALIBRATION_PATH
    key = str(path)
    if cache and key in _LOADED:
        return _LOADED[key]
    result: Optional[CalibrationSet] = None
    try:
        with open(path) as f:
            result = CalibrationSet.from_payload(json.load(f))
    except (OSError, ValueError, json.JSONDecodeError):
        result = None  # analytic fallback — never fail a plan() over this
    if cache:
        _LOADED[key] = result
    return result


def invalidate_calibration_cache() -> None:
    _LOADED.clear()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--bench", default="BENCH_ci.json",
        help="benchmark rows JSON (from benchmarks/run.py --json)",
    )
    ap.add_argument(
        "--out", default=str(DEFAULT_CALIBRATION_PATH),
        help="calibration JSON to write",
    )
    args = ap.parse_args(argv)
    with open(args.bench) as f:
        rows = json.load(f)
    cal = fit_rows(rows)
    if not cal.backends:
        print(f"no calibratable rows in {args.bench} (need timed stage rows "
              f"with cost features; run benchmarks/run.py --only figs --json)")
        return 1
    out = save_calibration(cal, Path(args.out))
    for name, c in sorted(cal.backends.items()):
        print(f"{name}: {c.coef} (n={c.nrows}, r2={c.r2})")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
