"""Bit-exact straggler-tolerant int8 matmul for serving, via CDMM over Z_{2^32}.

The paper's technique is integer-exact, so it cannot run bf16 matmuls — but
quantized inference matmuls ARE integer matmuls: with per-token activation
scales and per-channel weight scales,

    y = (sx ⊗ sw) * (q_x @ q_w),   q ∈ int8

and |sum_d q_x q_w| <= d * 127^2 < 2^31 for d <= 131k, so the int32 product
is exact and equals its value mod 2^32.  Lifting int8 two's-complement into
Z_{2^32} makes the accumulation a Galois-ring matmul — EP_RMFE-coded across
N workers, any R of which reconstruct the EXACT integer result (bit-identical
dequantized output, no approximation from stragglers/failures).

Built on the unified scheme API: the coded matmul is the registered
``ep_rmfe1`` scheme (MatDot-style contraction split, Cor IV.1) executed by
the local or shard_map backend from `repro.cdmm.backends`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.galois import make_ring

from .api import EPRMFE1Adapter
from .backends import LocalSimBackend, shard_worker_body

__all__ = ["quantize_int8", "CodedQuantMatmul", "lift_i8_to_ring", "unlift_to_i32"]


def quantize_int8(x: jnp.ndarray, axis: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization along ``axis``; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def lift_i8_to_ring(q: jnp.ndarray) -> jnp.ndarray:
    """int8 -> Z_{2^32} two's-complement lift, trailing ring dim D=1."""
    return q.astype(jnp.int32).astype(jnp.uint32)[..., None]


def unlift_to_i32(c: jnp.ndarray) -> jnp.ndarray:
    """Z_{2^32} (..., 1) -> exact signed int32 result."""
    return c[..., 0].astype(jnp.int32)


class CodedQuantMatmul:
    """EP_RMFE-I-coded exact int8 matmul across a worker mesh axis.

    n = 2 (MatDot-style split of the contraction dim) with N workers on
    ``axis_name``; u x v output partition, w | d/(2).  With N=16 the scheme
    runs over GR(2^32, 4) — the paper's 16-worker evaluation point.
    """

    def __init__(
        self,
        N: int,
        axis_name: Optional[str],
        *,
        n: int = 2,
        u: int = 2,
        v: int = 2,
        w: int = 1,
        use_kernel: bool = False,
    ):
        self.base = make_ring(2, 32, ())
        self.n = n
        self.scheme = EPRMFE1Adapter(self.base, n, N, u, v, w)
        self.axis = axis_name
        self.use_kernel = use_kernel
        self._local = LocalSimBackend()

    @property
    def R(self) -> int:
        return self.scheme.R

    def exact_int_matmul(
        self, qx: jnp.ndarray, qw: jnp.ndarray, mask: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """(tokens, d) int8 @ (d, f) int8 -> exact int32, coded across workers.

        If ``axis_name`` was given this must run inside shard_map over that
        axis with qx/qw/mask replicated; otherwise it runs locally.
        """
        A = lift_i8_to_ring(qx)  # (t, d, 1)
        B = lift_i8_to_ring(qw)  # (d, f, 1)
        if self.axis is not None:
            if mask is None:
                mask = jnp.ones(self.scheme.N, dtype=bool)
            C = shard_worker_body(
                self.scheme, self.axis, A, B, mask, use_kernel=self.use_kernel
            )
        else:
            C = self._local(self.scheme, A, B, mask)
        return unlift_to_i32(C)

    def __call__(
        self,
        x: jnp.ndarray,
        w: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Float-in/float-out coded matmul: quantize, code, dequantize."""
        qx, sx = quantize_int8(x, axis=-1)  # (t, d), (t, 1)
        qw, sw = quantize_int8(w, axis=0)  # (d, f), (1, f)
        acc = self.exact_int_matmul(qx, qw, mask)
        return acc.astype(jnp.float32) * sx * sw
