"""Distributed CDMM runtime: shard_map workers, straggler masks, quantized serving."""
from .runtime import DistributedEP, DistributedBatchRMFE, cdmm_shard_map
from .quantized import CodedQuantMatmul, quantize_int8, lift_i8_to_ring, unlift_to_i32

__all__ = [
    "DistributedEP", "DistributedBatchRMFE", "cdmm_shard_map",
    "CodedQuantMatmul", "quantize_int8", "lift_i8_to_ring", "unlift_to_i32",
]
