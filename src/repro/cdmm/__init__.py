"""CDMM: unified scheme API, cost-model planner, pluggable execution backends.

The front door is three calls::

    spec = ProblemSpec(t, r, s, n=batch, ring=Z32, N=workers)
    p = plan(spec, objective="download")
    C = coded_matmul(A, B, p, backend="shard_map", mask=liveness)

Backends: ``"local"`` (sync, vmapped in-process), ``"shard_map"`` (sync
SPMD over a mesh axis), ``"elastic"`` (event-driven master that decodes at
the R-th response and tolerates join/leave/slowdown — see
``repro.cdmm.backends`` for the full comparison table); plus the legacy
distributed runtime (shard_map master/worker bodies) and the quantized int8
serving plane built on top of it.
"""
from .api import (
    CdmmScheme,
    EPCosts,
    ProblemSpec,
    SchemeFamily,
    get_scheme,
    register_scheme,
    registered_schemes,
)
from .backends import (
    LocalSimBackend,
    ShardMapBackend,
    coded_matmul,
    get_backend,
    register_backend,
    shard_worker_body,
)
from .calibrate import (
    Calibration,
    CalibrationSet,
    fit_rows,
    load_calibration,
    save_calibration,
)
from .elastic import ElasticBackend, ElasticStream, NotEnoughResponders
from .planner import OBJECTIVES, Plan, PlanCandidate, expected_time_to_R, plan
from .runtime import DistributedEP, DistributedBatchRMFE, cdmm_shard_map
from .quantized import CodedQuantMatmul, quantize_int8, lift_i8_to_ring, unlift_to_i32

__all__ = [
    "CdmmScheme", "EPCosts", "ProblemSpec", "SchemeFamily",
    "get_scheme", "register_scheme", "registered_schemes",
    "plan", "Plan", "PlanCandidate", "OBJECTIVES", "expected_time_to_R",
    "Calibration", "CalibrationSet", "fit_rows", "load_calibration",
    "save_calibration",
    "coded_matmul", "get_backend", "register_backend",
    "LocalSimBackend", "ShardMapBackend", "shard_worker_body",
    "ElasticBackend", "ElasticStream", "NotEnoughResponders",
    "DistributedEP", "DistributedBatchRMFE", "cdmm_shard_map",
    "CodedQuantMatmul", "quantize_int8", "lift_i8_to_ring", "unlift_to_i32",
]
