"""CDMM: unified scheme API, cost-model planner, pluggable execution backends.

The front door is three calls::

    spec = ProblemSpec(t, r, s, n=batch, ring=Z32, N=workers)
    p = plan(spec, objective="download")
    C = coded_matmul(A, B, p, backend="shard_map", mask=liveness)

plus the legacy distributed runtime (shard_map master/worker bodies) and the
quantized int8 serving plane built on top of it.
"""
from .api import (
    CdmmScheme,
    EPCosts,
    ProblemSpec,
    SchemeFamily,
    get_scheme,
    register_scheme,
    registered_schemes,
)
from .backends import (
    LocalSimBackend,
    ShardMapBackend,
    coded_matmul,
    get_backend,
    shard_worker_body,
)
from .planner import OBJECTIVES, Plan, PlanCandidate, plan
from .runtime import DistributedEP, DistributedBatchRMFE, cdmm_shard_map
from .quantized import CodedQuantMatmul, quantize_int8, lift_i8_to_ring, unlift_to_i32

__all__ = [
    "CdmmScheme", "EPCosts", "ProblemSpec", "SchemeFamily",
    "get_scheme", "register_scheme", "registered_schemes",
    "plan", "Plan", "PlanCandidate", "OBJECTIVES",
    "coded_matmul", "get_backend", "LocalSimBackend", "ShardMapBackend",
    "shard_worker_body",
    "DistributedEP", "DistributedBatchRMFE", "cdmm_shard_map",
    "CodedQuantMatmul", "quantize_int8", "lift_i8_to_ring", "unlift_to_i32",
]
