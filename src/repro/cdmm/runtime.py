"""Distributed CDMM runtime: the paper's master/worker protocol as SPMD.

Mapping (DESIGN.md §3.3): the N CDMM workers are the shards of a mesh axis.
Under ``shard_map`` each shard

  1. *encodes its own point*  — evaluates f(alpha_i), g(alpha_i) from the
     (replicated) partition blocks.  This is the "broadcast blocks, evaluate
     at the worker" variant: upload = one block broadcast, and the master
     never materialises N evaluations (the paper's master-side encode is the
     `master_encode=True` mode, a Vandermonde matmul sharded over workers).
  2. computes its block product with the Pallas gr_matmul kernel,
  3. all-gathers responses; decoding from the first R live workers happens
     replicated (every shard doubles as the master — in a real deployment
     only the master decodes; collective bytes are reported either way).

Straggler tolerance is a runtime boolean mask: dead workers contribute
garbage that the any-R Lagrange decode provably never reads.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.batch_rmfe import BatchEPRMFE
from repro.core.ep_codes import EPCode
from repro.core.galois import Ring
from repro.core.polyops import as_u32, s_vandermonde
from repro.core.straggler import select_workers
from repro.kernels import gr_matmul

__all__ = ["DistributedEP", "DistributedBatchRMFE", "cdmm_shard_map"]


def _take_rows(M: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
    return lax.dynamic_index_in_dim(M, i, axis=0, keepdims=False)


class DistributedEP:
    """SPMD execution of one EPCode over a mesh axis of size N."""

    def __init__(
        self,
        code: EPCode,
        axis_name: str,
        *,
        use_kernel: bool = False,
        master_encode: bool = False,
    ):
        self.code = code
        self.axis = axis_name
        self.use_kernel = use_kernel
        self.master_encode = master_encode

    # ---- per-shard body (call inside shard_map over the worker axis) ------

    def worker_body(
        self, A: jnp.ndarray, B: jnp.ndarray, mask: jnp.ndarray
    ) -> jnp.ndarray:
        """A (t, r, D), B (r, s, D), mask (N,) replicated -> C (t, s, D) replicated.

        Executes encode-at-worker, local block product, all-gather + any-R
        decode.  Must run inside shard_map with these args replicated.
        """
        code, ring = self.code, self.code.ring
        i = lax.axis_index(self.axis)
        blocks_a = code.split_a(A)  # (uw, tb, rb, D)
        blocks_b = code.split_b(B)  # (wv, rb, sb, D)
        Ka, tb, rb, D = blocks_a.shape
        Kb, _, sb, _ = blocks_b.shape
        # this worker's Vandermonde rows (encode-at-worker)
        vf = _take_rows(code.Vf, i)  # (uw, D)
        vg = _take_rows(code.Vg, i)  # (wv, D)
        fa = ring.matmul(vf[None], blocks_a.reshape(Ka, tb * rb, D))[0]
        gb = ring.matmul(vg[None], blocks_b.reshape(Kb, rb * sb, D))[0]
        fa = fa.reshape(tb, rb, D)
        gb = gb.reshape(rb, sb, D)
        # local block product — the hot kernel
        if self.use_kernel:
            h = gr_matmul(fa, gb, ring)
        else:
            h = ring.matmul(fa, gb)
        # gather responses; decode replicated from the first R live workers
        H = lax.all_gather(h, self.axis)  # (N, tb, sb, D)
        idx = select_workers(mask, code.R)
        return code.decode(jnp.take(H, idx, axis=0), idx)

    def master_encode_body(self, A, B, mask):
        """Alternative: master-side Vandermonde encode, sharded over workers."""
        code, ring = self.code, self.code.ring
        i = lax.axis_index(self.axis)
        FA = code.encode_a(A)
        GB = code.encode_b(B)
        fa, gb = _take_rows(FA, i), _take_rows(GB, i)
        if self.use_kernel:
            h = gr_matmul(fa, gb, ring)
        else:
            h = ring.matmul(fa, gb)
        H = lax.all_gather(h, self.axis)
        idx = select_workers(mask, code.R)
        return code.decode(jnp.take(H, idx, axis=0), idx)

    def __call__(self, A, B, mask):
        if self.master_encode:
            return self.master_encode_body(A, B, mask)
        return self.worker_body(A, B, mask)


class DistributedBatchRMFE:
    """SPMD Batch-EP_RMFE: pack (replicated) -> DistributedEP -> unpack."""

    def __init__(self, scheme: BatchEPRMFE, axis_name: str, **kw):
        self.scheme = scheme
        self.dep = DistributedEP(scheme.code, axis_name, **kw)

    def __call__(self, As: jnp.ndarray, Bs: jnp.ndarray, mask: jnp.ndarray):
        """As, Bs: (n, t, r, D0) / (n, r, s, D0) replicated -> (n, t, s, D0)."""
        A = self.scheme.pack(As)
        B = self.scheme.pack(Bs)
        C = self.dep(A, B, mask)
        return self.scheme.unpack(C)


def cdmm_shard_map(
    fn,
    mesh: Mesh,
    axis_name: str,
):
    """Wrap a per-shard CDMM body into a shard_map with replicated operands.

    The worker axis carries no data sharding — CDMM's redundancy is in the
    *computation*; inputs are replicated (broadcast upload) and the decoded
    product is replicated (download).  Other mesh axes may shard the batch
    outside this wrapper.
    """
    spec = P()  # replicated

    def mapped(*args):
        return shard_map(
            fn,
            mesh=mesh,
            in_specs=tuple(spec for _ in args),
            out_specs=spec,
            check=False,
        )(*args)

    return mapped
