"""ServeScheduler: continuous batching between admission and the pool.

The paper's batch construction (Thm III.2) multiplies n independent
products at ~1/n of GCSA's recovery threshold — but it only pays off in a
service if n *concurrent requests* actually share one codeword.  This
engine sits where :class:`repro.dist.scheduler.PoolScheduler` sits (bounded
admission queue over one pool master) and adds the batch dimension:

admission   ``submit(A, B, spec)`` — per-request specs (``spec.n == 1``),
            bounded queue, :class:`SchedulerSaturated` on overflow;
planning    per spec, once: scan batch arities 1..``target_batch_n`` under
            the planner's ``"amortized"`` objective and keep the cheapest
            per-request configuration — a batched family at some fill
            (coalesce, cap = the scheme's RMFE pack size) or a single
            family (per-request dispatch, exactly PoolScheduler behavior);
coalescing  a :class:`~repro.serve.coalescer.BatchCoalescer` groups
            same-spec arrivals until the cap fills or the policy's wait
            budget expires (``max_wait_ms`` / adaptive idle);
execution   one ``Master.execute`` per batch: members stack on the leading
            batch axis, a partial final batch zero-pads up to the pack
            size (zero rows decode to exact zero products over the ring
            and are sliced off), and each member's Future resolves to its
            own slice of the decoded batch.

``privacy_t > 0`` specs ride the same path on ``ep_rmfe_secure``: one
derived key masks the whole batch (a batch IS one codeword), so coalesced
and sequential execution stay bit-identical under a caller-fixed key.

``request_timeout`` is a *deadline from submit* — queue wait, coalesce
wait and pool execution all spend the same budget.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.cdmm.api import CdmmScheme, ProblemSpec, get_scheme
from repro.cdmm.planner import plan
from repro.dist.scheduler import SchedulerSaturated
from repro.obs import http as obs_http
from repro.obs import trace as obs

from .coalescer import BatchCoalescer, CoalescePolicy
from .stats import ServeStats

__all__ = ["ServeScheduler"]

_WAKE = object()  # internal: queue.get timed out, run the expiry sweep


@dataclass
class _Member:
    """One admitted request: arrays pinned at submit, resolved by slice."""

    fut: Future
    A: np.ndarray
    B: np.ndarray
    key: Optional[object]
    t_submit: float
    rid: int = -1
    trace: Optional[obs.TraceContext] = None


@dataclass
class _SpecEntry:
    """The serving decision for one ProblemSpec, planned once.

    ``cap > 1``: coalesce up to ``cap`` requests into ``scheme`` (a batched
    adapter whose pack size is ``cap``).  ``cap == 1``: the amortized
    ranking found no batch arity that beats per-request dispatch, so
    ``scheme`` is the best single-product adapter and requests never wait
    for peers.
    """

    spec: ProblemSpec
    scheme: CdmmScheme
    cap: int
    label: str


class ServeScheduler:
    """Continuous-batching admission control over one pool master."""

    def __init__(
        self,
        master=None,
        policy: Optional[CoalescePolicy] = None,
        max_queue: int = 64,
        max_inflight: int = 4,
        objective: str = "amortized",
        request_timeout: Optional[float] = None,
        seed: Optional[int] = None,
        config=None,
    ):
        # config= (a repro.dist.PoolConfig) with no master: the engine
        # owns the pool it serves over — launched here, closed in close().
        # master=None with no config stays legal: planning entry points
        # (entry_for) never touch a pool until a request dispatches.
        self._owned_pool = None
        if master is None and config is not None:
            from repro.dist.launch import launch_pool

            self._owned_pool = launch_pool(config)
            master = self._owned_pool.master
        elif config is not None and request_timeout is None:
            request_timeout = config.request_timeout
        self.master = master
        self.policy = policy or CoalescePolicy()
        self.policy.validate()
        self.objective = objective
        self.request_timeout = request_timeout
        self.stats = ServeStats()
        # the admin HTTP plane scrapes this engine alongside its pool,
        # and /trace/<request_id> resolves through the engine's rid index
        self._obs_source = obs_http.register_source(
            "serve", self.stats.snapshot
        )
        obs_http.register_trace_resolver(self._resolve_trace)
        self._queue: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self._coalescer = BatchCoalescer(self.policy)
        self._entries: Dict[ProblemSpec, _SpecEntry] = {}
        self._entries_lock = threading.Lock()
        self._key_lock = threading.Lock()
        self._batch_seq = 0
        self._next_rid = 0
        # rid -> (request trace_id, carrier trace_id): a coalesced batch
        # records its pool spans once under the first member's trace (the
        # "carrier"); trace(rid) merges both (bounded, oldest roll off)
        self._trace_index: Dict[int, tuple] = {}
        self._trace_lock = threading.Lock()
        self._trace_index_cap = 1024
        import jax.random

        if seed is None:
            seed = int.from_bytes(os.urandom(4), "little")
        self._base_key = jax.random.PRNGKey(seed)
        self._closed = False
        self._pool = ThreadPoolExecutor(
            max_workers=max_inflight, thread_name_prefix="serve-exec"
        )
        self._thread = threading.Thread(
            target=self._coalesce_loop, name="serve-coalesce", daemon=True
        )
        self._thread.start()

    # -- planning ----------------------------------------------------------

    def entry_for(self, spec: ProblemSpec) -> _SpecEntry:
        """The (cached) serving decision for ``spec``: scan batch arities
        under the ``"amortized"`` objective, keep the cheapest per-request
        configuration, and build its executable scheme once."""
        with self._entries_lock:
            entry = self._entries.get(spec)
        if entry is not None:
            self.stats.bump("plan_cache_hits")
            return entry
        self.stats.bump("plan_cache_misses")

        # fill=1 first: ties go to per-request dispatch (never make a
        # request wait for peers unless coalescing strictly wins)
        choices = [(plan(spec, objective=self.objective, backend="pool"), 1)]
        for f in range(2, self.policy.target_batch_n + 1):
            try:
                pf = plan(
                    spec.with_batch(f), objective=self.objective,
                    backend="pool",
                )
            except ValueError:
                continue  # no feasible configuration at this arity
            if get_scheme(pf.best.scheme).batched:
                choices.append((pf, f))
        chosen, fill = min(choices, key=lambda c: c[0].best.score)
        scheme = chosen.instantiate()
        cap = scheme.batch if fill > 1 else 1
        entry = _SpecEntry(
            spec=spec,
            scheme=scheme,
            cap=cap,
            label=f"{scheme.name}[{spec.t}x{spec.r}x{spec.s}]",
        )
        with self._entries_lock:
            # a racing planner for the same spec wins idempotently
            entry = self._entries.setdefault(spec, entry)
        return entry

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        A,
        B,
        spec: ProblemSpec,
        key=None,
    ) -> Future:
        """Admit one request; returns a Future of this request's product.

        ``spec`` describes the *single* request (``spec.n == 1``) — batch
        arity is the engine's decision, not the caller's.  Raises
        :class:`~repro.dist.scheduler.SchedulerSaturated` when the
        admission queue is full.
        """
        if spec.n != 1:
            raise ValueError(
                f"serve coalesces per-request specs (n=1), got n={spec.n}; "
                f"batch arity is the engine's decision"
            )
        if self._closed:
            raise RuntimeError("scheduler is closed")
        entry = self.entry_for(spec)
        fut: Future = Future()
        with self._trace_lock:
            rid = self._next_rid
            self._next_rid += 1
        trace = obs.maybe_context("serve", request_id=rid)
        fut.request_id = rid
        fut.trace_id = trace.trace_id if trace is not None else None
        member = _Member(
            fut=fut,
            A=np.asarray(A),
            B=np.asarray(B),
            key=key,
            t_submit=time.perf_counter(),
            rid=rid,
            trace=trace,
        )
        try:
            self._queue.put_nowait((entry, member))
        except queue.Full:
            self.stats.bump("rejected")
            raise SchedulerSaturated(
                f"admission queue full ({self._queue.maxsize} waiting); "
                f"shed load or raise max_queue"
            ) from None
        self.stats.bump("submitted")
        return fut

    # -- coalescing --------------------------------------------------------

    def _coalesce_loop(self) -> None:
        while True:
            wait = self._coalescer.next_wait_s(
                time.perf_counter(), self._queue.empty()
            )
            try:
                item = self._queue.get(timeout=wait)
            except queue.Empty:
                item = _WAKE
            if item is None:  # close() sentinel: drain buffers and exit
                for _, items in self._coalescer.flush_all():
                    self._dispatch([m for _, m in items])
                return
            if item is not _WAKE:
                entry, member = item
                if entry.cap <= 1:
                    self._dispatch([(entry, member)])
                else:
                    full = self._coalescer.add(
                        entry.spec, (entry, member), entry.cap,
                        time.perf_counter(),
                    )
                    if full is not None:
                        self._dispatch(full)
            for _, items in self._coalescer.due(
                time.perf_counter(), self._queue.empty()
            ):
                self._dispatch(items)

    def _dispatch(self, items: List) -> None:
        """Hand one batch (list of (entry, member)) to an executor slot."""
        entry = items[0][0]
        members = [m for _, m in items]
        try:
            self._pool.submit(self._run_batch, entry, members)
        except RuntimeError as e:  # executor already shut down
            for m in members:
                if not m.fut.done():
                    m.fut.set_exception(e)

    # -- execution ---------------------------------------------------------

    def _batch_key(self, members: List[_Member]):
        """One key masks the whole batch (it is one codeword): the first
        caller-provided key wins, else derive a fresh per-batch key."""
        for m in members:
            if m.key is not None:
                return m.key
        import jax.random

        with self._key_lock:
            seq = self._batch_seq
            self._batch_seq += 1
        return jax.random.fold_in(self._base_key, seq)

    def _run_batch(self, entry: _SpecEntry, members: List[_Member]) -> None:
        now = time.perf_counter()
        active = []
        for m in members:
            if m.fut.set_running_or_notify_cancel():
                active.append(m)
            else:
                self.stats.bump("cancelled")
        if self.request_timeout is not None:
            still = []
            for m in active:
                if now - m.t_submit >= self.request_timeout:
                    self.stats.bump("timed_out")
                    m.fut.set_exception(TimeoutError(
                        f"request spent its {self.request_timeout}s budget "
                        f"waiting (queue + coalesce) before dispatch"
                    ))
                else:
                    still.append(m)
            active = still
        if not active:
            return
        scheme = entry.scheme
        fill = len(active)
        waits_ms = [(now - m.t_submit) * 1e3 for m in active]
        timeout = None
        if self.request_timeout is not None:
            # the earliest member's remaining budget bounds the whole batch
            timeout = min(
                m.t_submit + self.request_timeout for m in active
            ) - now
        key = None
        if scheme.privacy_t > 0:
            key = self._batch_key(active)
        # one batch = one pool execution: its pool/worker spans record
        # once, under the first traced member (the carrier); every
        # member's trace(rid) merges the carrier timeline back in
        carrier = next((m.trace for m in active if m.trace is not None),
                       None)
        if carrier is not None:
            t_now = obs.now()
            tracer = obs.tracer()
            with self._trace_lock:
                for m in active:
                    if m.trace is None:
                        continue
                    self._trace_index[m.rid] = (
                        m.trace.trace_id, carrier.trace_id
                    )
                while len(self._trace_index) > self._trace_index_cap:
                    self._trace_index.pop(next(iter(self._trace_index)))
            for m, wait_ms in zip(active, waits_ms):
                tracer.add(
                    m.trace, "coalesce_wait", "serve",
                    t_now - wait_ms / 1e3, t_now,
                    batch=scheme.batch, fill=fill, label=entry.label,
                )
        try:
            if entry.cap > 1:
                pad = scheme.batch - fill
                zA = np.zeros_like(active[0].A)
                zB = np.zeros_like(active[0].B)
                As = np.stack([m.A for m in active] + [zA] * pad)
                Bs = np.stack([m.B for m in active] + [zB] * pad)
                C, pstats = self.master.execute(
                    scheme, As, Bs, key=key, timeout=timeout,
                    batch_fill=fill, trace=carrier,
                )
                for j, m in enumerate(active):
                    m.fut.set_result(np.asarray(C[j]))
            else:
                pad = 0
                m = active[0]
                C, pstats = self.master.execute(
                    scheme, m.A, m.B, key=key, timeout=timeout, trace=carrier
                )
                m.fut.set_result(np.asarray(C))
            self.stats.bump("completed", fill)
            self.stats.record_batch(
                entry.label, fill, pad, pstats.wall_ms, waits_ms
            )
        except BaseException as e:
            self.stats.bump(
                "timed_out" if isinstance(e, TimeoutError) else "failed",
                fill,
            )
            for m in active:
                if not m.fut.done():
                    m.fut.set_exception(e)

    # -- tracing -----------------------------------------------------------

    def trace(self, request_id) -> obs.Timeline:
        """The merged end-to-end timeline of one request: coalesce wait,
        the batch's per-share encode/send, every responder's compute span
        (late arrivals and post-SIGKILL re-dispatches included), the
        any-R wait and decode.

        Accepts the Future returned by :meth:`submit` (its ``request_id``
        attribute) or the request id itself.  Spans of the batch the
        request rode in are merged from the carrier trace, so coalesced
        peers share the same pool/worker spans.  Raises ``KeyError``
        until the request has dispatched (or if it rolled off the
        bounded index), ``ValueError`` when tracing was disabled.
        """
        if not obs.enabled():
            raise ValueError(
                "tracing is disabled (enable with REPRO_TRACE=1, --trace, "
                "or repro.obs.set_enabled(True) before submit)"
            )
        rid = getattr(request_id, "request_id", request_id)
        with self._trace_lock:
            pair = self._trace_index.get(rid)
        if pair is None:
            raise KeyError(
                f"request {rid!r} has no dispatched trace (not yet "
                f"dispatched, never submitted, or rolled off the index)"
            )
        tid, carrier_tid = pair
        linked = (carrier_tid,) if carrier_tid != tid else ()
        return obs.tracer().timeline(tid, *linked)

    def _resolve_trace(self, key: str):
        """HTTP /trace/<request_id> hook: serve request ids are ints."""
        try:
            rid = int(key)
        except (TypeError, ValueError):
            return None
        try:
            return self.trace(rid)
        except (KeyError, ValueError):
            return None

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Drain: buffered partial batches execute, then dispatchers stop.
        Requests admitted after close() raise; stragglers that raced the
        sentinel into the queue are cancelled."""
        if self._closed:
            return
        self._closed = True
        obs_http.unregister_source(self._obs_source)
        obs_http.unregister_trace_resolver(self._resolve_trace)
        self._queue.put(None)
        self._thread.join(timeout=60)
        self._pool.shutdown(wait=True)
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None and item is not _WAKE:
                item[1].fut.cancel()
        if self._owned_pool is not None:
            self._owned_pool.close()
            self._owned_pool = None

    def __enter__(self) -> "ServeScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
