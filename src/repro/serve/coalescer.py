"""BatchCoalescer: group concurrent same-spec requests into batch slots.

This is the policy half of continuous batching, kept free of threads,
sockets and jax so the latency/throughput trade is unit-testable with a
synthetic clock: the engine thread feeds requests in arrival order and the
coalescer decides *when a buffer becomes a batch*:

  * the moment it reaches its ``cap`` (the RMFE pack size of the planned
    batch scheme — never beyond, a packed codeword has exactly that many
    slots), or
  * when the oldest member has waited ``max_wait_ms`` (the latency bound:
    no request waits for peers longer than the knob allows), or
  * in ``adaptive`` mode, when arrivals pause — the buffer is flushed once
    ``adaptive_idle_ms`` passes without a new same-spec request while the
    admission queue is empty.  Deep queues therefore fill batches to cap
    (arrivals keep refreshing the idle clock as fast as the engine drains
    them) while an idle service degenerates to per-request dispatch with
    ~``adaptive_idle_ms`` added latency instead of always paying
    ``max_wait_ms``.

Requests only ever coalesce within one buffer key — the engine keys
buffers by the full ``ProblemSpec`` — so mixed-spec streams can never pack
into one codeword (property-tested in tests/test_serve.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple

__all__ = ["BatchCoalescer", "CoalescePolicy"]


@dataclass(frozen=True)
class CoalescePolicy:
    """The latency/throughput knob of the serving engine.

    ``target_batch_n`` is the concurrency the planner prices coalescing at
    (an upper bound on the searched batch arity, not a promise: the
    ``"amortized"`` objective may choose a smaller fill — or reject
    coalescing entirely and fall back to per-request dispatch).
    ``max_wait_ms`` bounds how long any request waits for peers.
    ``adaptive`` flushes partial batches as soon as arrivals pause instead
    of sitting out the full wait (see module docstring).
    """

    target_batch_n: int = 8
    max_wait_ms: float = 5.0
    adaptive: bool = False
    adaptive_idle_ms: float = 0.5

    def validate(self) -> None:
        if self.target_batch_n < 1:
            raise ValueError(
                f"target_batch_n must be >= 1, got {self.target_batch_n}"
            )
        if self.max_wait_ms < 0 or self.adaptive_idle_ms < 0:
            raise ValueError("wait knobs must be >= 0")


@dataclass
class _Buffer:
    cap: int
    first_s: float  # arrival of the oldest member (monotonic seconds)
    last_s: float  # arrival of the newest member
    items: List = field(default_factory=list)


class BatchCoalescer:
    """Per-key request buffers governed by one :class:`CoalescePolicy`."""

    def __init__(self, policy: CoalescePolicy):
        policy.validate()
        self.policy = policy
        self._buffers: Dict[Hashable, _Buffer] = {}

    # -- feeding -----------------------------------------------------------

    def add(
        self, key: Hashable, item, cap: int, now_s: float
    ) -> Optional[List]:
        """Buffer one request under ``key``; returns the full batch the
        moment the buffer reaches ``cap`` (and removes it), else None."""
        buf = self._buffers.get(key)
        if buf is None:
            buf = self._buffers[key] = _Buffer(
                cap=cap, first_s=now_s, last_s=now_s
            )
        buf.cap = cap
        buf.last_s = now_s
        buf.items.append(item)
        if len(buf.items) >= buf.cap:
            del self._buffers[key]
            return buf.items
        return None

    # -- draining ----------------------------------------------------------

    def _deadline_s(self, buf: _Buffer, queue_empty: bool) -> float:
        deadline = buf.first_s + self.policy.max_wait_ms / 1e3
        if self.policy.adaptive and queue_empty:
            deadline = min(
                deadline, buf.last_s + self.policy.adaptive_idle_ms / 1e3
            )
        return deadline

    def due(
        self, now_s: float, queue_empty: bool = True
    ) -> List[Tuple[Hashable, List]]:
        """Pop every buffer whose wait budget is spent at ``now_s``."""
        out = []
        for key, buf in list(self._buffers.items()):
            if now_s >= self._deadline_s(buf, queue_empty):
                del self._buffers[key]
                out.append((key, buf.items))
        return out

    def next_wait_s(
        self, now_s: float, queue_empty: bool = True
    ) -> Optional[float]:
        """Seconds until the earliest buffer expires (None: nothing
        buffered, the engine may block on admissions indefinitely)."""
        if not self._buffers:
            return None
        earliest = min(
            self._deadline_s(buf, queue_empty)
            for buf in self._buffers.values()
        )
        return max(earliest - now_s, 0.0)

    def flush_all(self) -> List[Tuple[Hashable, List]]:
        """Pop every buffer regardless of wait budget (shutdown drain)."""
        out = [(key, buf.items) for key, buf in self._buffers.items()]
        self._buffers.clear()
        return out

    def pending(self) -> int:
        return sum(len(b.items) for b in self._buffers.values())
