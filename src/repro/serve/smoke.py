"""Serving smoke: coalesce concurrent requests, check fill and the bits.

The CI ``serving-smoke`` job runs this as its merge gate for the
continuous-batching engine::

    python -m repro.serve.smoke --workers 6 --requests 32

It spawns a ``--workers``-process LocalPool, submits ``--requests``
concurrent same-shape requests through :class:`ServeScheduler`, and
asserts (a) the engine actually coalesced — mean batch fill > 1 under the
``"amortized"`` objective's decision — and (b) every per-request result is
bit-identical to the plain ``A @ B`` oracle.  Exit code 0 = pass.

With ``--trace`` every request runs under a :mod:`repro.obs` trace and the
last request's merged timeline (serve admission -> coalesce wait -> pool
encode/send -> per-worker compute -> any-R decode) is validated against
the span schema: non-empty, monotone span times, compute spans from at
least R responders.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

# deterministic plans: the smoke asserts the analytic amortized decision
# (n=2 RMFE-batch over Z_2^32), so a host-specific calibration fit must
# not re-rank it
os.environ.setdefault("REPRO_CALIBRATION", "off")

import numpy as np


def run_smoke(
    workers: int = 6,
    requests: int = 32,
    size: int = 128,
    wait_ms: float = 50.0,
    target_batch: int = 8,
    privacy_t: int = 0,
    seed: int = 0,
    trace: bool = False,
) -> int:
    from repro.cdmm import ProblemSpec
    from repro.core import make_ring
    from repro.dist import LocalPool
    from repro.serve import CoalescePolicy, ServeScheduler

    if trace:
        from repro import obs

        obs.set_enabled(True)

    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=1, privacy_t=privacy_t,
    )
    rng = np.random.default_rng(seed)
    pairs = [
        (Z32.random(rng, (size, size)), Z32.random(rng, (size, size)))
        for _ in range(requests)
    ]
    oracles = [np.asarray(Z32.matmul(A, B)) for A, B in pairs]

    with LocalPool(workers=workers) as pool:
        policy = CoalescePolicy(
            target_batch_n=target_batch, max_wait_ms=wait_ms
        )
        with ServeScheduler(
            pool.master, policy, max_queue=requests, seed=seed
        ) as sched:
            entry = sched.entry_for(spec)
            print(f"pool up: {workers} workers; amortized plan: "
                  f"{entry.scheme.name} N={entry.scheme.N} "
                  f"R={entry.scheme.R} coalesce cap={entry.cap}")
            futs = [sched.submit(A, B, spec=spec) for A, B in pairs]
            results = [np.asarray(f.result(timeout=600)) for f in futs]
            snap = sched.stats.snapshot()
            if trace:
                from repro import obs

                timeline = sched.trace(futs[-1])
                problems = obs.validate_timeline(
                    timeline.to_json(),
                    min_workers=entry.scheme.R,
                    require_components=("serve", "pool", "worker"),
                )
                if problems:
                    for p in problems:
                        print(f"FAIL trace: {p}")
                    return 1
                comps = sorted({s.component for s in timeline.spans})
                print(f"trace {timeline.trace_id}: {len(timeline.spans)} "
                      f"spans across components {comps}, "
                      f"{timeline.wall_s * 1e3:.0f} ms wall")

    bad = [i for i, (C, want) in enumerate(zip(results, oracles))
           if not np.array_equal(C, want)]
    print(json.dumps({k: snap[k] for k in (
        "serve_submitted", "serve_completed", "serve_batches",
        "serve_coalesced_batches", "serve_mean_fill", "serve_total_pad",
        "serve_amortized_us_per_request", "serve_wait_ms_p50",
        "serve_wait_ms_p99",
    )}, indent=2))
    if bad:
        print(f"FAIL: {len(bad)}/{requests} results differ from the "
              f"A @ B oracle (first bad index: {bad[0]})")
        return 1
    if snap["serve_completed"] != requests:
        print(f"FAIL: {snap['serve_completed']}/{requests} requests "
              f"completed")
        return 1
    if snap["serve_mean_fill"] <= 1.0 or snap["serve_coalesced_batches"] < 1:
        print(f"FAIL: engine never coalesced (mean fill "
              f"{snap['serve_mean_fill']:.2f}, "
              f"{snap['serve_coalesced_batches']} coalesced batches)")
        return 1
    print(f"SERVE SMOKE OK: {requests} requests in {snap['serve_batches']} "
          f"batch jobs (mean fill {snap['serve_mean_fill']:.2f}), every "
          f"result bit-identical to the oracle")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--wait-ms", type=float, default=50.0)
    ap.add_argument("--target-batch", type=int, default=8)
    ap.add_argument("--privacy-t", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", action="store_true",
                    help="trace every request and validate the last "
                         "request's merged span timeline")
    args = ap.parse_args(argv)
    return run_smoke(args.workers, args.requests, args.size, args.wait_ms,
                     args.target_batch, args.privacy_t, args.seed,
                     trace=args.trace)


if __name__ == "__main__":
    sys.exit(main())
