"""Serving smoke: coalesce concurrent requests, check fill and the bits.

The CI ``serving-smoke`` job runs this as its merge gate for the
continuous-batching engine::

    python -m repro.serve.smoke --workers 6 --requests 32

It spawns a ``--workers``-process LocalPool, submits ``--requests``
concurrent same-shape requests through :class:`ServeScheduler`, and
asserts (a) the engine actually coalesced — mean batch fill > 1 under the
``"amortized"`` objective's decision — and (b) every per-request result is
bit-identical to the plain ``A @ B`` oracle.  Exit code 0 = pass.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

# deterministic plans: the smoke asserts the analytic amortized decision
# (n=2 RMFE-batch over Z_2^32), so a host-specific calibration fit must
# not re-rank it
os.environ.setdefault("REPRO_CALIBRATION", "off")

import numpy as np


def run_smoke(
    workers: int = 6,
    requests: int = 32,
    size: int = 128,
    wait_ms: float = 50.0,
    target_batch: int = 8,
    privacy_t: int = 0,
    seed: int = 0,
) -> int:
    from repro.cdmm import ProblemSpec
    from repro.core import make_ring
    from repro.dist import LocalPool
    from repro.serve import CoalescePolicy, ServeScheduler

    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=1, privacy_t=privacy_t,
    )
    rng = np.random.default_rng(seed)
    pairs = [
        (Z32.random(rng, (size, size)), Z32.random(rng, (size, size)))
        for _ in range(requests)
    ]
    oracles = [np.asarray(Z32.matmul(A, B)) for A, B in pairs]

    with LocalPool(workers=workers) as pool:
        policy = CoalescePolicy(
            target_batch_n=target_batch, max_wait_ms=wait_ms
        )
        with ServeScheduler(
            pool.master, policy, max_queue=requests, seed=seed
        ) as sched:
            entry = sched.entry_for(spec)
            print(f"pool up: {workers} workers; amortized plan: "
                  f"{entry.scheme.name} N={entry.scheme.N} "
                  f"R={entry.scheme.R} coalesce cap={entry.cap}")
            futs = [sched.submit(A, B, spec=spec) for A, B in pairs]
            results = [np.asarray(f.result(timeout=600)) for f in futs]
            snap = sched.stats.snapshot()

    bad = [i for i, (C, want) in enumerate(zip(results, oracles))
           if not np.array_equal(C, want)]
    print(json.dumps({k: snap[k] for k in (
        "submitted", "completed", "batches", "coalesced_batches",
        "mean_fill", "total_pad", "amortized_us_per_request",
        "wait_ms_p50", "wait_ms_p99",
    )}, indent=2))
    if bad:
        print(f"FAIL: {len(bad)}/{requests} results differ from the "
              f"A @ B oracle (first bad index: {bad[0]})")
        return 1
    if snap["completed"] != requests:
        print(f"FAIL: {snap['completed']}/{requests} requests completed")
        return 1
    if snap["mean_fill"] <= 1.0 or snap["coalesced_batches"] < 1:
        print(f"FAIL: engine never coalesced (mean fill "
              f"{snap['mean_fill']:.2f}, "
              f"{snap['coalesced_batches']} coalesced batches)")
        return 1
    print(f"SERVE SMOKE OK: {requests} requests in {snap['batches']} "
          f"batch jobs (mean fill {snap['mean_fill']:.2f}), every result "
          f"bit-identical to the oracle")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--wait-ms", type=float, default=50.0)
    ap.add_argument("--target-batch", type=int, default=8)
    ap.add_argument("--privacy-t", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_smoke(args.workers, args.requests, args.size, args.wait_ms,
                     args.target_batch, args.privacy_t, args.seed)


if __name__ == "__main__":
    sys.exit(main())
