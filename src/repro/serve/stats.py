"""ServeStats: the observability surface of the continuous-batching engine.

Everything the latency/throughput policy trades off is counted here so the
trade is inspectable while the engine runs: how full the coalesced batches
actually are (per-batch fill and padded slots), how long requests waited
for peers (a fixed-bucket wait-time histogram — admission-to-execution,
so queue time is never hidden), and what a request effectively costs once
batch execution is amortized over its fill (``amortized_us_per_request``).

Snapshots follow the shared :mod:`repro.stats` schema — ``serve_``-
prefixed counters plus the ``serve_wait_ms_hist`` / ``serve_wait_ms_p50``
/ ``serve_wait_ms_p99`` triple from :class:`repro.stats.Histogram`
(legacy unprefixed keys resolve with a one-time deprecation warning) —
so they merge cleanly with the pool
master's and scheduler's snapshots via :func:`repro.stats.merge_snapshots`
(``launch/serve.py --stats-every`` prints the merged view, and
``benchmarks/bench_serving.py`` records it next to the unbatched
baseline).
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Sequence, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.stats import StatsSnapshot

__all__ = ["ServeStats", "WAIT_BUCKETS_MS"]

# upper edges (ms) of the wait-time histogram; the last bucket is open
WAIT_BUCKETS_MS: Tuple[float, ...] = (
    0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
    math.inf,
)

RECENT_BATCHES = 64  # bounded per-batch log (spec label, fill, pad, wall)


class ServeStats:
    """Thread-safe counters + histograms for one serving engine.

    Registry-backed: every bump lands in a live
    :class:`repro.obs.metrics.MetricsRegistry` (the same numbers the
    HTTP ``/metrics``/``/stats`` plane scrapes continuously), and the
    legacy attribute reads (``stats.completed``) resolve to the live
    counter values.
    """

    _COUNTERS = (
        "submitted", "rejected", "completed", "failed", "timed_out",
        "cancelled", "batches", "coalesced_batches", "total_fill",
        "total_pad", "plan_cache_hits", "plan_cache_misses",
    )

    def __init__(self) -> None:
        self._lock = threading.Lock()  # guards the recent-batch deque
        self.metrics = MetricsRegistry("serve")
        for name, doc in (
            ("submitted", "requests admitted"),
            ("rejected", "requests shed at the bounded admission queue"),
            ("completed", "requests resolved with a product"),
            ("failed", "requests that raised"),
            ("timed_out", "requests that spent their deadline"),
            ("cancelled", "requests cancelled before dispatch"),
            ("batches", "batch jobs executed"),
            ("coalesced_batches", "batch jobs with fill > 1"),
            ("total_fill", "request slots served across all batches"),
            ("total_pad", "RMFE slots padded with zeros (wasted packing)"),
            ("plan_cache_hits", "serving decisions answered from cache"),
            ("plan_cache_misses", "serving decisions planned fresh"),
        ):
            self.metrics.counter(name, doc)
        self._counters = {
            name: self.metrics.counter(name) for name in self._COUNTERS
        }
        # summed master wall-clock of batch jobs (float counter)
        self._exec_wall = self.metrics.counter(
            "exec_wall_ms", "summed master wall-clock of batch jobs (ms)"
        )
        self.wait_ms = self.metrics.histogram(
            "wait_ms", "admission -> execution wait (ms)",
            bounds=WAIT_BUCKETS_MS,
        )
        self.metrics.gauge("mean_fill", "mean requests per executed batch")
        self.recent: "deque" = deque(maxlen=RECENT_BATCHES)

    # -- recording ---------------------------------------------------------

    def bump(self, name: str, by: int = 1) -> None:
        self._counters[name].inc(by)

    def record_batch(
        self,
        label: str,
        fill: int,
        pad: int,
        wall_ms: float,
        waits_ms: Sequence[float],
    ) -> None:
        """One executed batch job: ``fill`` requests served, ``pad`` zero
        slots, master wall-clock, and each member's admission->execute wait."""
        self.bump("batches")
        if fill > 1:
            self.bump("coalesced_batches")
        self.bump("total_fill", fill)
        self.bump("total_pad", pad)
        self._exec_wall.inc(wall_ms)
        with self._lock:
            self.recent.append(
                {"spec": label, "fill": fill, "pad": pad,
                 "wall_ms": round(wall_ms, 3)}
            )
        for w in waits_ms:
            self.wait_ms.observe(w)

    # -- reading -----------------------------------------------------------

    def __getattr__(self, name: str):
        # legacy attribute reads resolve to the live counter values;
        # __getattr__ only fires for names missing from __dict__
        counters = self.__dict__.get("_counters") or {}
        if name in counters:
            return counters[name].value
        if name == "exec_wall_ms":
            exec_wall = self.__dict__.get("_exec_wall")
            if exec_wall is not None:
                return exec_wall.value
        raise AttributeError(name)

    def snapshot(self) -> StatsSnapshot:
        """Every counter plus the derived serving signals (mean fill,
        wait quantiles, amortized us/request) in the shared repro.stats
        schema (``serve_``-prefixed keys; the legacy unprefixed names
        resolve with one DeprecationWarning).  Safe to call from any
        thread at any time."""
        batches = self._counters["batches"].value
        total_fill = self._counters["total_fill"].value
        exec_ms = float(self._exec_wall.value)
        self.metrics.gauge("mean_fill").set(
            total_fill / batches if batches else 0.0
        )
        with self._lock:
            recent = list(self.recent)
        snap = self.metrics.snapshot(extra={
            "amortized_us_per_request": (
                exec_ms * 1e3 / total_fill if total_fill else None
            ),
            "recent_batches": recent,
        })
        snap["serve_exec_wall_ms"] = round(exec_ms, 3)
        return snap
