"""ServeStats: the observability surface of the continuous-batching engine.

Everything the latency/throughput policy trades off is counted here so the
trade is inspectable while the engine runs: how full the coalesced batches
actually are (per-batch fill and padded slots), how long requests waited
for peers (a fixed-bucket wait-time histogram — admission-to-execution,
so queue time is never hidden), and what a request effectively costs once
batch execution is amortized over its fill (``amortized_us_per_request``).

Snapshots follow the shared :mod:`repro.stats` schema — ``serve_``-
prefixed counters plus the ``serve_wait_ms_hist`` / ``serve_wait_ms_p50``
/ ``serve_wait_ms_p99`` triple from :class:`repro.stats.Histogram`
(legacy unprefixed keys resolve with a one-time deprecation warning) —
so they merge cleanly with the pool
master's and scheduler's snapshots via :func:`repro.stats.merge_snapshots`
(``launch/serve.py --stats-every`` prints the merged view, and
``benchmarks/bench_serving.py`` records it next to the unbatched
baseline).
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, Sequence, Tuple

from repro.stats import Histogram, StatsSnapshot, namespaced

__all__ = ["ServeStats", "WAIT_BUCKETS_MS"]

# upper edges (ms) of the wait-time histogram; the last bucket is open
WAIT_BUCKETS_MS: Tuple[float, ...] = (
    0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
    math.inf,
)

RECENT_BATCHES = 64  # bounded per-batch log (spec label, fill, pad, wall)


class ServeStats:
    """Thread-safe counters + histograms for one serving engine."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.failed = 0
        self.timed_out = 0
        self.cancelled = 0
        self.batches = 0
        self.coalesced_batches = 0  # batches with fill > 1
        self.total_fill = 0
        self.total_pad = 0  # RMFE slots padded with zeros (wasted packing)
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        self.exec_wall_ms = 0.0  # summed master wall-clock of batch jobs
        self.wait_ms = Histogram(WAIT_BUCKETS_MS)
        self.recent: "deque" = deque(maxlen=RECENT_BATCHES)

    # -- recording ---------------------------------------------------------

    def bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + by)

    def record_batch(
        self,
        label: str,
        fill: int,
        pad: int,
        wall_ms: float,
        waits_ms: Sequence[float],
    ) -> None:
        """One executed batch job: ``fill`` requests served, ``pad`` zero
        slots, master wall-clock, and each member's admission->execute wait."""
        with self._lock:
            self.batches += 1
            if fill > 1:
                self.coalesced_batches += 1
            self.total_fill += fill
            self.total_pad += pad
            self.exec_wall_ms += wall_ms
            self.recent.append(
                {"spec": label, "fill": fill, "pad": pad,
                 "wall_ms": round(wall_ms, 3)}
            )
        for w in waits_ms:
            self.wait_ms.observe(w)

    # -- reading -----------------------------------------------------------

    def snapshot(self) -> StatsSnapshot:
        """A copy of every counter, taken under the lock, plus the derived
        serving signals (mean fill, wait quantiles, amortized us/request)
        in the shared repro.stats schema (``serve_``-prefixed keys; the
        legacy unprefixed names resolve with one DeprecationWarning).
        Safe to call from any thread at any time."""
        with self._lock:
            counters = {
                k: getattr(self, k)
                for k in (
                    "submitted", "rejected", "completed", "failed",
                    "timed_out", "cancelled", "batches", "coalesced_batches",
                    "total_fill", "total_pad", "plan_cache_hits",
                    "plan_cache_misses",
                )
            }
            exec_ms = self.exec_wall_ms
            recent = list(self.recent)
        counters["exec_wall_ms"] = round(exec_ms, 3)
        counters["mean_fill"] = (
            counters["total_fill"] / counters["batches"]
            if counters["batches"] else 0.0
        )
        counters["amortized_us_per_request"] = (
            exec_ms * 1e3 / counters["total_fill"]
            if counters["total_fill"] else None
        )
        counters.update(self.wait_ms.snapshot("wait_ms"))
        counters["recent_batches"] = recent
        return namespaced("serve", counters)
