"""repro.serve: continuous-batching serving over the pool runtime.

The subsystem that makes the paper's batch half load-bearing in
production shape: concurrent same-``ProblemSpec`` requests coalesce into
one ``batch_ep_rmfe`` / ``ep_rmfe_secure`` codeword (dynamic fill, padded
final batch, per-request slices out of the decoded batch), governed by a
latency/throughput policy and the planner's ``"amortized"`` objective.

    pool = LocalPool(workers=6)
    with ServeScheduler(pool.master, CoalescePolicy(max_wait_ms=10)) as s:
        futs = [s.submit(A, B, spec=spec) for (A, B) in requests]
        results = [f.result() for f in futs]
        print(s.stats.snapshot()["mean_fill"])
"""
from .coalescer import BatchCoalescer, CoalescePolicy
from .engine import ServeScheduler
from .stats import ServeStats

__all__ = [
    "BatchCoalescer",
    "CoalescePolicy",
    "ServeScheduler",
    "ServeStats",
]
