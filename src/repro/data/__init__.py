"""Deterministic sharded data pipelines."""
from .pipeline import DataConfig, TokenPipeline
