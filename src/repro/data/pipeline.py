"""Deterministic sharded data pipeline.

Two sources:
  * synthetic: seeded per (epoch-less) step index — restart at step k replays
    exactly the same batches (fault-tolerance requirement: checkpoint stores
    only the step counter, no loader state).
  * binfile: memory-mapped flat token file (uint16/uint32), strided by
    (step, shard) so every data shard reads a disjoint slice.

Batches are host numpy; the launcher device_puts them with the batch
sharding. For the multi-pod dry-run only ShapeDtypeStructs are used.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    source: str = "synthetic"  # synthetic | markov | binfile
    path: Optional[str] = None
    seed: int = 1234
    dtype: str = "uint16"


def _synth_tokens(seed: int, step: int, shard: int, shape, vocab: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard]))
    return rng.integers(0, vocab, shape, dtype=np.int64).astype(np.int32)


def _markov_tokens(seed: int, step: int, shard: int, shape, vocab: int) -> np.ndarray:
    """Learnable synthetic stream: per-row arithmetic progressions mod V.

    A model that infers the stride from context predicts every next token —
    gives real loss curves on CPU-scale runs without shipping a corpus."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard, 3]))
    B, S = shape
    start = rng.integers(0, vocab, (B, 1))
    stride = rng.integers(1, min(64, vocab - 1), (B, 1))
    idx = np.arange(S)[None, :]
    return ((start + stride * idx) % vocab).astype(np.int32)


class TokenPipeline:
    """Yields {tokens, labels} host batches for a (model, shape) cell."""

    def __init__(
        self,
        dcfg: DataConfig,
        mcfg: ModelConfig,
        shape: ShapeConfig,
        shard: int = 0,
        num_shards: int = 1,
    ):
        self.dcfg, self.mcfg, self.shape = dcfg, mcfg, shape
        self.shard, self.num_shards = shard, num_shards
        # ceil so a degraded shard count still covers the global batch
        self.local_batch = max(1, -(-shape.global_batch // num_shards))
        self._mm = None
        if dcfg.source == "binfile":
            assert dcfg.path, "binfile source needs a path"
            self._mm = np.memmap(dcfg.path, dtype=np.dtype(dcfg.dtype), mode="r")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.local_batch, self.shape.seq_len
        V = self.mcfg.vocab_size
        if self.dcfg.source == "markov":
            toks = _markov_tokens(self.dcfg.seed, step, self.shard, (B, S + 1), V)
        elif self._mm is None:
            toks = _synth_tokens(self.dcfg.seed, step, self.shard, (B, S + 1), V)
        else:
            n = len(self._mm)
            span = B * (S + 1)
            start = (step * self.num_shards + self.shard) * span % max(n - span, 1)
            flat = np.asarray(self._mm[start : start + span], dtype=np.int64)
            toks = (flat % V).astype(np.int32).reshape(B, S + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # extra modalities (stub frontends per assignment) -----------------------

    def with_frontend(self, batch: Dict[str, np.ndarray], step: int) -> Dict:
        cfg = self.mcfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self.dcfg.seed, step, self.shard, 7])
        )
        if cfg.frontend == "patch":
            B = batch["tokens"].shape[0]
            batch = dict(batch)
            batch["patches"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.frontend_dim)
            ).astype(np.float32)
        elif cfg.frontend == "frames":
            B, S = batch["tokens"].shape
            Ssrc = max(S // cfg.src_ratio, 16)
            batch = dict(batch)
            batch["frames"] = rng.standard_normal((B, Ssrc, cfg.frontend_dim)).astype(
                np.float32
            )
        return batch
