"""Pallas TPU kernels for the CDMM hot paths (validated via interpret mode).

gr_matmul: blocked Galois-ring matmul (worker compute, encode, decode).
"""
from .ops import coded_encode, gr_matmul, kernel_supported, pick_blocks
from .ref import gr_matmul_planar_ref, gr_matmul_ref

__all__ = [
    "gr_matmul",
    "coded_encode",
    "kernel_supported",
    "pick_blocks",
    "gr_matmul_ref",
    "gr_matmul_planar_ref",
]
