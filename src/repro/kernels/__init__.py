"""Pallas TPU kernels for the CDMM hot paths (validated via interpret mode).

gr_matmul: blocked Galois-ring matmul (worker compute, encode, decode).
autotune: measured block-size search + persisted cache consulted by ops.
"""
# NB: the tuner entry point lives at repro.kernels.autotune.autotune —
# re-exporting the function here would shadow the submodule attribute
from .autotune import cached_blocks, candidate_blocks, tune_key
from .ops import (
    coded_encode,
    gr_matmul,
    kernel_auto_enabled,
    kernel_supported,
    pick_blocks,
)
from .ref import gr_matmul_planar_ref, gr_matmul_ref

__all__ = [
    "gr_matmul",
    "coded_encode",
    "kernel_supported",
    "kernel_auto_enabled",
    "pick_blocks",
    "gr_matmul_ref",
    "gr_matmul_planar_ref",
    "cached_blocks",
    "candidate_blocks",
    "tune_key",
]
