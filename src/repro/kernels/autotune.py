"""Kernel autotuner: measured (bt, bs, br) block sizes for gr_matmul_planar.

The CDMM hot loop ran with a static 128^3 block default; the right block
shape depends on the ring (D controls the unrolled dot count, K the VMEM
accumulator footprint), the problem tile and the device.  This module
searches a *divisor-aware* candidate grid per
``(device, ring.D, ring.K, T, S, R)`` point, times each candidate through
the benchmark harness's median-wall-clock helper, and persists the winner
to a committed JSON cache (``autotune_cache.json`` next to this file) with
an in-process LRU on top.  ``ops.gr_matmul`` consults the cache whenever the
caller does not pin ``blocks`` explicitly, so every backend (local,
shard_map, elastic) inherits tuned schedules transparently.

CLI (the CI ``autotune-smoke`` job runs this in a bounded ``--budget``
mode and verifies the committed cache still covers the tier-1 points):

    python -m repro.kernels.autotune --budget 6            # retune DEFAULT_POINTS
    python -m repro.kernels.autotune --check               # validate committed cache
    python -m repro.kernels.autotune --out /tmp/cache.json # write elsewhere

Determinism: candidate enumeration is a pure function of the key (sorted,
no RNG), so two runs disagree only through timing noise; the cache keeps
the measured us alongside the winner for later inspection.
"""
from __future__ import annotations

import argparse
import json
import time
from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.galois import Ring, make_ring

from .gr_matmul import MAX_D, _round_up, gr_matmul_planar

__all__ = [
    "CACHE_PATH",
    "DEFAULT_POINTS",
    "TuneResult",
    "autotune",
    "cached_blocks",
    "candidate_blocks",
    "load_cache",
    "save_cache",
    "tune_key",
]

CACHE_PATH = Path(__file__).with_name("autotune_cache.json")
CACHE_VERSION = 1

# MXU-aligned block sizes the search draws from; the (8-aligned) dim itself
# is always added so small tiles get a single-block schedule
BLOCK_SIZES = (8, 16, 32, 64, 128, 256)
VMEM_BUDGET_BYTES = 12 * 2**20  # leave headroom under the ~16 MiB/core VMEM
MAX_INTERPRET_GRID = 64  # interpret mode pays python per grid step; cap it

_LRU_SIZE = 256
_LRU: "OrderedDict[str, Tuple[int, int, int]]" = OrderedDict()
_DISK: Optional[Dict[str, dict]] = None  # lazily-loaded committed cache


def device_kind() -> str:
    """Cache namespace for the executing device ("cpu" implies interpret
    mode — the kernel only compiles on TPU)."""
    import jax

    return jax.default_backend()


def tune_key(
    ring: Ring, t: int, r: int, s: int, device: Optional[str] = None
) -> str:
    """Canonical cache key: device | ring envelope | 8-aligned planar dims.

    Dims are rounded up to the minimal (sublane) alignment so every ragged
    shape inside one envelope shares a tuned entry; ``ops.gr_matmul`` then
    pads to the chosen block multiples exactly as before.
    """
    dev = device or device_kind()
    T, R, S = _round_up(t, 8), _round_up(r, 8), _round_up(s, 8)
    return f"{dev}|D{ring.D}K{ring.K}e{ring.e}|{T}x{R}x{S}"


def _vmem_words(D: int, K: int, bt: int, bs: int, br: int) -> int:
    return (bt * br + br * bs + bt * bs) * D + K * bt * bs


def _dim_candidates(d: int) -> List[int]:
    """Block choices for one (8-aligned) dim: divisors of the dim drawn
    from the MXU-aligned sizes first (zero padding waste), then the
    non-divisor sizes below the dim, then the dim itself."""
    dp = _round_up(d, 8)
    divisors = [b for b in BLOCK_SIZES if b <= dp and dp % b == 0]
    rest = [b for b in BLOCK_SIZES if b <= dp and dp % b != 0]
    out = divisors + rest
    if dp not in out:
        out.append(dp)
    return out


def candidate_blocks(
    ring: Ring, t: int, r: int, s: int
) -> List[Tuple[int, int, int]]:
    """Deterministic candidate (bt, bs, br) grid for one tuning point.

    Divisor-aware: per-dim choices that divide the 8-aligned dim come
    first; the cross product is filtered by the VMEM accumulator budget
    (the K conv planes dominate for towers) and ordered by (padding waste,
    larger blocks first) so a bounded ``--budget`` prefix still explores
    the schedules most likely to win.  The static 128^3 default is always
    a member when it fits, so a tuned entry can only match or beat it.
    """
    D, K = ring.D, ring.K
    tp, rp, sp = _round_up(t, 8), _round_up(r, 8), _round_up(s, 8)
    seen = set()
    cands: List[Tuple[int, int, int]] = []
    for bt in _dim_candidates(tp):
        for bs in _dim_candidates(sp):
            for br in _dim_candidates(rp):
                blocks = (bt, bs, br)
                if blocks in seen:
                    continue
                seen.add(blocks)
                if _vmem_words(D, K, bt, bs, br) * 4 > VMEM_BUDGET_BYTES:
                    continue
                cands.append(blocks)

    def waste(blocks: Tuple[int, int, int]) -> float:
        bt, bs, br = blocks
        padded = _round_up(tp, bt) * _round_up(rp, br) * _round_up(sp, bs)
        return padded / (tp * rp * sp)

    cands.sort(key=lambda b: (waste(b), -(b[0] * b[1] * b[2]), b))
    return cands


def _grid_steps(t: int, r: int, s: int, blocks: Tuple[int, int, int]) -> int:
    bt, bs, br = blocks
    return (
        (_round_up(t, bt) // bt)
        * (_round_up(s, bs) // bs)
        * (_round_up(r, br) // br)
    )


def _median_us(fn, *args, iters: int = 3) -> float:
    """Median wall-clock (us); delegates to the benchmark harness's timeit
    when the ``benchmarks`` package is importable (repo checkouts), with a
    faithful local mirror for installed-package use."""
    try:
        from benchmarks.common import timeit

        return timeit(fn, *args, iters=iters)
    except ImportError:
        import jax

        jax.block_until_ready(fn(*args))  # warmup / compile
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)


@dataclass(frozen=True)
class TuneResult:
    key: str
    blocks: Tuple[int, int, int]
    us: float
    tried: int  # candidates actually timed under the budget


def load_cache(path: Optional[Path] = None) -> Dict[str, dict]:
    """Deserialize the persisted cache ({key: {blocks, us, tried}})."""
    p = Path(path) if path else CACHE_PATH
    if not p.exists():
        return {}
    with open(p) as f:
        payload = json.load(f)
    if payload.get("version") != CACHE_VERSION:
        return {}
    entries = payload.get("entries", {})
    for key, e in entries.items():
        blocks = e.get("blocks")
        if (
            not isinstance(blocks, list)
            or len(blocks) != 3
            or not all(isinstance(b, int) and b > 0 for b in blocks)
        ):
            raise ValueError(f"autotune cache entry {key!r} is malformed: {e}")
    return entries


def save_cache(entries: Dict[str, dict], path: Optional[Path] = None) -> Path:
    p = Path(path) if path else CACHE_PATH
    with open(p, "w") as f:
        json.dump(
            {"version": CACHE_VERSION, "entries": entries},
            f, indent=1, sort_keys=True,
        )
        f.write("\n")
    return p


def _disk_cache() -> Dict[str, dict]:
    global _DISK
    if _DISK is None:
        try:
            _DISK = load_cache()
        except (ValueError, json.JSONDecodeError):  # corrupt cache: ignore,
            _DISK = {}  # the static default is always safe
    return _DISK


def invalidate_memory_cache() -> None:
    """Drop the in-process views (tests, or after rewriting the JSON)."""
    global _DISK
    _DISK = None
    _LRU.clear()


def cached_blocks(
    ring: Ring, t: int, r: int, s: int, device: Optional[str] = None
) -> Optional[Tuple[int, int, int]]:
    """Tuned blocks for this point, or None (caller falls back to the
    static heuristic).  LRU over the deserialized committed cache — the
    hot path never re-reads JSON."""
    key = tune_key(ring, t, r, s, device)
    hit = _LRU.get(key)
    if hit is not None:
        _LRU.move_to_end(key)
        return hit
    entry = _disk_cache().get(key)
    if entry is None:
        return None
    blocks = tuple(int(b) for b in entry["blocks"])
    while len(_LRU) >= _LRU_SIZE:
        _LRU.popitem(last=False)
    _LRU[key] = blocks
    return blocks


def autotune(
    ring: Ring,
    t: int,
    r: int,
    s: int,
    *,
    budget: Optional[int] = None,
    iters: int = 3,
    interpret: Optional[bool] = None,
    device: Optional[str] = None,
    persist: bool = False,
    path: Optional[Path] = None,
) -> TuneResult:
    """Time the candidate grid at one point and record the winner.

    ``budget`` caps how many candidates are timed (the deterministic
    ordering makes a small budget meaningful); ``persist`` writes the
    updated cache JSON back to disk (default: in-process only).
    """
    import jax

    if ring.p != 2 or ring.e > 32 or ring.D > MAX_D:
        raise ValueError(f"{ring} is outside the kernel envelope")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    key = tune_key(ring, t, r, s, device)
    cands = candidate_blocks(ring, t, r, s)
    if interpret:
        cands = [
            b for b in cands if _grid_steps(t, r, s, b) <= MAX_INTERPRET_GRID
        ]
    if budget is not None:
        cands = cands[: max(1, budget)]

    rng = np.random.default_rng(0)
    D = ring.D
    tp, rp, sp = _round_up(t, 8), _round_up(r, 8), _round_up(s, 8)
    A = rng.integers(0, 2**16, size=(D, tp, rp), dtype=np.uint32)
    B = rng.integers(0, 2**16, size=(D, rp, sp), dtype=np.uint32)

    best: Optional[Tuple[float, Tuple[int, int, int]]] = None
    failed = 0
    for blocks in cands:
        bt, bs, br = blocks

        def call(a, b, bt=bt, bs=bs, br=br):
            return gr_matmul_planar(
                a, b, ring, bt=bt, bs=bs, br=br, interpret=interpret
            )

        try:
            us = _median_us(jax.jit(call), A, B, iters=iters)
        except Exception:  # noqa: BLE001 - a candidate that fails to lower
            # or exhausts VMEM on the real device (the static budget here
            # is only a heuristic) must not abort the sweep: skip it and
            # keep the winners measured so far
            failed += 1
            continue
        if best is None or us < best[0]:
            best = (us, blocks)
    if best is None:
        raise ValueError(
            f"no runnable kernel candidate for {key} "
            f"({len(cands)} tried, {failed} failed; VMEM/grid limits)"
        )

    us, blocks = best
    result = TuneResult(key=key, blocks=blocks, us=us, tried=len(cands))
    entries = _disk_cache()
    entries[key] = {"blocks": list(blocks), "us": round(us, 1),
                    "tried": len(cands)}
    _LRU.pop(key, None)
    if persist:
        save_cache(entries, path)
    return result


# ---------------------------------------------------------------------------
# CLI: retune / verify the committed cache (CI autotune-smoke)
# ---------------------------------------------------------------------------

# (ring constructor args, (t, r, s)) pairs the tier-1 suites lean on: the
# paper's 8/16-worker rings GR(2^32, 3/4) and the machine-word ring Z_{2^32},
# at the conformance tile (8^3) and the kernel-test block sizes.  The CI
# autotune-smoke job verifies the committed cache covers all of these.
DEFAULT_POINTS: Tuple[Tuple[Tuple[int, int, Tuple[int, ...]], Tuple[int, int, int]], ...] = tuple(
    (ring_args, shape)
    for ring_args in ((2, 32, ()), (2, 32, (3,)), (2, 32, (4,)))
    for shape in ((8, 8, 8), (16, 16, 16), (64, 64, 64), (128, 128, 128))
)


def coverage_gaps(
    entries: Dict[str, dict],
    points: Sequence = DEFAULT_POINTS,
    device: Optional[str] = None,
) -> List[str]:
    """Keys from ``points`` missing from ``entries`` (empty = full cover)."""
    missing = []
    for ring_args, (t, r, s) in points:
        p, e, degrees = ring_args
        key = tune_key(make_ring(p, e, tuple(degrees)), t, r, s, device)
        if key not in entries:
            missing.append(key)
    return missing


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--budget", type=int, default=None,
        help="max candidates timed per point (default: the full grid)",
    )
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument(
        "--out", default=None,
        help=f"cache path to write (default {CACHE_PATH})",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="do not retune: verify the committed cache deserializes and "
             "covers DEFAULT_POINTS for this device",
    )
    args = ap.parse_args(argv)

    if args.check:
        entries = load_cache(args.out)  # raises on malformed entries
        gaps = coverage_gaps(entries)
        print(f"cache OK: {len(entries)} entries at "
              f"{args.out or CACHE_PATH}")
        if gaps:
            print("MISSING tier-1 coverage:")
            for k in gaps:
                print(f"  {k}")
            return 1
        print(f"covers all {len(DEFAULT_POINTS)} tier-1 points "
              f"on device={device_kind()!r}")
        return 0

    for ring_args, (t, r, s) in DEFAULT_POINTS:
        p, e, degrees = ring_args
        ring = make_ring(p, e, tuple(degrees))
        res = autotune(
            ring, t, r, s, budget=args.budget, iters=args.iters,
        )
        print(f"{res.key}: blocks={res.blocks} us={res.us:.1f} "
              f"(tried {res.tried})")
    out = save_cache(_disk_cache(), args.out)
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
