"""Jit-ready wrappers around the Pallas Galois-ring matmul kernel.

Handles layout conversion (interleaved (t, r, D) <-> planar (D, t, r)),
padding to block multiples, block-size selection, and fallback to the jnp
reference when the ring is outside the kernel envelope (odd p or D > MAX_D).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.galois import Ring

from .gr_matmul import MAX_D, gr_matmul_planar
from .ref import gr_matmul_ref


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pick_blocks(t: int, r: int, s: int) -> Tuple[int, int, int]:
    """MXU-aligned block sizes: multiples of 128 when the dim allows, else
    the (padded) dim itself."""

    def pick(d: int, target: int = 128) -> int:
        return target if d >= target else _round_up(d, 8)

    return pick(t), pick(s), pick(r)


def kernel_supported(ring: Ring) -> bool:
    return ring.p == 2 and ring.e <= 32 and ring.D <= MAX_D


def gr_matmul(
    A: jnp.ndarray,
    B: jnp.ndarray,
    ring: Ring,
    *,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    force_ref: bool = False,
) -> jnp.ndarray:
    """Ring matmul (t, r, D) x (r, s, D) -> (t, s, D) via the Pallas kernel.

    On CPU containers ``interpret`` defaults to True (kernel body runs in
    python for validation); on TPU it compiles to Mosaic.
    """
    t, r, D = A.shape
    r2, s, D2 = B.shape
    assert r == r2 and D == D2 == ring.D
    if force_ref or not kernel_supported(ring):
        return gr_matmul_ref(A, B, ring)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bt, bs, br = blocks if blocks else pick_blocks(t, r, s)
    tp, rp, sp = _round_up(t, bt), _round_up(r, br), _round_up(s, bs)
    Ap = jnp.moveaxis(jnp.pad(A, ((0, tp - t), (0, rp - r), (0, 0))), -1, 0)
    Bp = jnp.moveaxis(jnp.pad(B, ((0, rp - r), (0, sp - s), (0, 0))), -1, 0)
    Cp = gr_matmul_planar(Ap, Bp, ring, bt=bt, bs=bs, br=br, interpret=interpret)
    return jnp.moveaxis(Cp, 0, -1)[:t, :s]


def coded_encode(
    V: jnp.ndarray, blocks_mat: jnp.ndarray, ring: Ring, **kw
) -> jnp.ndarray:
    """CDMM encode = ring matmul against a Vandermonde slice.

    V: (N, K, D); blocks_mat: (K, M, D) -> (N, M, D)."""
    return gr_matmul(V, blocks_mat, ring, **kw)
