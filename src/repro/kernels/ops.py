"""Jit-ready wrappers around the Pallas Galois-ring matmul kernel.

Handles layout conversion (interleaved (t, r, D) <-> planar (D, t, r)),
padding to block multiples, block-size selection (autotuned cache first,
static heuristic as fallback), and fallback to the jnp reference when the
ring is outside the kernel envelope (odd p or D > MAX_D).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.galois import Ring

from .autotune import cached_blocks
from .gr_matmul import MAX_D, _round_up, gr_matmul_planar
from .ref import gr_matmul_ref


def pick_blocks(t: int, r: int, s: int) -> Tuple[int, int, int]:
    """MXU-aligned block sizes: multiples of 128 when the dim allows, else
    the (padded) dim itself."""

    def pick(d: int, target: int = 128) -> int:
        return target if d >= target else _round_up(d, 8)

    return pick(t), pick(s), pick(r)


def kernel_supported(ring: Ring) -> bool:
    return ring.p == 2 and ring.e <= 32 and ring.D <= MAX_D


def kernel_auto_enabled(ring: Ring) -> bool:
    """Should a backend default its workers onto the kernel path?

    True when the ring is inside the kernel envelope AND the kernel
    actually compiles — i.e. on TPU, the only Pallas target this kernel
    lowers for (VMEM scratch + Mosaic compiler params).  On CPU it would
    run in interpret mode (a validation path, not a perf path) and on GPU
    it would fail to lower, so both default to the XLA reference unless
    explicitly forced.
    """
    return kernel_supported(ring) and jax.default_backend() == "tpu"


def gr_matmul(
    A: jnp.ndarray,
    B: jnp.ndarray,
    ring: Ring,
    *,
    blocks: Optional[Tuple[int, int, int]] = None,
    interpret: Optional[bool] = None,
    force_ref: bool = False,
) -> jnp.ndarray:
    """Ring matmul (t, r, D) x (r, s, D) -> (t, s, D) via the Pallas kernel.

    On CPU containers ``interpret`` defaults to True (kernel body runs in
    python for validation); on TPU it compiles to Mosaic.
    """
    t, r, D = A.shape
    r2, s, D2 = B.shape
    assert r == r2 and D == D2 == ring.D
    if force_ref or not kernel_supported(ring):
        return gr_matmul_ref(A, B, ring)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if blocks is None:
        # tuned schedule for this (device, ring, tile) when one is cached;
        # the static MXU heuristic otherwise
        blocks = cached_blocks(ring, t, r, s)
    bt, bs, br = blocks if blocks else pick_blocks(t, r, s)
    tp, rp, sp = _round_up(t, bt), _round_up(r, br), _round_up(s, bs)
    Ap = jnp.moveaxis(jnp.pad(A, ((0, tp - t), (0, rp - r), (0, 0))), -1, 0)
    Bp = jnp.moveaxis(jnp.pad(B, ((0, rp - r), (0, sp - s), (0, 0))), -1, 0)
    Cp = gr_matmul_planar(Ap, Bp, ring, bt=bt, bs=bs, br=br, interpret=interpret)
    return jnp.moveaxis(Cp, 0, -1)[:t, :s]


def coded_encode(
    V: jnp.ndarray, blocks_mat: jnp.ndarray, ring: Ring, **kw
) -> jnp.ndarray:
    """CDMM encode = ring matmul against a Vandermonde slice.

    V: (N, K, D); blocks_mat: (K, M, D) -> (N, M, D)."""
    return gr_matmul(V, blocks_mat, ring, **kw)
