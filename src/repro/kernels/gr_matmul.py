"""Pallas TPU kernel: blocked Galois-ring matrix multiplication.

This is the CDMM hot loop: every worker computes f(alpha_i) @ g(alpha_i)
over GR(2^e, D) — and encode/decode are themselves ring matmuls against
Vandermonde / Lagrange matrices, so ONE kernel serves all three stages.

TPU adaptation (DESIGN.md §3.1): the paper's NTL implementation is a scalar
tower-field library.  Here a GR matmul is decomposed into D^2 *integer*
matmuls (coefficient outer-convolution) accumulated into a VMEM scratch of
K = prod(2m_l - 1) coefficient planes, folded once per output tile by the
precomputed linear reduction FOLD (K x D).  All matmul operands are laid out
*planar* — (D, t, r) — so the contraction dims are genuine matrix dims and
each partial product is an MXU-shaped ``dot``.

Constraints: p = 2, e <= 32 (uint32 wraparound arithmetic — the machine-word
case the paper targets); D <= MAX_D keeps the unrolled D^2 dot loop bounded.
``ops.gr_matmul`` falls back to the jnp reference outside this envelope.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params
from repro.core.galois import Ring

MAX_D = 16  # unrolled D^2 dots per block; beyond this use the jnp reference


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, ring: Ring, nsteps_r: int):
    """Grid (T/bt, S/bs, R/br); planar blocks.

    a_ref: (D, bt, br), b_ref: (D, br, bs), o_ref: (D, bt, bs)
    acc_ref: VMEM scratch (K, bt, bs) uint32 accumulator (conv coefficients).
    """
    D, K = ring.D, ring.K
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    # coefficient outer-convolution: D^2 MXU dots
    for i in range(D):
        ai = a[i]
        for j in range(D):
            c = int(ring.CONVPOS[i, j])  # static conv plane
            acc_ref[c, :, :] += jax.lax.dot(
                ai, b[j], preferred_element_type=jnp.uint32
            )

    @pl.when(k == nsteps_r - 1)
    def _fold():
        acc = acc_ref[...]  # (K, bt, bs)
        fold = ring.FOLD.astype(np.uint32)  # (K, D) host constant
        out = jnp.zeros(o_ref.shape, dtype=jnp.uint32)
        for d in range(D):
            plane = jnp.zeros(o_ref.shape[1:], dtype=jnp.uint32)
            for c in range(K):
                f = int(fold[c, d])
                if f == 0:
                    continue
                if f == 1:
                    plane += acc[c]
                else:
                    plane += jnp.uint32(f) * acc[c]
            out = out.at[d].set(plane)
        if ring.e < 32:
            out = out & jnp.uint32(2**ring.e - 1)
        o_ref[...] = out


def gr_matmul_planar(
    A: jnp.ndarray,
    B: jnp.ndarray,
    ring: Ring,
    *,
    bt: int = 128,
    bs: int = 128,
    br: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Planar GR matmul: A (D, T, R), B (D, R, S) -> (D, T, S).

    Block sizes need not divide the dims: oversized blocks are clamped to
    the 8-aligned dim and the operands are zero-padded up to block
    multiples (zeros contribute zero to the coefficient convolution), so
    autotuner candidates and odd-shaped CDMM tiles never crash the kernel
    path.  The output is sliced back to the input (T, S).
    """
    if ring.p != 2 or ring.e > 32:
        raise ValueError("kernel supports the machine-word case p=2, e<=32")
    if ring.D > MAX_D:
        raise ValueError(f"D={ring.D} > MAX_D={MAX_D}; use the jnp reference")
    D, T, R = A.shape
    _, R2, S = B.shape
    assert R == R2 and D == ring.D
    bt = min(bt, _round_up(T, 8))
    bs = min(bs, _round_up(S, 8))
    br = min(br, _round_up(R, 8))
    Tp, Sp, Rp = _round_up(T, bt), _round_up(S, bs), _round_up(R, br)
    if (Tp, Rp) != (T, R):
        A = jnp.pad(A, ((0, 0), (0, Tp - T), (0, Rp - R)))
    if (Rp, Sp) != (R, S):
        B = jnp.pad(B, ((0, 0), (0, Rp - R), (0, Sp - S)))
    grid = (Tp // bt, Sp // bs, Rp // br)

    kern = functools.partial(_kernel, ring=ring, nsteps_r=grid[2])
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((D, bt, br), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((D, br, bs), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((D, bt, bs), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((D, Tp, Sp), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((ring.K, bt, bs), jnp.uint32)],
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(A, B)
    return out if (Tp, Sp) == (T, S) else out[:, :T, :S]
