"""Pure-jnp oracle for the Galois-ring matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.galois import Ring


def gr_matmul_ref(A: jnp.ndarray, B: jnp.ndarray, ring: Ring) -> jnp.ndarray:
    """Interleaved layout reference: (t, r, D) x (r, s, D) -> (t, s, D)."""
    return ring.matmul(A, B)


def gr_matmul_planar_ref(A: jnp.ndarray, B: jnp.ndarray, ring: Ring) -> jnp.ndarray:
    """Planar layout reference: (D, t, r) x (D, r, s) -> (D, t, s)."""
    Ai = jnp.moveaxis(A, 0, -1)
    Bi = jnp.moveaxis(B, 0, -1)
    Ci = ring.matmul(Ai, Bi)
    return jnp.moveaxis(Ci, -1, 0)
