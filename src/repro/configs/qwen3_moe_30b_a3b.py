"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    layer_pattern=("global",),
    qk_norm=True,
    mlp_act="swiglu",
    num_experts=128,
    experts_per_tok=8,
    expert_d_ff=768,
    rope_theta=1_000_000.0,
    max_context=32768,
)
