"""internvl2-2b [vlm]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
InternViT frontend is a STUB — input_specs provides precomputed patch
embeddings (256 tokens x 1024). [arXiv:2404.16821; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    layer_pattern=("global",),
    mlp_act="swiglu",
    frontend="patch",
    frontend_dim=1024,
    frontend_len=256,
    max_context=32768,
)
