"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d=1024 16H (MHA kv=16)
d_ff=4096 vocab=256206.  Encoder-decoder; modality frontend is a STUB —
input_specs provides precomputed frame embeddings. [arXiv:2308.11596; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    layer_pattern=("global",),
    mlp_act="gelu",
    norm="layernorm",
    frontend="frames",
    frontend_dim=1024,
    src_ratio=4,  # src frames = seq_len / 4 (audio downsampling stub)
    max_context=32768,
)
