"""Config schema: model architecture + input-shape cells."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    layer_pattern: Tuple[str, ...] = ("global",)  # repeating unit of local/global
    window_size: int = 4096
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    expert_d_ff: int = 0
    shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_d_inner: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): shared attention block applied every k SSM layers
    shared_attn_every: int = 0

    # enc-dec
    encoder_layers: int = 0
    src_ratio: int = 1  # src_len = seq_len // src_ratio

    # modality stub frontend
    frontend: Optional[str] = None  # "patch" | "frames"
    frontend_dim: int = 0
    frontend_len: int = 0  # fixed token count for patches

    # numerics / training
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    max_context: int = 131072
    sub_quadratic: bool = False  # can run long_500k

    # distribution / memory plan
    fsdp_axes: Tuple[str, ...] = ("data",)  # weight-shard axes (ZeRO-3)
    optimizer: str = "adamw"  # adamw | adafactor
    opt_state_dtype: str = "float32"
    grad_accum: int = 1  # microbatch accumulation (memory, not comms)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def adtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        if self.shared_attn_every:
            smoke_every = min(self.shared_attn_every, 2)
            nl = 2 * smoke_every + 1  # 2 units + a tail layer
        else:
            nl = max(2, len(self.layer_pattern)) + self.first_k_dense
            rem = (nl - self.first_k_dense) % len(self.layer_pattern)
            if rem:
                nl += len(self.layer_pattern) - rem
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=nl,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            experts_per_tok=min(self.experts_per_tok, 2) if self.experts_per_tok else 0,
            expert_d_ff=64 if self.expert_d_ff else 0,
            ssm_state=32 if self.ssm_state else 0,
            ssm_d_inner=256 if self.ssm_d_inner else 0,
            ssm_head_dim=32,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_dim=64 if self.frontend_dim else 0,
            frontend_len=8 if self.frontend_len else 0,
            window_size=min(self.window_size, 64),
            shared_attn_every=min(self.shared_attn_every, 2) or 0,
            max_context=2048,
            first_k_dense=min(self.first_k_dense, 1),
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str = "train") -> ShapeConfig:
    return ShapeConfig(f"smoke_{kind}", 64, 2, kind)
