"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 experts top-8 + 1 shared expert, first layer dense.
Trillion-parameter MoE (paper-table). [arXiv:2501.kimi2; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=14336,  # used by the first dense layer
    vocab_size=163840,
    layer_pattern=("global",),
    mlp_act="swiglu",
    num_experts=384,
    experts_per_tok=8,
    expert_d_ff=2048,
    shared_experts=1,
    first_k_dense=1,
    rope_theta=50_000.0,
    max_context=131072,
    # 1T params: ZeRO-3 across pod+data, factored optimizer states — the only
    # plan that fits 2 TB of bf16 params + grads in 512 x 16 GB (see
    # EXPERIMENTS.md §Dry-run for the measured bytes/device)
    fsdp_axes=("pod", "data"),
    optimizer="adafactor",
    opt_state_dtype="bfloat16",
    # grad_accum=16 was REFUTED (iter K3): ZeRO-3 weight re-gathers per
    # microbatch blew collective time 15x; SP-residual (K4) solves the
    # activation memory instead.
)
