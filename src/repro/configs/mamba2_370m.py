"""mamba2-370m [ssm]: 48L d=1024, attention-free, vocab=50280, state=128.
SSD (state-space duality). [arXiv:2405.21060; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_d_inner=2048,
    ssm_head_dim=64,  # 32 SSD heads
    ssm_conv=4,
    ssm_chunk=256,
    max_context=1_048_576,
    sub_quadratic=True,  # runs long_500k
)
