"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local:global attention, 128k context. [hf:google/gemma-3; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern=("local",) * 5 + ("global",),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_act="geglu",
    max_context=131072,
    sub_quadratic=False,  # sliding windows but 1:6 layers are full attention
)
