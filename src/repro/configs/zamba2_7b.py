"""zamba2-7b [hybrid]: 81L (Mamba2) d=3584, shared attention block
(32H MHA kv=32, d_ff=14336) applied every 6 SSM layers, ssm_state=64,
vocab=32000. [arXiv:2411.15242; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    mlp_act="geglu",
    ssm_state=64,
    ssm_d_inner=7168,
    ssm_head_dim=64,  # 112 SSD heads
    ssm_conv=4,
    shared_attn_every=6,  # 13 shared-attn applications + 3 tail SSM layers
    max_context=1_048_576,
    sub_quadratic=True,  # SSM backbone; shared attn is O(S) per decode step
)
