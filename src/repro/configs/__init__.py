"""Assigned architecture configs (--arch <id>) + shape cells."""
from .base import SHAPES, ModelConfig, ShapeConfig, smoke_shape
from .deepseek_67b import CONFIG as deepseek_67b
from .gemma2_2b import CONFIG as gemma2_2b
from .gemma3_12b import CONFIG as gemma3_12b
from .internvl2_2b import CONFIG as internvl2_2b
from .kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from .mamba2_370m import CONFIG as mamba2_370m
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .starcoder2_3b import CONFIG as starcoder2_3b
from .zamba2_7b import CONFIG as zamba2_7b

ARCHS = {
    c.name: c
    for c in [
        gemma3_12b,
        starcoder2_3b,
        deepseek_67b,
        gemma2_2b,
        mamba2_370m,
        seamless_m4t_medium,
        qwen3_moe_30b_a3b,
        kimi_k2_1t_a32b,
        zamba2_7b,
        internvl2_2b,
    ]
}

# long_500k requires a sub-quadratic sequence mechanism (see DESIGN.md §4)
LONG_CONTEXT_ARCHS = {k for k, c in ARCHS.items() if c.sub_quadratic}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic archs."""
    out = []
    for a, cfg in ARCHS.items():
        for s, shp in SHAPES.items():
            skipped = s == "long_500k" and a not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            out.append((a, s, skipped))
    return out
