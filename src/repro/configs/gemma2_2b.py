"""gemma2-2b [dense]: 26L d=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local/global alternating attention, logit softcaps. [arXiv:2408.00118; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=("local", "global"),
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    mlp_act="geglu",
    max_context=8192,
)
