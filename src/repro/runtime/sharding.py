"""Logical-axis sharding runtime (MaxText-style) for the LM plane.

Model code annotates arrays with *logical* axis names; a rules table maps
them to physical mesh axes.  ``spec_for`` silently drops a mesh axis when
the dimension is not divisible by it (replication fallback) so every config
in the zoo lowers on the fixed production meshes — per-cell tuning then
tightens the rules for the hillclimbed cells.

Params are declared as ``ParamSpec`` trees (shape, logical axes, init), so
the same declaration yields:
  * real arrays for CPU smoke tests        (``materialize``)
  * ShapeDtypeStructs + NamedShardings for the multi-pod dry-run
    (``shape_structs`` — no allocation, jit in_shardings).
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# rules + context
# ---------------------------------------------------------------------------

# default logical->physical rules; None = replicated
DEFAULT_RULES: Dict[str, Union[None, str, Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,                # set to "model" for context-parallel shapes
    "q_seq": None,              # attention-internal query-seq layout
    "residual_seq": None,       # residual-stream seq layout (Megatron-SP)
    "kv_seq": None,             # attention-internal key/value-seq layout
    "cache_seq": None,          # decode KV-cache sequence axis
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",             # flattened head*dim projections
    "ffn": "model",
    "experts": "model",
    "expert_ffn": None,
    "vocab": "model",
    "fsdp": "data",             # weight "row" dim when FSDP is on
    "frontend": None,
    "conv": None,
    "state": None,              # SSM state dim
    "ssm_heads": "model",
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Any] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None):
    """Activate sharding annotations inside the block (no-op mesh=None)."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _CTX.rules = merged
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axes_of(name: Optional[str], rules) -> Tuple[str, ...]:
    if name is None:
        return ()
    rule = rules.get(name, None)
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def spec_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, Any]] = None,
) -> P:
    """PartitionSpec for ``shape`` under logical names, with divisibility
    fallback (drop trailing mesh axes until the dim divides)."""
    mesh = mesh if mesh is not None else _CTX.mesh
    rules = rules if rules is not None else _CTX.rules
    if mesh is None:
        return P(*([None] * len(shape)))
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axes = [a for a in _axes_of(name, rules) if a in mesh.shape and a not in used]
        # shrink until divisible
        while axes:
            total = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % total == 0:
                break
            axes = axes[:-1]
        if axes:
            used.update(axes)
            out.append(tuple(axes) if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def shard(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """Sharding constraint by logical names; identity outside axis_rules."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical, mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# param declaration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def _map_leaves(fn: Callable[[Tuple[str, ...], ParamSpec], Any], tree, prefix=()):
    if isinstance(tree, dict):
        return {k: _map_leaves(fn, v, prefix + (k,)) for k, v in tree.items()}
    return fn(prefix, tree)


def _path_key(key: jax.Array, path: Tuple[str, ...]) -> jax.Array:
    h = int.from_bytes(hashlib.md5("/".join(path).encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def materialize(spec_tree, key: jax.Array):
    """Instantiate real arrays (smoke tests / the example trainer)."""

    def init_one(path, ps: ParamSpec):
        k = _path_key(key, path)
        if ps.init == "zeros":
            return jnp.zeros(ps.shape, ps.dtype)
        if ps.init == "ones":
            return jnp.ones(ps.shape, ps.dtype)
        fan_in = ps.shape[0] if len(ps.shape) >= 1 else 1
        std = ps.scale / np.sqrt(max(fan_in, 1))
        if ps.init == "embed":
            std = ps.scale
        return (jax.random.normal(k, ps.shape, jnp.float32) * std).astype(ps.dtype)

    return _map_leaves(init_one, spec_tree)


def shape_structs(spec_tree, mesh: Optional[Mesh], rules=None):
    """ShapeDtypeStructs with shardings — dry-run stand-ins, no allocation."""

    def one(path, ps: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(ps.shape, ps.dtype)
        spec = spec_for(ps.shape, ps.logical, mesh, rules or dict(DEFAULT_RULES))
        return jax.ShapeDtypeStruct(ps.shape, ps.dtype, sharding=NamedSharding(mesh, spec))

    return _map_leaves(one, spec_tree)


def sharding_tree(spec_tree, mesh: Mesh, rules=None):
    """NamedSharding pytree (jit in_shardings for params)."""

    def one(path, ps: ParamSpec):
        spec = spec_for(ps.shape, ps.logical, mesh, rules or dict(DEFAULT_RULES))
        return NamedSharding(mesh, spec)

    return _map_leaves(one, spec_tree)


def param_count(spec_tree) -> int:
    return sum(int(np.prod(ps.shape)) for _, ps in _leaf_paths(spec_tree))


def param_bytes(spec_tree) -> int:
    return sum(
        int(np.prod(ps.shape)) * jnp.dtype(ps.dtype).itemsize
        for _, ps in _leaf_paths(spec_tree)
    )
