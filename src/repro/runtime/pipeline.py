"""GPipe-style pipeline parallelism over a mesh axis (designed for "pod").

Cross-pod ICI/DCN links are the slowest; pipeline point-to-point traffic
(one activation tensor per microbatch tick) is the cheapest way to use them.
The layer stack (leading scan axis) is sharded over the pipeline axis via
shard_map; inside, a GPipe schedule runs M microbatches over P stages with
``ppermute`` hops.  The SPMD emulation computes every stage every tick
(bubble = (P-1)/(M+P-1) wasted ticks — the standard GPipe overhead).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def pipeline_body(
    stage_fn: Callable,
    stage_params,
    x: jnp.ndarray,
    *,
    axis: str,
    microbatches: int,
):
    """Per-shard GPipe body (call inside shard_map over ``axis``).

    stage_fn(stage_params, xmb) -> ymb applies THIS stage's layer slice.
    x: (B, ...) replicated batch; returns y: (B, ...) replicated.
    """
    nstages = lax.psum(1, axis)
    s = lax.axis_index(axis)
    M = microbatches
    B = x.shape[0]
    assert B % M == 0
    mb = x.reshape(M, B // M, *x.shape[1:])
    ticks = M + nstages - 1
    perm = [(i, i + 1) for i in range(nstages - 1)]

    def tick(carry, t):
        buf, outs = carry
        # stage 0 ingests microbatch t (or garbage past the end)
        idx = jnp.clip(t, 0, M - 1)
        inp = jnp.where(s == 0, mb[idx], buf)
        out = stage_fn(stage_params, inp)
        # last stage collects microbatch t-(P-1)
        oidx = t - (nstages - 1)
        valid = (s == nstages - 1) & (oidx >= 0)
        outs = lax.cond(
            valid,
            lambda o: o.at[jnp.clip(oidx, 0, M - 1)].set(out),
            lambda o: o,
            outs,
        )
        nxt = lax.ppermute(out, axis, perm)
        return (nxt, outs), None

    buf0 = jnp.zeros_like(mb[0])
    outs0 = jnp.zeros((M,) + mb.shape[1:], x.dtype)
    (_, outs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
    # broadcast final outputs from the last stage to all stages
    outs = lax.psum(jnp.where(s == nstages - 1, outs, jnp.zeros_like(outs)), axis)
    return outs.reshape(B, *x.shape[1:])


def pipelined_apply(
    stage_fn: Callable,
    params_stacked,
    x: jnp.ndarray,
    mesh: Mesh,
    *,
    axis: str = "pod",
    microbatches: int = 4,
):
    """shard_map wrapper: layer-stack leading dim sharded over ``axis``."""
    pspec = jax.tree.map(lambda _: P(axis), params_stacked)
    body = partial(pipeline_body, stage_fn, axis=axis, microbatches=microbatches)
    return shard_map(
        lambda p, xx: body(p, xx),
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check=False,
    )(params_stacked, x)
