"""Distributed runtime: sharding rules, mesh helpers, fault tolerance."""
from .sharding import (
    ParamSpec, axis_rules, shard, spec_for, materialize,
    shape_structs, sharding_tree, param_count, param_bytes, DEFAULT_RULES,
)
