"""Elastic scaling: restore any checkpoint onto any mesh.

The checkpoint format is mesh-agnostic (host-gathered full arrays + the data
step for deterministic replay), so growing 256 -> 512 chips, shrinking after
node failure, or changing the (data, model) split is just a restore with new
shardings.  For true multi-host restarts the same logic runs per-host with
process-local slices; here (single process, fake devices) we validate the
semantics end-to-end.
"""
from __future__ import annotations

from typing import Dict, Optional

from jax.sharding import Mesh

from repro.checkpoint import Checkpointer
from repro.runtime.sharding import sharding_tree


def elastic_restore(
    ckpt: Checkpointer,
    param_specs: Dict,
    mesh: Mesh,
    rules: Optional[Dict] = None,
    step: Optional[int] = None,
) -> Dict:
    """Load params and place them on ``mesh`` regardless of the mesh that
    wrote the checkpoint."""
    shardings = sharding_tree(param_specs, mesh, rules)
    tree = ckpt.restore(step=step, shardings={"params": shardings})
    return tree


def replan_batch(global_batch: int, live_data_shards: int) -> int:
    """After losing (or gaining) nodes, keep the global batch by resizing the
    per-shard batch (preferred: preserves optimization trajectory) — returns
    the new local batch.

    When ``live_data_shards`` does not divide ``global_batch`` the per-shard
    batch is the ceiling, so ``per * live >= global`` and the trailing shard
    runs partially filled (callers pad or mask the remainder).  The CDMM
    elastic backend (``repro.cdmm.elastic``) calls this on every membership
    change to re-chunk a batch stream across the live pool.
    """
    if global_batch < 1:
        raise ValueError(f"global_batch must be >= 1, got {global_batch}")
    if live_data_shards < 1:
        raise ValueError(
            f"cannot replan onto {live_data_shards} live shards; "
            "need at least one survivor"
        )
    return -(-global_batch // live_data_shards)
