"""Elastic scaling: restore any checkpoint onto any mesh.

The checkpoint format is mesh-agnostic (host-gathered full arrays + the data
step for deterministic replay), so growing 256 -> 512 chips, shrinking after
node failure, or changing the (data, model) split is just a restore with new
shardings.  For true multi-host restarts the same logic runs per-host with
process-local slices; here (single process, fake devices) we validate the
semantics end-to-end.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import Checkpointer
from repro.runtime.sharding import sharding_tree


def elastic_restore(
    ckpt: Checkpointer,
    param_specs: Dict,
    mesh: Mesh,
    rules: Optional[Dict] = None,
    step: Optional[int] = None,
) -> Dict:
    """Load params and place them on ``mesh`` regardless of the mesh that
    wrote the checkpoint."""
    shardings = sharding_tree(param_specs, mesh, rules)
    tree = ckpt.restore(step=step, shardings={"params": shardings})
    return tree


def replan_batch(global_batch: int, live_data_shards: int) -> int:
    """After losing nodes, keep the global batch by growing per-shard batch
    (preferred: preserves optimization trajectory) — returns new local batch."""
    assert global_batch % live_data_shards == 0 or live_data_shards > 0
    per = -(-global_batch // live_data_shards)
    return per
