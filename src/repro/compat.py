"""Cross-version JAX compatibility shims.

``shard_map`` moved twice across jax releases:

  * jax <= 0.4.x:  ``jax.experimental.shard_map.shard_map`` with a
    ``check_rep`` kwarg,
  * jax >= 0.5/0.6: top-level ``jax.shard_map`` with the kwarg renamed to
    ``check_vma``.

Every shard_map call in this repo goes through :func:`shard_map` below so
the version split lives in exactly one place.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax

__all__ = ["shard_map", "pallas_tpu_compiler_params"]


def pallas_tpu_compiler_params(**kwargs) -> Any:
    """Build Pallas TPU compiler params across the 0.4 -> 0.5 rename
    (``TPUCompilerParams`` became ``CompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def _resolve():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    params = inspect.signature(fn).parameters
    if "check_vma" in params:
        kw = "check_vma"
    elif "check_rep" in params:
        kw = "check_rep"
    else:  # future jax: replication checking removed entirely
        kw = None
    return fn, kw


_SHARD_MAP, _CHECK_KW = _resolve()


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False) -> Any:
    """Version-agnostic ``shard_map``.

    ``check`` maps onto ``check_vma`` (new jax) / ``check_rep`` (old jax);
    the repo's CDMM bodies decode from runtime-selected worker subsets, which
    the replication checker cannot prove, so callers pass ``check=False``.
    """
    kwargs = {} if _CHECK_KW is None else {_CHECK_KW: check}
    return _SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
