"""Pool smoke: spawn a local pool, kill workers mid-request, check the bits.

The CI ``pool-smoke`` job runs this as its merge gate for the distributed
runtime::

    python -m repro.dist.smoke --workers 6 --kill 1

It spawns a ``--workers``-process LocalPool, plans a scheme under a
straggler budget, parks every worker's compute long enough for the kill to
land provably mid-request, SIGKILLs ``--kill`` workers while the request
is in flight, and asserts the decoded product still equals the plain
``A @ B`` oracle bit for bit.  Exit code 0 = pass.

With ``--trace`` the killed request runs under a :mod:`repro.obs` trace
and the merged timeline is validated against the span schema: non-empty,
monotone span times, per-worker compute spans from at least R responders,
and — when workers were killed — a re-dispatched send span proving the
dead worker's share moved.  ``--trace-out PATH`` additionally writes the
timeline in Chrome ``trace_event`` format (load via chrome://tracing).

With ``--obs-http`` the pool starts its embedded admin server on an
ephemeral port and the smoke scrapes ``/metrics`` *while the killed
request is in flight*, gating on the strict exposition parser
(:func:`repro.obs.parse_prometheus`) plus a ``/healthz`` liveness check
— the acceptance oracle for the live telemetry plane.
"""
from __future__ import annotations

import argparse
import sys
import threading
import time
from typing import Optional

import numpy as np


def _scrape_obs(url: str, min_workers: int) -> list:
    """Scrape /metrics and /healthz of a live pool; returns problems."""
    import json
    import urllib.request

    from repro.obs import parse_prometheus

    problems = []
    with urllib.request.urlopen(f"{url}/metrics", timeout=10) as resp:
        text = resp.read().decode()
    try:
        families = parse_prometheus(text)
    except ValueError as e:
        return [f"/metrics failed strict parsing: {e}"]
    health = [
        s for fam in families.values() for s in fam["samples"]
        if s[0] == "repro_pool_worker_health"
    ]
    if len(health) < min_workers:
        problems.append(
            f"/metrics has {len(health)} pool_worker_health samples, "
            f"expected >= {min_workers}"
        )
    for name in ("repro_pool_requests", "repro_pool_workers_live"):
        if name not in families:
            problems.append(f"/metrics missing family {name}")
    if "repro_pool_wall_ms" in families:
        if families["repro_pool_wall_ms"]["type"] != "histogram":
            problems.append("repro_pool_wall_ms is not a histogram family")
    else:
        problems.append("/metrics missing family repro_pool_wall_ms")
    with urllib.request.urlopen(f"{url}/healthz", timeout=10) as resp:
        doc = json.loads(resp.read().decode())
    if not doc.get("ok"):
        problems.append(f"/healthz not ok: {doc}")
    if "pool" not in doc.get("sources", []):
        problems.append(f"/healthz lists no pool source: {doc}")
    return problems


def run_smoke(
    workers: int = 6,
    kill: int = 1,
    size: int = 32,
    delay_ms: float = 400.0,
    seed: int = 0,
    trace: bool = False,
    trace_out: str = "",
    obs_http: bool = False,
) -> int:
    from repro.cdmm import ProblemSpec, coded_matmul, plan
    from repro.core import make_ring
    from repro.dist import LocalPool, PoolBackend, PoolConfig

    if trace:
        from repro import obs

        obs.set_enabled(True)

    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=max(kill, 1),
    )
    # tightest feasible code: the candidate with the LARGEST R still inside
    # the budget, so killing N - R workers leaves exactly R responders and
    # the any-R property is exercised with zero slack
    p = plan(spec, objective="threshold")
    rank = max(range(len(p.candidates)), key=lambda i: p.candidates[i].costs.R)
    scheme = p.instantiate(rank)
    rng = np.random.default_rng(seed)
    A = Z32.random(rng, (size, size))
    B = Z32.random(rng, (size, size))
    oracle = np.asarray(Z32.matmul(A, B))

    cfg = PoolConfig(workers=workers)
    if obs_http:
        cfg = cfg.with_(obs_http_port=0)  # ephemeral admin port
    with LocalPool(config=cfg) as pool:
        caps = pool.master.worker_caps()
        print(f"pool up: {len(caps)} workers, scheme {scheme.name} "
              f"N={scheme.N} R={scheme.R} over {scheme.ring}")
        be = PoolBackend(pool)
        # warm round: every worker jits its ring matmul before the race
        warm = np.asarray(coded_matmul(A, B, scheme, backend=be))
        if not np.array_equal(warm, oracle):
            print("FAIL: warm-up decode != oracle")
            return 1
        # park every worker so the kill lands mid-compute, then race it
        for wid in pool.master.live_workers():
            pool.master.task_delay_ms[wid] = delay_ms
        result: dict = {}
        ctx = None
        if trace:
            from repro import obs

            ctx = obs.TraceContext.new("smoke")

        def _request():
            try:
                if ctx is not None:
                    # explicit context so the smoke can fetch the timeline
                    # by trace_id after the race resolves
                    C, result["stats"] = pool.master.execute(
                        scheme, A, B, trace=ctx
                    )
                    result["C"] = np.asarray(C)
                else:
                    result["C"] = np.asarray(
                        coded_matmul(A, B, scheme, backend=be)
                    )
            except Exception as e:  # surfaced below
                result["err"] = e

        t = threading.Thread(target=_request)
        t.start()
        time.sleep(delay_ms / 4e3)  # tasks dispatched, workers parked
        if obs_http:
            # scrape mid-load: the request is in flight, workers parked
            from repro.obs import http as obs_http_mod

            url = obs_http_mod.server().url
            problems = _scrape_obs(url, min_workers=scheme.R)
            if problems:
                for p in problems:
                    print(f"FAIL obs: {p}")
                return 1
            print(f"obs scrape OK mid-request: {url}/metrics parsed "
                  f"strictly, /healthz ok")
        killed = pool.kill(kill)
        print(f"SIGKILLed {len(killed)} worker(s) mid-request: pids {killed}")
        t.join(timeout=120)
        if t.is_alive():
            print("FAIL: request did not complete after the kill")
            return 1
        if "err" in result:
            print(f"FAIL: request raised {result['err']!r}")
            return 1
        if not np.array_equal(result["C"], oracle):
            print("FAIL: post-kill decode != oracle")
            return 1
        stats = result.get("stats", be.last_stats)
        print(f"decoded from shares {stats.live_idx} "
              f"({stats.redispatched} re-dispatched) in {stats.wall_ms:.0f} ms "
              f"with {pool.alive_count()}/{workers} workers alive")
        if ctx is not None:
            from repro import obs

            timeline = obs.tracer().timeline(ctx.trace_id)
            problems = obs.validate_timeline(
                timeline.to_json(),
                min_workers=scheme.R,
                require_components=("pool", "worker"),
            )
            sends = [s for s in timeline.spans if s.name == "send"]
            if kill and not any(s.tags.get("redispatch") for s in sends):
                problems.append(
                    f"{kill} worker(s) killed but no redispatched send span"
                )
            if problems:
                for p in problems:
                    print(f"FAIL trace: {p}")
                return 1
            lanes = {
                s.tags.get("wid") for s in timeline.spans
                if s.name == "compute"
            }
            print(f"trace {timeline.trace_id}: {len(timeline.spans)} spans, "
                  f"{timeline.wall_s * 1e3:.0f} ms wall, compute lanes "
                  f"{sorted(lanes)}, {sum(s.tags.get('redispatch', False) for s in sends)}"
                  f" redispatched send span(s)")
            if trace_out:
                with open(trace_out, "w") as f:
                    f.write(obs.to_chrome_trace(timeline, indent=1))
                print(f"chrome trace_event JSON written to {trace_out}")
    print("POOL SMOKE OK: decode bit-identical to the oracle after "
          f"{kill} mid-request SIGKILL(s)")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--kill", type=int, default=1)
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--delay-ms", type=float, default=400.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", action="store_true",
                    help="trace the killed request and validate the "
                         "merged span timeline")
    ap.add_argument("--trace-out", default="",
                    help="write the timeline as Chrome trace_event JSON")
    ap.add_argument("--obs-http", action="store_true",
                    help="start the embedded admin server and gate on a "
                         "strict /metrics parse mid-request")
    args = ap.parse_args(argv)
    return run_smoke(args.workers, args.kill, args.size, args.delay_ms,
                     args.seed, trace=args.trace, trace_out=args.trace_out,
                     obs_http=args.obs_http)


if __name__ == "__main__":
    sys.exit(main())
