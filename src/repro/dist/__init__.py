"""repro.dist: a real multi-process worker-pool runtime behind coded_matmul.

Every earlier backend (LocalSim, ShardMap, Elastic) *simulates* the paper's
master/worker protocol inside one process — stragglers are ``WorkerTrace``
fictions.  This package runs it for real:

  * :mod:`repro.dist.protocol` — length-prefixed framed RPC (msgpack header
    + raw-bytes array payloads) over TCP or Unix-domain sockets;
  * :mod:`repro.dist.worker` — the worker-process entrypoint
    (``python -m repro.dist.worker --connect ...``): registers with a
    capability handshake (device kind, ring-arithmetic envelope, autotune
    cache coverage) and computes jitted ``gr_matmul`` block products;
  * :mod:`repro.dist.master` — the master: accepts workers, tracks
    heartbeats and membership (``core.straggler.MembershipEvents``),
    dispatches per-worker ``encode_*_at`` shares, re-dispatches the shares
    of workers that die mid-request, and fires the LRU-cached any-R
    ``decode_op`` at the R-th response; plus :class:`LocalPool`, which
    spawns a local master + N worker OS processes in one call;
  * :mod:`repro.dist.scheduler` — a serving scheduler (bounded queue,
    admission control, per-spec plan cache) so one pool serves many
    concurrent matmul requests;
  * :mod:`repro.dist.pool_backend` — :class:`PoolBackend`, registered as
    ``coded_matmul(A, B, plan, backend="pool")``.

Importing this package registers the ``"pool"`` backend; ``cdmm.backends``
also lazy-imports it on first use, so the one-line switch works without an
explicit ``import repro.dist``.

Determinism: encode runs master-side (same process, same bits as
LocalSim), worker compute is exact integer ring arithmetic (bit-identical
across processes), and the decode subset is the canonical sorted first-R
arrival set — so a fixed encode key gives bit-identical results to
``LocalSimBackend`` even under real worker deaths (property-tested in
tests/test_conformance.py and tests/test_dist.py).
"""
from repro.cdmm.backends import register_backend

from .master import LocalPool, Master, PoolStats, WorkerDied
from .pool_backend import PoolBackend, default_pool, shutdown_default_pool
from .protocol import recv_msg, send_msg
from .scheduler import PoolScheduler, SchedulerSaturated

register_backend("pool", PoolBackend)

__all__ = [
    "LocalPool",
    "Master",
    "PoolBackend",
    "PoolScheduler",
    "PoolStats",
    "SchedulerSaturated",
    "WorkerDied",
    "default_pool",
    "shutdown_default_pool",
    "recv_msg",
    "send_msg",
]
