"""repro.dist: a real multi-process worker-pool runtime behind coded_matmul.

Every earlier backend (LocalSim, ShardMap, Elastic) *simulates* the paper's
master/worker protocol inside one process — stragglers are ``WorkerTrace``
fictions.  This package runs it for real:

  * :mod:`repro.dist.config` — :class:`PoolConfig`/:class:`Endpoint`, the
    unified pool + transport configuration every entry point accepts
    (worker counts, hostfiles, wire codec, compression level, streaming
    chunk size, heartbeat/request timeouts);
  * :mod:`repro.dist.protocol` — length-prefixed framed RPC (msgpack header
    + array payloads) over TCP or Unix-domain sockets, with per-connection
    negotiated wire codecs: bit-packing to the ring's true bit-width plus
    optional zlib/zstd framing, so Z_{2^k} shares stop shipping dead carrier
    bits (raw vs. on-wire bytes are counted end to end);
  * :mod:`repro.dist.worker` — the worker-process entrypoint
    (``python -m repro.dist.worker --connect ...``): registers with a
    capability handshake (device kind, ring-arithmetic envelope, wire
    codecs, autotune cache coverage), computes jitted ``gr_matmul`` block
    products, and accumulates chunked shares into partial products so
    transfer and compute overlap;
  * :mod:`repro.dist.master` — the master: accepts workers, tracks
    heartbeats and membership (``core.straggler.MembershipEvents``),
    dispatches per-worker ``encode_*_at`` shares (pipelined in
    contraction-axis chunks when they are large), re-dispatches the shares
    of workers that die mid-request, and fires the LRU-cached any-R
    ``decode_op`` at the R-th response; plus :class:`LocalPool`, which
    spawns a local master + N worker OS processes in one call;
  * :mod:`repro.dist.launch` — the multi-host launcher
    (``python -m repro.dist.launch --hostfile hosts.txt``): hostfile or
    SPMD-style env rank-wiring, per-host worker counts, TCP endpoints;
    :class:`LocalPool` is its single-host specialization;
  * :mod:`repro.dist.scheduler` — a serving scheduler (bounded queue,
    admission control, per-spec plan cache) so one pool serves many
    concurrent matmul requests;
  * :mod:`repro.dist.pool_backend` — :class:`PoolBackend`, registered as
    ``coded_matmul(A, B, plan, backend="pool")``.

Importing this package registers the ``"pool"`` backend; ``cdmm.backends``
also lazy-imports it on first use, so the one-line switch works without an
explicit ``import repro.dist``.

Determinism: encode runs master-side (same process, same bits as
LocalSim), worker compute is exact integer ring arithmetic (bit-identical
across processes; chunked partial products accumulate with exact ring
addition), and the decode subset is the canonical sorted first-R
arrival set — so a fixed encode key gives bit-identical results to
``LocalSimBackend`` even under real worker deaths (property-tested in
tests/test_conformance.py and tests/test_dist.py).
"""
from repro.cdmm.backends import register_backend

from .config import Endpoint, HostSpec, PoolConfig, parse_hostfile
from .launch import HostPool, launch_pool, spawn_local_workers
from .master import LocalPool, Master, PoolStats, WorkerDied
from .pool_backend import PoolBackend, default_pool, shutdown_default_pool
from .protocol import recv_msg, send_msg
from .scheduler import PoolScheduler, SchedulerSaturated

register_backend("pool", PoolBackend)

__all__ = [
    "Endpoint",
    "HostPool",
    "HostSpec",
    "LocalPool",
    "Master",
    "PoolBackend",
    "PoolConfig",
    "PoolScheduler",
    "PoolStats",
    "SchedulerSaturated",
    "WorkerDied",
    "default_pool",
    "launch_pool",
    "parse_hostfile",
    "shutdown_default_pool",
    "spawn_local_workers",
    "recv_msg",
    "send_msg",
]
