"""Unified pool/transport configuration: ``PoolConfig`` + ``Endpoint``.

Before this module, pool wiring lived in ad-hoc pieces: address strings
(``"tcp:HOST:PORT"``/``"unix:/path"``) parsed in three places, worker
counts from ``REPRO_POOL_WORKERS``, and heartbeat/codec knobs scattered
across ``LocalPool``/``Master`` signatures.  :class:`PoolConfig` is the
one value every entry point accepts — ``LocalPool(config=...)``,
``launch_pool(config)``, ``PoolBackend(config=...)``,
``coded_matmul(..., pool_config=...)`` and ``ServeScheduler(config=...)``
— and :class:`Endpoint` replaces raw address strings (the string forms
still parse, for compatibility).

Hostfile format (one host per line, ``#`` comments)::

    # host [slots=N] [port=P]
    10.0.0.4 slots=8
    10.0.0.5 slots=8 port=7777

Deprecated forms (``REPRO_POOL_WORKERS``, positional ``LocalPool`` args)
keep working through a shim that emits a single ``DeprecationWarning``
per process.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from typing import List, Optional, Tuple, Union

from repro import settings

# the warn-once registry lives in repro.settings now; re-exported here
# because existing callers (and tests) reach it as dist_config._WARNED
from repro.settings import _WARNED, warn_deprecated_once  # noqa: F401

__all__ = [
    "Endpoint",
    "HostSpec",
    "PoolConfig",
    "parse_hostfile",
]


@dataclass(frozen=True)
class Endpoint:
    """A listener/connect endpoint: TCP host+port or a Unix-domain path.

    Replaces the ``"tcp:HOST:PORT"`` / ``"unix:/path"`` strings that used
    to be parsed ad hoc at every call site; ``Endpoint.parse`` accepts
    those strings (and Endpoint instances, idempotently) so existing
    addresses keep working.
    """

    kind: str  # "tcp" | "unix"
    host: str = ""
    port: int = 0
    path: str = ""

    @classmethod
    def tcp(cls, host: str = "127.0.0.1", port: int = 0) -> "Endpoint":
        return cls(kind="tcp", host=host, port=int(port))

    @classmethod
    def unix(cls, path: str) -> "Endpoint":
        return cls(kind="unix", path=path)

    @classmethod
    def parse(cls, value: Union[str, "Endpoint"]) -> "Endpoint":
        if isinstance(value, Endpoint):
            return value
        kind, _, rest = str(value).partition(":")
        if kind == "unix" and rest:
            return cls.unix(rest)
        if kind == "tcp" and rest:
            host, _, port = rest.rpartition(":")
            if host and port.lstrip("-").isdigit() and int(port) >= 0:
                return cls.tcp(host, int(port))
        raise ValueError(
            f"bad endpoint {value!r}; expected tcp:HOST:PORT or unix:/path"
        )

    @property
    def address(self) -> str:
        """The canonical address string the wire layer consumes."""
        if self.kind == "unix":
            return f"unix:{self.path}"
        return f"tcp:{self.host}:{self.port}"

    def __str__(self) -> str:
        return self.address


@dataclass(frozen=True)
class HostSpec:
    """One hostfile row: a host and how many worker slots it contributes."""

    host: str
    slots: int = 1
    port: int = 0  # optional per-host connect port override (0 = master's)

    @property
    def is_local(self) -> bool:
        import socket as _socket

        return self.host in (
            "localhost", "127.0.0.1", "::1", _socket.gethostname(),
        )


def parse_hostfile(source: str) -> Tuple[HostSpec, ...]:
    """Parse hostfile text *or* a path to one into ``(HostSpec, ...)``."""
    if os.path.exists(source):
        with open(source) as f:
            text = f.read()
    else:
        text = source
    hosts: List[HostSpec] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        host, slots, port = parts[0], 1, 0
        for opt in parts[1:]:
            k, _, v = opt.partition("=")
            if k == "slots" and v.isdigit():
                slots = int(v)
            elif k == "port" and v.isdigit():
                port = int(v)
            else:
                raise ValueError(
                    f"hostfile line {lineno}: unknown option {opt!r} "
                    f"(expected slots=N or port=P)"
                )
        hosts.append(HostSpec(host=host, slots=slots, port=port))
    if not hosts:
        raise ValueError("hostfile has no host entries")
    return tuple(hosts)


@dataclass(frozen=True)
class PoolConfig:
    """Everything needed to bring up and talk to a worker pool.

    ``workers`` is the local worker count when no ``hosts`` are given;
    with ``hosts`` the per-host ``slots`` govern and ``total_workers``
    sums them.  ``transport`` picks the share wire codec: ``"auto"``
    (best both sides support — packed+compressed when available),
    ``"raw"``, ``"pack"``, ``"pack+zlib"``, ``"pack+zstd"``.
    ``stream_chunk_bytes`` > 0 pipelines share transfer in chunks of
    roughly that many raw bytes so encode/transfer/compute overlap
    (0 disables streaming).

    Telemetry/hedging (None = resolve from the matching ``repro.settings``
    knob): ``obs_http_port`` starts the embedded admin server
    (:mod:`repro.obs.http`; 0 = ephemeral port), ``hedge_factor`` > 0
    enables speculative re-dispatch of shares outstanding past
    p95(recent round-trips) x factor, ``health_ewma`` smooths the
    per-worker health signals feeding dispatch order and hedging.
    """

    workers: int = 4
    hosts: Tuple[HostSpec, ...] = ()
    endpoint: Optional[Endpoint] = None
    transport: str = "auto"
    compression_level: int = 3
    stream_chunk_bytes: int = 1 << 20
    heartbeat_s: float = 0.5
    heartbeat_timeout: float = 5.0
    request_timeout: Optional[float] = None
    use_kernel: Optional[bool] = None
    spawn_timeout: float = 120.0
    obs_http_port: Optional[int] = None
    hedge_factor: Optional[float] = None
    health_ewma: Optional[float] = None

    def __post_init__(self):
        if isinstance(self.endpoint, str):
            object.__setattr__(self, "endpoint", Endpoint.parse(self.endpoint))
        if isinstance(self.hosts, list):
            object.__setattr__(self, "hosts", tuple(self.hosts))
        valid = ("auto", "raw", "pack", "pack+zlib", "pack+zstd")
        if self.transport not in valid:
            raise ValueError(
                f"transport {self.transport!r} not one of {valid}"
            )

    @property
    def total_workers(self) -> int:
        if self.hosts:
            return sum(h.slots for h in self.hosts)
        return self.workers

    @property
    def multi_host(self) -> bool:
        return any(not h.is_local for h in self.hosts)

    def with_(self, **changes) -> "PoolConfig":
        return replace(self, **changes)

    @classmethod
    def from_hostfile(cls, source: str, **overrides) -> "PoolConfig":
        """Build a config from a hostfile (path or literal text).  A
        multi-host file forces a TCP listener on all interfaces unless an
        explicit ``endpoint`` override is given."""
        hosts = tuple(parse_hostfile(source))
        cfg = cls(hosts=hosts, **overrides)
        if cfg.endpoint is None and cfg.multi_host:
            cfg = cfg.with_(endpoint=Endpoint.tcp("0.0.0.0", 0))
        return cfg

    @classmethod
    def from_env(cls, env=os.environ, **overrides) -> "PoolConfig":
        """Config from the environment.

        Every variable resolves through :mod:`repro.settings` (see
        ``python -m repro.settings`` for the full documented list):
        ``REPRO_DIST_WORKERS``, ``REPRO_DIST_TRANSPORT``,
        ``REPRO_DIST_HOSTFILE``, ``REPRO_DIST_MASTER_ADDR``,
        ``REPRO_DIST_STREAM_CHUNK``.  The legacy ``REPRO_POOL_WORKERS``
        still works but emits one ``DeprecationWarning`` per process.
        """
        kw = dict(overrides)
        hostfile = settings.get("dist_hostfile", env)
        if hostfile is not None and "hosts" not in kw:
            kw["hosts"] = tuple(parse_hostfile(hostfile))
        if "workers" not in kw:
            workers = settings.get_int("dist_workers", env)
            if workers is not None:
                kw["workers"] = workers
        transport = settings.get("dist_transport", env)
        if transport is not None and "transport" not in kw:
            kw["transport"] = transport
        master_addr = settings.get("dist_master_addr", env)
        if master_addr is not None and "endpoint" not in kw:
            kw["endpoint"] = Endpoint.parse(master_addr)
        chunk = settings.get_int("dist_stream_chunk", env)
        if chunk is not None and "stream_chunk_bytes" not in kw:
            kw["stream_chunk_bytes"] = chunk
        for name, getter in (
            ("obs_http_port", settings.get_int),
            ("hedge_factor", settings.get_float),
            ("health_ewma", settings.get_float),
        ):
            val = getter(name, env)
            if val is not None and name not in kw:
                kw[name] = val
        return cls(**kw)
