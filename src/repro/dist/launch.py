"""Multi-host pool launcher: ``python -m repro.dist.launch --hostfile ...``.

One config, three ways to bring a pool up:

- **Hostfile** — ``launch_pool(PoolConfig.from_hostfile("hosts.txt"))``:
  the master listens on a TCP endpoint; every *local* host entry gets a
  worker-group agent process (its own session, so a whole simulated host
  can be SIGKILLed as one unit); remote entries are driven over ``ssh``
  when ``REPRO_DIST_SSH=1``, otherwise the launcher prints the exact
  worker-group command to run on each host and waits for them to dial in.
- **Env rank-wiring (SPMD-style)** — every process runs
  ``python -m repro.dist.launch`` with ``REPRO_DIST_RANK`` set: rank 0
  binds ``REPRO_DIST_MASTER_ADDR``, spawns its local workers and waits
  for the world; ranks > 0 run a worker group against the master address
  and block until it hangs up.
- **Local** — no hosts in the config: :func:`launch_pool` degenerates to
  :class:`repro.dist.LocalPool` (which itself spawns through
  :func:`spawn_local_workers` here — the local pool is the single-host
  specialization of this launcher, not a separate code path).

``--smoke`` runs the multi-host acceptance check used by CI: bring the
pool up per the hostfile, run a planned coded matmul while SIGKILLing one
whole worker group mid-request, and assert (a) the decode equals the
single-process oracle bit for bit and (b) compressed transport put fewer
bytes on the wire than the raw share payloads.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import time
from typing import Dict, List, Optional

from repro import settings

from .config import Endpoint, HostSpec, PoolConfig

__all__ = [
    "HostPool",
    "launch_from_env",
    "launch_pool",
    "main",
    "spawn_local_workers",
    "worker_group",
]


def spawn_local_workers(
    address: str,
    count: int,
    heartbeat_s: float = 0.5,
    name_prefix: str = "local",
) -> List[subprocess.Popen]:
    """Spawn ``count`` worker OS processes dialing ``address``.

    The one place worker processes are forked — LocalPool and the
    hostfile/env worker groups all come through here.
    """
    from .master import _worker_env

    env = _worker_env()
    # REPRO_POOL_LOG=1 lets worker stderr through for debugging
    sink = None if settings.get_bool("pool_log") else subprocess.DEVNULL
    procs = []
    for i in range(count):
        procs.append(subprocess.Popen(
            [
                sys.executable, "-m", "repro.dist.worker",
                "--connect", str(address),
                "--name", f"{name_prefix}-{i}",
                "--heartbeat", str(heartbeat_s),
            ],
            env=env,
            stdout=subprocess.DEVNULL,
            stderr=sink,
        ))
    return procs


def worker_group(
    address: str, count: int, heartbeat_s: float = 0.5,
    name_prefix: str = "host",
) -> int:
    """The per-host agent: spawn ``count`` workers against the master and
    wait until they exit (they exit when the master hangs up)."""
    procs = spawn_local_workers(
        address, count, heartbeat_s=heartbeat_s, name_prefix=name_prefix
    )
    code = 0
    try:
        for p in procs:
            code = max(code, p.wait() or 0)
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover
                p.kill()
    return code


class HostPool:
    """A master plus one worker group per hostfile entry.

    Local host entries become agent subprocesses in their own sessions
    (``kill_host(k)`` SIGKILLs the whole group — a machine failure, not a
    process failure).  Remote entries run the printed/ssh'd worker-group
    command and are out of this process's kill reach.  Same execute
    surface as :class:`~repro.dist.master.LocalPool`.
    """

    def __init__(self, config: PoolConfig):
        from .master import Master, _worker_env

        if not config.hosts:
            raise ValueError("HostPool needs config.hosts; use LocalPool")
        cfg = config
        if cfg.endpoint is None:
            host = "0.0.0.0" if cfg.multi_host else "127.0.0.1"
            cfg = cfg.with_(endpoint=Endpoint.tcp(host, 0))
        self.config = cfg
        self.master = Master(config=cfg)
        connect_addr = self._advertised_address()
        self.agents: List[subprocess.Popen] = []
        pending_remote: List[HostSpec] = []
        env = _worker_env()
        sink = (None if settings.get_bool("pool_log")
                else subprocess.DEVNULL)
        for idx, spec in enumerate(cfg.hosts):
            addr = connect_addr
            if spec.port:
                ep = Endpoint.parse(connect_addr)
                addr = Endpoint.tcp(ep.host, spec.port).address
            cmd = [
                sys.executable, "-m", "repro.dist.launch",
                "--role", "workers", "--connect", addr,
                "--workers", str(spec.slots),
                "--heartbeat", str(cfg.heartbeat_s),
                "--name-prefix", f"host{idx}",
            ]
            if spec.is_local:
                # own session => one killpg takes down the whole "host"
                self.agents.append(subprocess.Popen(
                    cmd, env=env, stdout=subprocess.DEVNULL, stderr=sink,
                    start_new_session=True,
                ))
            elif os.environ.get("REPRO_DIST_SSH") and shutil.which("ssh"):
                self.agents.append(subprocess.Popen(
                    ["ssh", spec.host, "--"] + cmd,
                    stdout=subprocess.DEVNULL, stderr=sink,
                    start_new_session=True,
                ))
            else:
                pending_remote.append(spec)
        if pending_remote:
            for spec in pending_remote:
                print(
                    f"[repro.dist.launch] run on {spec.host}: "
                    f"python -m repro.dist.launch --role workers "
                    f"--connect {connect_addr} --workers {spec.slots}",
                    file=sys.stderr,
                )
        try:
            self.master.wait_for_workers(
                cfg.total_workers, timeout=cfg.spawn_timeout
            )
        except TimeoutError:
            self.close()
            raise

    def _advertised_address(self) -> str:
        """The address workers dial: the bound endpoint, with a wildcard
        host rewritten to something routable."""
        ep = Endpoint.parse(self.master.address)
        if ep.kind == "tcp" and ep.host in ("0.0.0.0", "::"):
            import socket as _socket

            host = os.environ.get("REPRO_DIST_ADVERTISE")
            if not host:
                host = (
                    _socket.gethostname() if self.config.multi_host
                    else "127.0.0.1"
                )
            ep = Endpoint.tcp(host, ep.port)
        return ep.address

    @property
    def address(self) -> str:
        return self.master.address

    def execute(self, scheme, A, B, mask=None, key=None, timeout=None,
                batch_fill=None):
        return self.master.execute(scheme, A, B, mask=mask, key=key,
                                   timeout=timeout, batch_fill=batch_fill)

    def stats(self) -> Dict[str, object]:
        return self.master.stats()

    def kill_host(self, idx: int = 0) -> int:
        """SIGKILL one whole worker group (simulates a host failure);
        returns the number of groups killed (0 if already gone)."""
        if idx >= len(self.agents):
            return 0
        agent = self.agents[idx]
        if agent.poll() is not None:
            return 0
        os.killpg(os.getpgid(agent.pid), signal.SIGKILL)
        agent.wait(timeout=30)
        return 1

    def alive_hosts(self) -> int:
        return sum(1 for a in self.agents if a.poll() is None)

    def close(self) -> None:
        self.master.close()
        for a in self.agents:
            if a.poll() is None:
                try:
                    os.killpg(os.getpgid(a.pid), signal.SIGTERM)
                except (OSError, ProcessLookupError):
                    a.terminate()
        for a in self.agents:
            try:
                a.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover
                try:
                    os.killpg(os.getpgid(a.pid), signal.SIGKILL)
                except (OSError, ProcessLookupError):
                    a.kill()
                a.wait(timeout=10)

    def __enter__(self) -> "HostPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def launch_pool(config: PoolConfig):
    """Bring up a pool per ``config``: :class:`HostPool` when host entries
    are present, :class:`~repro.dist.master.LocalPool` otherwise."""
    if config.hosts:
        return HostPool(config)
    from .master import LocalPool

    return LocalPool(config=config)


def launch_from_env(config: Optional[PoolConfig] = None):
    """SPMD-style rank wiring: every participating process runs this with
    ``REPRO_DIST_RANK`` / ``REPRO_DIST_MASTER_ADDR`` /
    ``REPRO_DIST_WORKERS`` (per-rank worker count) and, on rank 0,
    ``REPRO_DIST_WORLD_WORKERS`` (total to wait for).

    Rank 0 returns the pool object; other ranks serve their worker group
    until the master hangs up and return ``None``.
    """
    rank = int(os.environ.get("REPRO_DIST_RANK", "0"))
    cfg = config or PoolConfig.from_env()
    if rank != 0:
        addr = os.environ["REPRO_DIST_MASTER_ADDR"]
        worker_group(addr, cfg.workers, heartbeat_s=cfg.heartbeat_s,
                     name_prefix=f"rank{rank}")
        return None
    from .master import LocalPool, Master

    world = int(os.environ.get("REPRO_DIST_WORLD_WORKERS", "0"))
    if world <= cfg.workers:  # single-rank world: plain local pool
        return LocalPool(config=cfg)
    # rank 0 hosts the master + its own local workers, then waits for the
    # other ranks' worker groups to dial in
    master = Master(config=cfg if cfg.endpoint else cfg.with_(
        endpoint=Endpoint.tcp("0.0.0.0", 0)
    ))
    ep = Endpoint.parse(master.address)
    local_addr = (
        Endpoint.tcp("127.0.0.1", ep.port).address
        if ep.kind == "tcp" and ep.host in ("0.0.0.0", "::") else ep.address
    )
    procs = spawn_local_workers(
        local_addr, cfg.workers, heartbeat_s=cfg.heartbeat_s,
        name_prefix="rank0",
    )
    pool = _EnvPool(master, procs)
    master.wait_for_workers(world, timeout=cfg.spawn_timeout)
    return pool


class _EnvPool:
    """Thin pool wrapper for env-rank launches (rank 0 side)."""

    def __init__(self, master, procs):
        self.master = master
        self.procs = procs

    @property
    def address(self):
        return self.master.address

    def execute(self, *a, **kw):
        return self.master.execute(*a, **kw)

    def stats(self):
        return self.master.stats()

    def close(self):
        self.master.close()
        for p in self.procs:
            if p.poll() is None:
                p.terminate()
        for p in self.procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover
                p.kill()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# --------------------------------------------------------------------------
# smoke: the CI multihost acceptance check
# --------------------------------------------------------------------------


def run_multihost_smoke(
    hostfile: str,
    transport: str = "pack+zlib",
    kill_hosts: int = 1,
    size: int = 96,
    seed: int = 0,
    stream_chunk_bytes: int = 1 << 16,
) -> Dict[str, object]:
    """Launcher-level smoke: pool per hostfile, one simulated host SIGKILL
    mid-request, oracle bit-equality, and wire < raw bytes under a
    compressed transport.  Raises on any violated invariant."""
    import numpy as np

    from repro.cdmm import ProblemSpec, plan
    from repro.core import make_ring

    cfg = PoolConfig.from_hostfile(
        hostfile, transport=transport,
        stream_chunk_bytes=stream_chunk_bytes,
        heartbeat_timeout=2.0,
    )
    # Z_2^16 shares in uint32 carriers: bit-packing alone halves the wire
    ring = make_ring(2, 16, ())
    N = cfg.total_workers
    spec = ProblemSpec(t=size, r=size, s=size, n=1, ring=ring, N=N,
                       straggler_budget=1)
    # share indices are multiplexed round-robin, so even a whole dead host
    # re-dispatches onto the survivors — any R distinct share responses
    # decode, whichever processes computed them
    p = plan(spec, objective="threshold")
    rank = max(range(len(p.candidates)),
               key=lambda i: p.candidates[i].costs.R)
    scheme = p.instantiate(rank)
    rng = np.random.default_rng(seed)
    A = ring.random(rng, (size, size))
    B = ring.random(rng, (size, size))
    oracle = np.asarray(ring.matmul(A, B))

    with launch_pool(cfg) as pool:
        # warm round: every worker jits the ring closure before the race
        C0, _ = pool.execute(scheme, A, B)
        if not np.array_equal(np.asarray(C0), oracle):
            raise AssertionError("warm-round decode != oracle")
        # park every worker briefly so the host SIGKILL lands mid-request
        for wid in pool.master.live_workers():
            pool.master.task_delay_ms[wid] = 400.0
        import threading

        killed = []
        if kill_hosts > 0 and isinstance(pool, HostPool):
            def _assassin():
                time.sleep(0.15)
                for k in range(kill_hosts):
                    killed.append(pool.kill_host(k))

            t = threading.Thread(target=_assassin, daemon=True)
            t.start()
        C, stats = pool.execute(scheme, A, B, timeout=120.0)
        pool.master.task_delay_ms.clear()
        snap = pool.stats()

    if not np.array_equal(np.asarray(C), oracle):
        raise AssertionError("post-kill decode != oracle")
    if transport != "raw" and not (
        snap["bytes_out"] < snap["raw_bytes_out"]
    ):
        raise AssertionError(
            f"compressed transport put {snap['bytes_out']} bytes on the "
            f"wire >= raw {snap['raw_bytes_out']}"
        )
    return {
        "workers": N,
        "hosts": len(cfg.hosts),
        "hosts_killed": int(sum(killed)),
        "redispatched": stats.redispatched,
        "scheme": scheme.name,
        "R": scheme.R,
        "codecs": list(stats.codecs),
        "raw_bytes_out": snap["raw_bytes_out"],
        "bytes_out": snap["bytes_out"],
        "wire_ratio": (
            snap["raw_bytes_out"] / snap["bytes_out"]
            if snap["bytes_out"] else None
        ),
        "time_to_R_ms": stats.time_to_R_ms,
        "bit_identical": True,
    }


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--hostfile", metavar="PATH",
                    help="hosts, one per line: HOST [slots=N] [port=P]")
    ap.add_argument("--role", choices=["auto", "master", "workers"],
                    default="auto",
                    help="auto: hostfile/env decides; workers: run a "
                    "worker group against --connect")
    ap.add_argument("--connect", metavar="ADDR",
                    help="master address for --role workers")
    ap.add_argument("--workers", type=int, default=4,
                    help="worker count (per host for --role workers)")
    ap.add_argument("--heartbeat", type=float, default=0.5)
    ap.add_argument("--name-prefix", default="host")
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "raw", "pack", "pack+zlib",
                             "pack+zstd"])
    ap.add_argument("--port", type=int, default=0,
                    help="master listen port (0 = ephemeral)")
    ap.add_argument("--smoke", action="store_true",
                    help="multihost acceptance check: SIGKILL one host "
                    "group mid-request, assert oracle bit-equality and "
                    "wire bytes < raw bytes")
    ap.add_argument("--kill-hosts", type=int, default=1)
    ap.add_argument("--size", type=int, default=96)
    ap.add_argument("--stream-chunk", type=int, default=1 << 16,
                    help="pipelined streaming chunk size in bytes "
                    "(0 = ship whole shares)")
    args = ap.parse_args(argv)

    if args.role == "workers":
        if not args.connect:
            ap.error("--role workers requires --connect ADDR")
        return worker_group(args.connect, args.workers,
                            heartbeat_s=args.heartbeat,
                            name_prefix=args.name_prefix)

    if args.smoke:
        if not args.hostfile:
            ap.error("--smoke requires --hostfile")
        out = run_multihost_smoke(
            args.hostfile, transport=args.transport,
            kill_hosts=args.kill_hosts, size=args.size,
            stream_chunk_bytes=args.stream_chunk,
        )
        print(json.dumps(out, indent=2))
        ok = out["bit_identical"] and (
            args.transport == "raw"
            or out["bytes_out"] < out["raw_bytes_out"]
        )
        print("MULTIHOST SMOKE " + ("OK" if ok else "FAILED"))
        return 0 if ok else 1

    if "REPRO_DIST_RANK" in os.environ and not args.hostfile:
        pool = launch_from_env()
        if pool is None:
            return 0  # worker rank: group served until master hangup
        print(f"pool up at {pool.address}; Ctrl-C to stop")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            pool.close()
        return 0

    if not args.hostfile:
        ap.error("need --hostfile, --role workers, or REPRO_DIST_RANK")
    cfg = PoolConfig.from_hostfile(
        args.hostfile, transport=args.transport,
        endpoint=(Endpoint.tcp("0.0.0.0", args.port) if args.port else None),
    )
    pool = launch_pool(cfg)
    print(f"pool up at {pool.address} "
          f"({cfg.total_workers} workers / {len(cfg.hosts)} hosts); "
          f"Ctrl-C to stop")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        pool.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
