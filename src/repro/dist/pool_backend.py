"""``coded_matmul(A, B, plan, backend="pool")`` — the one-line switch.

:class:`PoolBackend` adapts a pool master to the execution-backend
protocol every other backend implements (``__call__(scheme, A, B, mask,
key)``), so the same planned scheme that runs vmapped in-process runs over
real worker OS processes by changing one string.  With no explicit pool it
lazily spawns a shared process-global :class:`~repro.dist.master.LocalPool`
(``REPRO_POOL_WORKERS`` processes, default 4) on first use and reaps it at
interpreter exit — `zero-config`, mirroring how ShardMapBackend conjures a
host-device mesh.
"""
from __future__ import annotations

import atexit
import os
import threading
from typing import Optional, Union

from .master import LocalPool, Master, PoolStats

__all__ = ["PoolBackend", "default_pool", "shutdown_default_pool"]

_default_pool: Optional[LocalPool] = None
_default_lock = threading.Lock()


def default_pool(workers: Optional[int] = None) -> LocalPool:
    """The shared process-global LocalPool, spawned on first use.

    ``workers`` defaults to ``REPRO_POOL_WORKERS`` (4).  Pool size is
    independent of any scheme's N: the master multiplexes share indices
    round-robin over however many processes exist.
    """
    global _default_pool
    with _default_lock:
        if _default_pool is None:
            n = workers or int(os.environ.get("REPRO_POOL_WORKERS", "4"))
            _default_pool = LocalPool(workers=n)
            atexit.register(shutdown_default_pool)
        elif workers is not None and workers != len(_default_pool.procs):
            import warnings

            warnings.warn(
                f"default_pool(workers={workers}) reuses the existing "
                f"{len(_default_pool.procs)}-process shared pool; build a "
                f"LocalPool(workers={workers}) explicitly for a dedicated "
                f"pool of that size",
                stacklevel=2,
            )
        return _default_pool


def shutdown_default_pool() -> None:
    global _default_pool
    with _default_lock:
        pool, _default_pool = _default_pool, None
    if pool is not None:
        pool.close()


class PoolBackend:
    """Execute the coded-matmul protocol on a multi-process worker pool."""

    name = "pool"

    def __init__(
        self,
        pool: Union[None, Master, LocalPool] = None,
        workers: Optional[int] = None,
        timeout: Optional[float] = None,
    ):
        self._pool = pool
        self._workers = workers
        self.timeout = timeout
        self.last_stats: Optional[PoolStats] = None

    @property
    def master(self) -> Master:
        pool = self._pool if self._pool is not None else default_pool(self._workers)
        return pool.master if isinstance(pool, LocalPool) else pool

    def __call__(self, scheme, A, B, mask=None, key=None):
        C, self.last_stats = self.master.execute(
            scheme, A, B, mask=mask, key=key, timeout=self.timeout
        )
        return C
