"""``coded_matmul(A, B, plan, backend="pool")`` — the one-line switch.

:class:`PoolBackend` adapts a pool master to the execution-backend
protocol every other backend implements (``__call__(scheme, A, B, mask,
key)``), so the same planned scheme that runs vmapped in-process runs over
real worker OS processes by changing one string.  With no explicit pool it
lazily spawns a shared process-global :class:`~repro.dist.master.LocalPool`
on first use and reaps it at interpreter exit — `zero-config`, mirroring
how ShardMapBackend conjures a host-device mesh.  Pool shape and transport
come from a :class:`~repro.dist.config.PoolConfig` (``config=`` here, or
``coded_matmul(..., pool_config=...)`` one level up); the legacy
``REPRO_POOL_WORKERS`` env var still works through
``PoolConfig.from_env``'s deprecation shim.
"""
from __future__ import annotations

import atexit
import threading
from typing import Optional, Union

from .config import PoolConfig
from .master import LocalPool, Master, PoolStats

__all__ = ["PoolBackend", "default_pool", "shutdown_default_pool"]

_default_pool: Optional[LocalPool] = None
_default_lock = threading.Lock()


def default_pool(
    workers: Optional[int] = None, config: Optional[PoolConfig] = None
) -> LocalPool:
    """The shared process-global LocalPool, spawned on first use.

    Shape comes from ``config`` (or ``PoolConfig.from_env()``, which
    honors ``REPRO_DIST_WORKERS`` and — deprecated, one warning — the old
    ``REPRO_POOL_WORKERS``).  Pool size is independent of any scheme's N:
    the master multiplexes share indices round-robin over however many
    processes exist.
    """
    global _default_pool
    with _default_lock:
        if _default_pool is None:
            cfg = config or PoolConfig.from_env()
            if workers is not None:
                cfg = cfg.with_(workers=workers)
            _default_pool = LocalPool(config=cfg)
            atexit.register(shutdown_default_pool)
        elif workers is not None and workers != len(_default_pool.procs):
            import warnings

            warnings.warn(
                f"default_pool(workers={workers}) reuses the existing "
                f"{len(_default_pool.procs)}-process shared pool; build a "
                f"LocalPool(workers={workers}) explicitly for a dedicated "
                f"pool of that size",
                stacklevel=2,
            )
        return _default_pool


def shutdown_default_pool() -> None:
    global _default_pool
    with _default_lock:
        pool, _default_pool = _default_pool, None
    if pool is not None:
        pool.close()


class PoolBackend:
    """Execute the coded-matmul protocol on a multi-process worker pool.

    ``pool`` may be an existing Master/LocalPool/HostPool; with
    ``config=`` and no pool, the backend owns a dedicated pool built from
    the config (spawned lazily, closed by :meth:`close` or at interpreter
    exit); with neither, the shared process-global default pool serves.
    """

    name = "pool"

    def __init__(
        self,
        pool: Union[None, Master, LocalPool] = None,
        workers: Optional[int] = None,
        timeout: Optional[float] = None,
        config: Optional[PoolConfig] = None,
    ):
        self._pool = pool
        self._workers = workers
        self._config = config
        self._owned = None  # the pool this backend spawned from config=
        self.timeout = (
            timeout if timeout is not None
            else (config.request_timeout if config else None)
        )
        self.last_stats: Optional[PoolStats] = None

    @property
    def master(self) -> Master:
        pool = self._pool
        if pool is None and self._config is not None:
            if self._owned is None:
                from .launch import launch_pool

                self._owned = launch_pool(self._config)
                atexit.register(self.close)
            pool = self._owned
        if pool is None:
            pool = default_pool(self._workers)
        return pool.master if hasattr(pool, "master") else pool

    def stats(self):
        """Cumulative master accounting (shared repro.stats schema)."""
        return self.master.stats()

    def close(self) -> None:
        """Shut down the config-owned pool (no-op for shared/borrowed)."""
        owned, self._owned = self._owned, None
        if owned is not None:
            owned.close()

    def __call__(self, scheme, A, B, mask=None, key=None):
        C, self.last_stats = self.master.execute(
            scheme, A, B, mask=mask, key=key, timeout=self.timeout
        )
        return C
