"""Serving scheduler: one pool, many concurrent coded-matmul requests.

The master multiplexes tasks by request id, so nothing stops N requests
from being in flight at once — but a serving system needs *policy* on top
of that mechanism: how many requests may be in flight (``max_inflight``
dispatcher threads), how many may wait (a bounded admission queue —
``submit`` raises :class:`SchedulerSaturated` instead of buffering
unboundedly, so the caller can shed load), and how to avoid re-planning
and re-instantiating a scheme for every request of the same shape (a
per-spec plan cache keyed by ``(ProblemSpec, objective)``; plans rank with
the pool's own calibration coefficients when ``benchmarks/calibration.json``
carries a ``pool`` fit, falling back to ``local``).

Usage::

    pool = LocalPool(workers=8)
    sched = PoolScheduler(pool.master, max_queue=32, max_inflight=4)
    fut = sched.submit(A, B, spec=spec)          # non-blocking, may raise
    C = fut.result()                              # blocks for this request
    sched.close(); pool.close()
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, Optional, Tuple

from repro.cdmm.api import CdmmScheme, ProblemSpec
from repro.cdmm.planner import plan
from repro.obs import http as obs_http
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.stats import StatsSnapshot

__all__ = ["PoolScheduler", "SchedulerSaturated", "SchedulerStats"]


class SchedulerSaturated(RuntimeError):
    """Admission control rejected the request: the bounded queue is full.
    Callers shed load (retry with backoff, route elsewhere) instead of the
    scheduler buffering without bound."""


class SchedulerStats:
    """Scheduler counters, registry-backed for the live telemetry plane.

    Recording is in-line (``_bump`` is one counter ``inc``); the legacy
    attribute reads (``stats.completed``) and ``snapshot()`` both read
    the same live :class:`repro.obs.metrics.MetricsRegistry` the HTTP
    ``/metrics``/``/stats`` endpoints scrape.
    """

    _COUNTERS = (
        "submitted", "rejected", "completed", "failed", "timed_out",
        "plan_cache_hits", "plan_cache_misses",
    )

    def __init__(self) -> None:
        self.metrics = MetricsRegistry("scheduler")
        self._counters = {
            name: self.metrics.counter(name) for name in self._COUNTERS
        }
        # submit-to-completion latency in the shared repro.stats schema
        # (request_ms_hist / _p50 / _p99 / _sum in snapshots)
        self.request_ms = self.metrics.histogram(
            "request_ms", "submit -> result latency (ms)"
        )

    def _bump(self, name: str) -> None:
        self._counters[name].inc()

    def __getattr__(self, name: str):
        # legacy attribute reads (stats.completed == 6) resolve to the
        # live counter values; __getattr__ only fires for names not in
        # __dict__, so the instruments above stay ordinary attributes
        counters = self.__dict__.get("_counters") or {}
        if name in counters:
            return counters[name].value
        raise AttributeError(name)

    def snapshot(self) -> StatsSnapshot:
        """Every counter plus the request-latency histogram family, in
        the shared ``repro.stats`` snapshot schema (``scheduler_``-
        prefixed keys; legacy unprefixed names resolve with one
        DeprecationWarning)."""
        return self.metrics.snapshot()


class PoolScheduler:
    """Bounded-queue admission control + plan cache over one pool master."""

    def __init__(
        self,
        master,
        max_queue: int = 32,
        max_inflight: int = 4,
        objective: str = "latency",
        request_timeout: Optional[float] = None,
    ):
        self.master = master
        self.objective = objective
        self.request_timeout = request_timeout
        self.stats = SchedulerStats()
        # the admin HTTP plane scrapes this scheduler alongside its pool
        self._obs_source = obs_http.register_source(
            "scheduler", self.stats.snapshot
        )
        self._queue: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self._plans: Dict[Tuple[ProblemSpec, str], CdmmScheme] = {}
        self._plans_lock = threading.Lock()
        self._closed = False
        self._threads = [
            threading.Thread(
                target=self._dispatch_loop, name=f"pool-sched-{i}", daemon=True
            )
            for i in range(max_inflight)
        ]
        for t in self._threads:
            t.start()

    # -- plan cache --------------------------------------------------------

    def scheme_for(self, spec: ProblemSpec) -> CdmmScheme:
        """The executable scheme serving ``spec`` (planned once, reused for
        every request of that shape)."""
        key = (spec, self.objective)
        with self._plans_lock:
            scheme = self._plans.get(key)
        if scheme is not None:
            self.stats._bump("plan_cache_hits")
            return scheme
        self.stats._bump("plan_cache_misses")
        built = plan(spec, objective=self.objective,
                     backend="pool").instantiate()
        with self._plans_lock:
            # a racing planner for the same spec wins idempotently
            scheme = self._plans.setdefault(key, built)
        return scheme

    # -- admission ---------------------------------------------------------

    def submit(
        self,
        A,
        B,
        spec: Optional[ProblemSpec] = None,
        scheme: Optional[CdmmScheme] = None,
        mask=None,
        key=None,
    ) -> Future:
        """Admit one request; returns a Future of the decoded product.

        Exactly one of ``spec`` (planned + cached) or ``scheme`` (already
        built) selects the code.  Raises :class:`SchedulerSaturated` when
        the admission queue is full.
        """
        if (spec is None) == (scheme is None):
            raise ValueError("pass exactly one of spec= or scheme=")
        if self._closed:
            raise RuntimeError("scheduler is closed")
        if scheme is None:
            scheme = self.scheme_for(spec)
        fut: Future = Future()
        trace = obs.maybe_context("req")
        fut.trace_id = trace.trace_id if trace is not None else None
        try:
            self._queue.put_nowait(
                (fut, scheme, A, B, mask, key, time.perf_counter(), trace)
            )
        except queue.Full:
            self.stats._bump("rejected")
            raise SchedulerSaturated(
                f"admission queue full ({self._queue.maxsize} waiting); "
                f"shed load or raise max_queue"
            ) from None
        self.stats._bump("submitted")
        return fut

    def trace(self, fut_or_trace_id) -> obs.Timeline:
        """The merged timeline of one submitted request: queue wait,
        per-share encode/send, every responder's compute span, decode.
        Accepts the Future returned by :meth:`submit` (its ``trace_id``
        attribute) or a trace id string."""
        tid = getattr(fut_or_trace_id, "trace_id", fut_or_trace_id)
        if tid is None:
            raise ValueError(
                "request was not traced (enable with REPRO_TRACE=1 or "
                "repro.obs.set_enabled(True) before submit)"
            )
        return obs.tracer().timeline(tid)

    # -- dispatch ----------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            fut, scheme, A, B, mask, key, t_submit, trace = item
            if not fut.set_running_or_notify_cancel():
                continue
            if trace is not None:
                # admission-queue dwell: submit() -> this dispatch slot
                t1 = obs.now()
                obs.tracer().add(
                    trace, "queue_wait", "scheduler",
                    t1 - (time.perf_counter() - t_submit), t1,
                )
            # request_timeout is a deadline from submit(): time spent
            # waiting in the admission queue draws down the same budget
            # the pool execution gets, so a saturated scheduler fails
            # requests at the promised latency instead of stretching it
            remaining = None
            if self.request_timeout is not None:
                remaining = self.request_timeout - (
                    time.perf_counter() - t_submit
                )
                if remaining <= 0:
                    self.stats._bump("timed_out")
                    fut.set_exception(TimeoutError(
                        f"request spent its {self.request_timeout}s budget "
                        f"in the admission queue before dispatch"
                    ))
                    continue
            try:
                C, _ = self.master.execute(
                    scheme, A, B, mask=mask, key=key, timeout=remaining,
                    trace=trace,
                )
                self.stats._bump("completed")
                self.stats.request_ms.observe(
                    (time.perf_counter() - t_submit) * 1e3
                )
                fut.set_result(C)
            except BaseException as e:
                self.stats._bump(
                    "timed_out" if isinstance(e, TimeoutError) else "failed"
                )
                fut.set_exception(e)

    def close(self, drain: bool = True) -> None:
        """Stop the dispatchers.  ``drain=True`` serves queued requests
        first; ``drain=False`` cancels whatever is still waiting."""
        if self._closed:
            return
        self._closed = True
        obs_http.unregister_source(self._obs_source)
        if not drain:
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if item is not None:
                    item[0].cancel()
        for _ in self._threads:
            self._queue.put(None)
        for t in self._threads:
            t.join(timeout=30)
        # a submit racing this close can slip an item in behind the
        # sentinels after the dispatchers exited: cancel the leftovers so
        # no Future is left forever unresolved
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None:
                item[0].cancel()

    def __enter__(self) -> "PoolScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
