"""Pool master: real workers, heartbeats, death detection, any-R decode.

:class:`Master` listens on a socket, accepts worker registrations (the
``hello`` capability handshake, which now negotiates a wire codec per
connection — see :mod:`repro.dist.protocol`), and executes coded matmuls
against the pool: the master encodes per-worker shares with the same
jitted ``encode_*_at`` closures the elastic backend uses, ships each
share to a live worker process (chunked along the contraction axis when
``stream_chunk_bytes`` says the share is big enough to pipeline — the
worker accumulates partial products, so transfer and compute overlap),
and fires the LRU-cached any-R ``decode_op`` the moment the R-th
response lands — through :func:`repro.cdmm.elastic.decode_responses`,
the exact decode tail of the in-process elastic master, so the two paths
are bit-identical by construction.

Failure model.  A worker is dead when its socket drops (SIGKILL, crash,
network) or its heartbeat goes silent past ``heartbeat_timeout``.  Death
mid-request re-dispatches the worker's unanswered shares to surviving
workers (any process can compute any share — the share index, not the
process, is the paper's "worker"), so a request completes as long as one
process survives and R distinct shares can still be computed.  Membership
is tracked by :class:`repro.core.straggler.MembershipEvents`, so the
observed join/leave/response history is available as a real
:class:`~repro.core.straggler.WorkerTrace` (``Master.trace()``) and plugs
into everything built on trace semantics.

Shares are multiplexed: a pool of W processes serves schemes with any N
(round-robin assignment), decoupling pool size from the code's worker
count.  Requests are multiplexed too — every task carries a request id and
responses are routed to per-request queues — which is what lets the
serving scheduler (:mod:`repro.dist.scheduler`) keep several requests in
flight over one pool.

Bandwidth accounting: every connection counts pre-codec (raw) vs. on-wire
bytes; per-request totals land on :class:`PoolStats` and cumulative
totals (plus latency histograms in the shared ``repro.stats`` schema) on
``Master.stats()``.

:class:`LocalPool` spawns a master plus N ``python -m repro.dist.worker``
OS processes on a Unix-domain socket (TCP fallback) in one call, with
``kill()`` for failure injection and clean shutdown on ``close()`` — it
is the single-host specialization of :func:`repro.dist.launch.launch_pool`
and accepts the same :class:`~repro.dist.config.PoolConfig`.
"""
from __future__ import annotations

import math
import os
import queue
import signal
import socket
import subprocess
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cdmm.elastic import NotEnoughResponders, decode_responses, worker_closures
from repro.core.straggler import MembershipEvents
from repro.obs import trace as obs
from repro.stats import Histogram, StatsSnapshot, namespaced

from .config import Endpoint, PoolConfig, warn_deprecated_once
from .protocol import Channel, ProtocolError, listen, negotiate

__all__ = ["LocalPool", "Master", "PoolStats", "WorkerDied"]


def _shutdown_socket(sock: socket.socket) -> None:
    """Force-wake any thread blocked reading ``sock``, then close it.
    ``close()`` alone leaves a blocked ``recv`` sleeping forever;
    ``shutdown(SHUT_RDWR)`` delivers EOF first."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


class WorkerDied(RuntimeError):
    """A request became impossible: too few live workers remain to compute
    R distinct shares (every surviving share was already re-dispatched)."""


@dataclass(frozen=True)
class PoolStats:
    """Accounting of one pool execution (real wall-clock, real processes)."""

    dispatched: Tuple[int, ...]  # share indices shipped to workers
    live_idx: Tuple[int, ...]  # the R-subset actually decoded from
    workers: Tuple[int, ...]  # pool worker ids that served shares
    redispatched: int  # shares re-shipped after a worker death
    wall_ms: float  # master wall-clock for the call
    time_to_R_ms: float  # wall-clock until the R-th response landed
    batch: int = 1  # products the scheme packs per codeword (RMFE slots)
    fill: int = 1  # slots carrying real requests (rest were zero padding)
    # bandwidth accounting (shared schema: raw = pre-codec payload bytes,
    # bytes_* = what actually crossed the socket, framing included)
    raw_bytes_out: int = 0  # share payloads before the wire codec
    bytes_out: int = 0  # what the master actually sent
    raw_bytes_in: int = 0  # result payloads before the wire codec
    bytes_in: int = 0  # what the master actually received
    codecs: Tuple[str, ...] = ()  # negotiated codecs of the workers used


class _WorkerHandle:
    def __init__(self, wid: int, chan: Channel, caps: Dict):
        self.wid = wid
        self.chan = chan
        self.sock = chan.sock
        self.caps = caps
        self.codec = chan.codec
        self.name = caps.get("name", f"worker-{wid}")
        self.alive = True
        self.last_seen = time.time()
        self.send_lock = threading.Lock()

    def send(self, header: Dict, arrays=None,
             codec: Optional[str] = None) -> Tuple[int, int]:
        with self.send_lock:
            return self.chan.send(header, arrays, codec=codec)


class _Request:
    """Routing state of one in-flight coded matmul."""

    def __init__(self, rid: int, R: int,
                 trace: Optional[obs.TraceContext] = None):
        self.rid = rid
        self.R = R
        self.trace = trace
        self.events: "queue.Queue" = queue.Queue()
        self.lock = threading.Lock()
        # task_id -> (share index, fa, gb, wid currently assigned)
        self.pending: Dict[int, Tuple[int, np.ndarray, np.ndarray, int]] = {}
        self.redispatched = 0
        self.done = False
        # per-request bandwidth accounting (summed into PoolStats)
        self.raw_out = 0
        self.wire_out = 0
        self.raw_in = 0
        self.wire_in = 0
        self.codecs: set = set()


class Master:
    """Accept workers, track membership, execute coded matmuls on the pool."""

    def __init__(
        self,
        address: Optional[str] = None,
        heartbeat_timeout: Optional[float] = None,
        use_kernel: Optional[bool] = None,
        config: Optional[PoolConfig] = None,
    ):
        cfg = config or PoolConfig()
        if heartbeat_timeout is not None:
            cfg = cfg.with_(heartbeat_timeout=heartbeat_timeout)
        if use_kernel is not None:
            cfg = cfg.with_(use_kernel=use_kernel)
        if address is not None:
            cfg = cfg.with_(endpoint=Endpoint.parse(address))
        self.config = cfg
        listen_addr = (
            cfg.endpoint.address if cfg.endpoint else "tcp:127.0.0.1:0"
        )
        self._listener, self.address = listen(listen_addr)
        self.heartbeat_timeout = cfg.heartbeat_timeout
        # None = let each worker auto-select (kernel wherever it compiles on
        # the worker's device); True/False force it pool-wide
        self.use_kernel = cfg.use_kernel
        self.transport = cfg.transport
        self.compression_level = cfg.compression_level
        self.stream_chunk_bytes = cfg.stream_chunk_bytes
        self.membership = MembershipEvents()
        self._workers: Dict[int, _WorkerHandle] = {}
        self._requests: Dict[int, _Request] = {}
        self._lock = threading.Lock()
        self._joined = threading.Condition(self._lock)
        self._next_wid = 0
        self._next_rid = 0
        self._next_task = 0
        self._next_echo = 0
        self._echo_waiters: Dict[int, Tuple[threading.Event, List]] = {}
        self._rr = 0  # round-robin cursor for share -> worker assignment
        self._closed = False
        # cumulative accounting (shared repro.stats schema; see stats())
        self._stats_lock = threading.Lock()
        self._counters = {
            "requests": 0, "completed": 0, "failed": 0, "redispatched": 0,
            "raw_bytes_out": 0, "bytes_out": 0,
            "raw_bytes_in": 0, "bytes_in": 0,
        }
        self._wall_hist = Histogram()
        self._time_to_R_hist = Histogram()
        # rid -> trace_id of recently finished traced requests, so spans
        # from stragglers that answer after the any-R decode still land
        # on the right timeline (bounded: oldest entries roll off)
        self._done_traces: "Dict[int, str]" = {}
        self._done_traces_cap = 256
        # failure injection: per-worker-id compute delay stamped into task
        # headers (tests/CI park a victim's compute so SIGKILL lands mid-task)
        self.task_delay_ms: Dict[int, float] = {}
        # error injection: these workers raise instead of computing, which
        # exercises the bounded share-retry path without corrupting state
        self.task_fail_wids: set = set()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="pool-accept", daemon=True
        )
        self._accept_thread.start()
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, name="pool-monitor", daemon=True
        )
        self._monitor_thread.start()

    # -- membership --------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            threading.Thread(
                target=self._register, args=(sock,), daemon=True
            ).start()

    def _register(self, sock: socket.socket) -> None:
        try:
            chan = Channel(sock, level=self.compression_level)
            caps, _, _, _ = chan.recv()
        except (ProtocolError, OSError):
            sock.close()
            return
        if caps.get("type") != "hello":
            sock.close()
            return
        # per-connection codec: the strongest the peer decodes, or the
        # pinned transport when both sides support it; a v0 worker that
        # advertises nothing gets raw frames (full interop)
        chan.codec = negotiate(caps.get("codecs"), prefer=self.transport)
        with self._lock:
            wid = self._next_wid
            self._next_wid += 1
            handle = _WorkerHandle(wid, chan, caps)
            self._workers[wid] = handle
            self._joined.notify_all()
        self.membership.record_join(wid, time.time())
        threading.Thread(
            target=self._reader_loop, args=(handle,),
            name=f"pool-reader-{wid}", daemon=True,
        ).start()

    def _reader_loop(self, handle: _WorkerHandle) -> None:
        try:
            while True:
                header, arrays, raw, wire = handle.chan.recv()
                handle.last_seen = time.time()
                kind = header.get("type")
                if kind == "result":
                    self._account(raw_bytes_in=raw, bytes_in=wire)
                    self._route_result(handle, header, arrays, raw, wire)
                elif kind == "echo_reply":
                    with self._lock:
                        waiter = self._echo_waiters.pop(
                            header.get("seq"), None
                        )
                    if waiter is not None:
                        event, slot = waiter
                        slot.append((raw, wire))
                        event.set()
        except (ProtocolError, OSError):
            self._on_death(handle)

    def _monitor_loop(self) -> None:
        while not self._closed:
            time.sleep(min(self.heartbeat_timeout / 4.0, 0.5))
            deadline = time.time() - self.heartbeat_timeout
            with self._lock:
                stale = [
                    h for h in self._workers.values()
                    if h.alive and h.last_seen < deadline
                ]
            for h in stale:
                # shutdown() (not close()) is what actually wakes a reader
                # thread blocked in recv with EOF, tripping its death path
                _shutdown_socket(h.sock)

    def _on_death(self, handle: _WorkerHandle) -> None:
        with self._lock:
            if not handle.alive:
                return
            handle.alive = False
            self._workers.pop(handle.wid, None)
            requests = list(self._requests.values())
        self.membership.record_leave(handle.wid, time.time())
        _shutdown_socket(handle.sock)
        for req in requests:
            self._redispatch(req, handle.wid)

    def _route_result(
        self, handle: _WorkerHandle, header: Dict, arrays: Dict,
        raw: int = 0, wire: int = 0,
    ) -> None:
        rid = header.get("req")
        with self._lock:
            req = self._requests.get(rid)
            done_tid = self._done_traces.get(rid) if req is None else None
        if req is None:
            # request already decoded (straggler / duplicate) — but a
            # traced request still wants the late responder on its
            # timeline, tagged so the viewer can tell it lost the race
            if done_tid is not None:
                self._collect_worker_spans(
                    done_tid, handle, header, wire, late=True
                )
            return
        if req.trace is not None:
            self._collect_worker_spans(
                req.trace.trace_id, handle, header, wire, late=False
            )
        with req.lock:
            req.pending.pop(header.get("task"), None)
            req.raw_in += raw
            req.wire_in += wire
        self.membership.record_response(
            handle.wid, float(header.get("wall_us", 0.0)) / 1e3
        )
        if header.get("ok"):
            req.events.put(("result", int(header["i"]), arrays.get("h")))
        else:
            req.events.put(
                ("error", int(header["i"]), (handle.wid, header.get("err")))
            )

    def _collect_worker_spans(
        self, trace_id: str, handle: _WorkerHandle, header: Dict,
        wire: int, late: bool,
    ) -> None:
        """Land a result frame's compute span on the request's timeline.

        Tracing-capable workers piggyback their span on the reply
        (``spans`` header field); a v0 peer sends none, so the master
        synthesizes one from the ``wall_us`` it already reports, ending
        at receipt time — same schema either way, tagged so readers know
        which clock produced it.
        """
        entries = header.get("spans")
        tags = {
            "wid": handle.wid, "worker": handle.name,
            "share": header.get("i"), "wire_bytes": wire,
        }
        if late:
            tags["late"] = True
        tracer = obs.tracer()
        if entries:
            for span in obs.spans_from_wire(entries, trace_id, **tags):
                tracer.record(span)
        else:
            t1 = obs.now()
            wall_s = float(header.get("wall_us", 0.0)) / 1e6
            tracer.record(obs.Span(
                trace_id=trace_id, name="compute", component="worker",
                t_start=t1 - wall_s, t_end=t1,
                tags={**tags, "synthesized": True,
                      "ok": bool(header.get("ok"))},
            ))

    # -- introspection -----------------------------------------------------

    def live_workers(self) -> List[int]:
        with self._lock:
            return sorted(w for w, h in self._workers.items() if h.alive)

    def worker_caps(self) -> Dict[int, Dict]:
        with self._lock:
            return {w: dict(h.caps) for w, h in self._workers.items()}

    def worker_codecs(self) -> Dict[int, str]:
        """Negotiated wire codec per live worker."""
        with self._lock:
            return {w: h.codec for w, h in self._workers.items()}

    def trace(self):
        """The observed membership history as a real WorkerTrace."""
        return self.membership.trace()

    def _account(self, **deltas: int) -> None:
        with self._stats_lock:
            for k, v in deltas.items():
                self._counters[k] += v

    def stats(self) -> StatsSnapshot:
        """Cumulative master accounting in the shared ``repro.stats``
        snapshot schema (``pool_``-prefixed keys): counters,
        ``pool_bytes_in/out`` vs ``pool_raw_bytes_in/out`` (on-wire vs
        pre-codec), and ``pool_wall_ms``/``pool_time_to_R_ms`` histograms
        with p50/p99.  Legacy unprefixed keys still resolve (with one
        DeprecationWarning per key)."""
        with self._stats_lock:
            snap: Dict[str, object] = dict(self._counters)
        snap["workers_live"] = len(self.live_workers())
        snap.update(self._wall_hist.snapshot("wall_ms"))
        snap.update(self._time_to_R_hist.snapshot("time_to_R_ms"))
        return namespaced("pool", snap)

    def wait_for_workers(self, n: int, timeout: float = 60.0) -> None:
        deadline = time.time() + timeout
        with self._joined:
            while len(self._workers) < n:
                remaining = deadline - time.time()
                if remaining <= 0 or not self._joined.wait(remaining):
                    raise TimeoutError(
                        f"pool has {len(self._workers)}/{n} workers after "
                        f"{timeout:.0f}s"
                    )

    # -- calibration probe -------------------------------------------------

    def echo(
        self, nbytes: int, wid: Optional[int] = None,
        timeout: float = 30.0, codec: Optional[str] = None,
    ) -> Dict[str, float]:
        """Time one real round-trip of an ``nbytes`` share-shaped payload
        to a worker and back (the calibration probe behind the pool
        backend's measured comm coefficients).  Returns seconds and byte
        counts: ``{"rtt_s", "raw_bytes", "wire_bytes"}``."""
        with self._lock:
            handle = (
                self._workers.get(wid) if wid is not None
                else next(iter(sorted(self._workers.items())), (None, None))[1]
            )
        if handle is None or not handle.alive:
            raise WorkerDied("no live worker for echo probe")
        payload = np.arange(max(1, nbytes // 4), dtype=np.uint32)
        with self._lock:
            seq = self._next_echo
            self._next_echo += 1
            event, slot = threading.Event(), []
            self._echo_waiters[seq] = (event, slot)
        t0 = time.perf_counter()
        use = handle.codec if codec is None else codec
        raw, wire = handle.send(
            {"type": "echo", "seq": seq, "codec": use},
            {"x": payload}, codec=use,
        )
        if not event.wait(timeout):
            with self._lock:
                self._echo_waiters.pop(seq, None)
            raise TimeoutError(f"echo probe {seq} got no reply in {timeout}s")
        rtt = time.perf_counter() - t0
        raw_back, wire_back = slot[0]
        return {
            "rtt_s": rtt,
            "raw_bytes": float(raw + raw_back),
            "wire_bytes": float(wire + wire_back),
        }

    # -- dispatch ----------------------------------------------------------

    def _pick_worker(self, exclude: Tuple[int, ...] = ()) -> _WorkerHandle:
        with self._lock:
            live = [
                h for w, h in sorted(self._workers.items())
                if h.alive and w not in exclude
            ]
            if not live:
                live = [h for _, h in sorted(self._workers.items()) if h.alive]
            if not live:
                raise WorkerDied("pool has no live workers")
            self._rr += 1
            return live[self._rr % len(live)]

    def _stream_chunks(self, fa: np.ndarray, gb: np.ndarray) -> int:
        """How many chunks to pipeline this share in (1 = single message).
        Only 3-D planar block shares with a shared contraction axis are
        chunkable: ``fa (t,r,D) @ gb (r,s,D)`` splits along r exactly."""
        if self.stream_chunk_bytes <= 0:
            return 1
        if (
            getattr(fa, "ndim", 0) != 3 or getattr(gb, "ndim", 0) != 3
            or fa.shape[1] != gb.shape[0]
        ):
            return 1
        r = int(fa.shape[1])
        total = int(fa.nbytes) + int(gb.nbytes)
        if total <= self.stream_chunk_bytes:
            return 1
        return max(1, min(r, math.ceil(total / self.stream_chunk_bytes)))

    def _send_task(
        self,
        req: _Request,
        scheme,
        i: int,
        fa: np.ndarray,
        gb: np.ndarray,
        exclude: Tuple[int, ...] = (),
        redispatch: bool = False,
    ) -> int:
        tried = set(exclude)
        while True:
            handle = self._pick_worker(tuple(tried))
            with self._lock:
                task = self._next_task
                self._next_task += 1
            header = {
                "type": "task",
                "req": req.rid,
                "task": task,
                "i": i,
                "codec": handle.codec,
                "ring": {
                    "p": scheme.ring.p,
                    "e": scheme.ring.e,
                    "degrees": list(scheme.ring.degrees),
                },
            }
            # trace_id rides the task header only when this worker's hello
            # advertised tracing — a v0 peer never sees the field and the
            # master synthesizes its compute span from wall_us instead
            if req.trace is not None and handle.caps.get("tracing"):
                header["trace"] = req.trace.trace_id
            # None = auto: each worker decides per its own device/ring
            # (kernel_auto_enabled on the worker side)
            header["use_kernel"] = (
                "auto" if self.use_kernel is None else bool(self.use_kernel)
            )
            delay = self.task_delay_ms.get(handle.wid, 0.0)
            if delay > 0.0:
                header["delay_ms"] = delay
            if handle.wid in self.task_fail_wids:
                header["inject_fail"] = True
            with req.lock:
                req.pending[task] = (i, fa, gb, handle.wid)
            try:
                t_send = obs.now()
                chunks = self._stream_chunks(fa, gb)
                if chunks <= 1:
                    raw, wire = handle.send(header, {"fa": fa, "gb": gb})
                else:
                    # pipelined transfer: ship the share as contraction-
                    # axis slices so the worker computes partial products
                    # while later chunks are still in flight.  The header
                    # must promise exactly the number of chunk messages
                    # that follow (ceil(r/step) can undershoot the chunk
                    # target when step rounds up), or the worker's
                    # accumulator waits forever on a phantom chunk.
                    r = fa.shape[1]
                    step = math.ceil(r / chunks)
                    starts = range(0, r, step)
                    header["stream"] = len(starts)
                    raw, wire = handle.send(header)
                    for seq, lo in enumerate(starts):
                        hi = min(lo + step, r)
                        craw, cwire = handle.send(
                            {
                                "type": "chunk", "req": req.rid,
                                "task": task, "seq": seq,
                            },
                            {
                                "fa": np.ascontiguousarray(fa[:, lo:hi, :]),
                                "gb": np.ascontiguousarray(gb[lo:hi, :, :]),
                            },
                        )
                        raw += craw
                        wire += cwire
                with req.lock:
                    req.raw_out += raw
                    req.wire_out += wire
                    req.codecs.add(handle.codec)
                self._account(raw_bytes_out=raw, bytes_out=wire)
                # the send span IS the dead worker's footprint when it
                # never answers: timeline evidence the share went there
                obs.tracer().add(
                    req.trace, "send", "pool", t_send, obs.now(),
                    wid=handle.wid, share=i, task=task,
                    raw_bytes=raw, wire_bytes=wire, chunks=chunks,
                    codec=handle.codec, redispatch=redispatch,
                )
                return handle.wid
            except OSError:
                # the send found the corpse; retry on another worker (the
                # death path would skip this task if _on_death already ran)
                with req.lock:
                    req.pending.pop(task, None)
                tried.add(handle.wid)
                self._on_death(handle)

    def _redispatch(self, req: _Request, dead_wid: int) -> None:
        """Re-ship the dead worker's unanswered shares to survivors."""
        with req.lock:
            if req.done:
                return
            orphans = [
                (task, i, fa, gb)
                for task, (i, fa, gb, wid) in req.pending.items()
                if wid == dead_wid
            ]
            for task, *_ in orphans:
                req.pending.pop(task, None)
        for _, i, fa, gb in orphans:
            try:
                self._send_task(req, req.scheme, i, fa, gb,
                                exclude=(dead_wid,), redispatch=True)
                with req.lock:
                    req.redispatched += 1
                self._account(redispatched=1)
            except WorkerDied as e:
                req.events.put(("dead", -1, str(e)))
                return

    # -- protocol entry point ----------------------------------------------

    def execute(
        self,
        scheme,
        A,
        B,
        mask=None,
        key=None,
        timeout: Optional[float] = None,
        batch_fill: Optional[int] = None,
        trace: Optional[obs.TraceContext] = None,
    ) -> Tuple[np.ndarray, PoolStats]:
        """Run one coded matmul on the pool; returns (C, PoolStats).

        ``mask`` is the usual (N,)-bool share-liveness vector: masked-out
        share indices are never dispatched (the test seam for simulating
        straggler budgets deterministically).  ``key`` feeds the keyed
        encode of secure schemes — encode runs master-side, so workers
        only ever see masked shares.  ``batch_fill`` is observability from
        a coalescing caller: how many of the scheme's batch slots carry
        real requests (the rest are padding), surfaced on PoolStats.
        ``trace`` carries an upstream :class:`repro.obs.TraceContext`
        (scheduler/serving); when tracing is enabled and none is passed, a
        fresh one is opened so direct ``Master.execute`` calls trace too.
        """
        t0 = time.perf_counter()
        if trace is None:
            trace = obs.maybe_context("pool")
        tracer = obs.tracer()
        N, R = scheme.N, scheme.R
        shares = list(range(N))
        if mask is not None:
            m = np.asarray(mask, dtype=bool)
            if len(m) != N:
                raise ValueError(f"mask has {len(m)} entries, scheme N={N}")
            shares = [i for i in shares if m[i]]
        if len(shares) < R:
            raise NotEnoughResponders(
                f"{scheme.name}: mask leaves {len(shares)} shares, "
                f"decode needs R={R}"
            )
        encode_at, _ = worker_closures(scheme, keyed=key is not None)

        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = _Request(rid, R, trace=trace)
            req.scheme = scheme
            self._requests[rid] = req
        self._account(requests=1)
        deadline = time.perf_counter() + timeout if timeout else None
        workers_used: List[int] = []
        ok = False
        try:
            import jax.numpy as jnp

            for i in shares:
                t_enc = obs.now()
                if key is None:
                    fa, gb = encode_at(A, B, jnp.int32(i))
                else:
                    fa, gb = encode_at(A, B, jnp.int32(i), key)
                fa, gb = np.asarray(fa), np.asarray(gb)
                tracer.add(trace, "encode", "pool", t_enc, obs.now(),
                           share=i, scheme=scheme.name)
                wid = self._send_task(req, scheme, i, fa, gb)
                workers_used.append(wid)
            t_wait = obs.now()

            got: Dict[int, np.ndarray] = {}
            errors: Dict[int, int] = {}  # share -> failed compute attempts
            t_R = None
            while len(got) < R:
                wait = None
                if deadline is not None:
                    wait = deadline - time.perf_counter()
                    if wait <= 0:
                        raise TimeoutError(
                            f"pool request {rid}: {len(got)}/{R} responses "
                            f"after {timeout}s"
                        )
                try:
                    kind, i, payload = req.events.get(timeout=wait)
                except queue.Empty:
                    raise TimeoutError(
                        f"pool request {rid}: {len(got)}/{R} responses "
                        f"after {timeout}s"
                    ) from None
                if kind == "result":
                    got[i] = payload
                elif kind == "error":
                    # a compute error is a worker failure, not a request
                    # failure: retry the share ONCE on a different worker,
                    # then write it off — the any-R decode only needs R of
                    # the remaining shares
                    bad_wid, err = payload
                    errors[i] = errors.get(i, 0) + 1
                    healthy = [
                        s for s in shares
                        if s in got or errors.get(s, 0) < 2
                    ]
                    if len(healthy) < R:
                        raise RuntimeError(
                            f"pool request {rid}: share {i} failed "
                            f"{errors[i]}x and only {len(healthy)} viable "
                            f"shares remain (R={R}); last error: {err}"
                        )
                    if errors[i] < 2 and i not in got:
                        t_enc = obs.now()
                        if key is None:
                            fa, gb = encode_at(A, B, jnp.int32(i))
                        else:
                            fa, gb = encode_at(A, B, jnp.int32(i), key)
                        fa, gb = np.asarray(fa), np.asarray(gb)
                        tracer.add(trace, "encode", "pool", t_enc,
                                   obs.now(), share=i, retry=True)
                        self._send_task(
                            req, scheme, i, fa, gb,
                            exclude=(bad_wid,), redispatch=True,
                        )
                else:  # "dead": no live workers remain for a re-dispatch
                    raise WorkerDied(
                        f"pool request {rid}: {payload} with {len(got)}/{R} "
                        f"responses collected"
                    )
            t_R = (time.perf_counter() - t0) * 1e3
            with req.lock:
                req.done = True
            # the any-R race: dispatch done -> R-th response landed
            tracer.add(trace, "wait_R", "pool", t_wait, obs.now(),
                       R=R, responders=sorted(got),
                       redispatched=req.redispatched)
            t_dec = obs.now()
            C = decode_responses(scheme, got)
            tracer.add(trace, "decode", "pool", t_dec, obs.now(),
                       live_idx=sorted(got)[:R], scheme=scheme.name)
            wall_ms = (time.perf_counter() - t0) * 1e3
            stats = PoolStats(
                dispatched=tuple(shares),
                live_idx=tuple(sorted(got))[:R],
                workers=tuple(sorted(set(workers_used))),
                redispatched=req.redispatched,
                wall_ms=wall_ms,
                time_to_R_ms=t_R,
                batch=int(getattr(scheme, "batch", 1)),
                fill=(int(batch_fill) if batch_fill is not None
                      else int(getattr(scheme, "batch", 1))),
                raw_bytes_out=req.raw_out,
                bytes_out=req.wire_out,
                raw_bytes_in=req.raw_in,
                bytes_in=req.wire_in,
                codecs=tuple(sorted(req.codecs)),
            )
            ok = True
            self._account(completed=1)
            self._wall_hist.observe(wall_ms)
            self._time_to_R_hist.observe(t_R)
            return C, stats
        finally:
            if not ok:
                self._account(failed=1)
            with self._lock:
                self._requests.pop(rid, None)
                if trace is not None:
                    # keep routing late responders' spans to this timeline
                    self._done_traces[rid] = trace.trace_id
                    while len(self._done_traces) > self._done_traces_cap:
                        self._done_traces.pop(next(iter(self._done_traces)))

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._lock:
            handles = list(self._workers.values())
            self._workers.clear()
        for h in handles:
            try:
                h.send({"type": "shutdown"})
            except OSError:
                pass
            _shutdown_socket(h.sock)
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self) -> "Master":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# local pools: master + N worker OS processes in one call
# --------------------------------------------------------------------------


def _worker_env() -> Dict[str, str]:
    """Child env: inherit, but make sure the repro package resolves."""
    import repro

    env = dict(os.environ)
    # repro may be a namespace package (no __init__.py): __path__ still
    # points at the package directory; its parent is the import root
    pkg_dir = (repro.__file__ and os.path.dirname(repro.__file__)) or list(
        repro.__path__
    )[0]
    src = os.path.dirname(os.path.abspath(pkg_dir))
    parts = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join(dict.fromkeys(parts))
    return env


_LEGACY_POOL_ARGS = (
    "workers", "address", "heartbeat_s", "heartbeat_timeout", "use_kernel",
    "spawn_timeout",
)


class LocalPool:
    """A master plus N local worker OS processes (the zero-config pool).

    The single-host specialization of the launcher
    (:func:`repro.dist.launch.launch_pool`): prefers a Unix-domain socket
    under a private tempdir, falls back to loopback TCP.  ``kill(k)``
    SIGKILLs k workers (failure injection); ``close()`` shuts the master
    down and reaps every child.

    Preferred construction is ``LocalPool(config=PoolConfig(...))``;
    keyword arguments (``workers=``, ``address=``, ...) remain supported
    and override the config.  Positional arguments are deprecated (one
    ``DeprecationWarning`` per process) but keep working.
    """

    def __init__(self, *args, config: Optional[PoolConfig] = None, **kwargs):
        if args:
            warn_deprecated_once(
                "LocalPool-positional",
                "positional LocalPool arguments are deprecated; pass "
                "LocalPool(config=PoolConfig(workers=...)) or keyword "
                "arguments",
            )
            for name, val in zip(_LEGACY_POOL_ARGS, args):
                if name in kwargs:
                    raise TypeError(
                        f"LocalPool got multiple values for {name!r}"
                    )
                kwargs[name] = val
        unknown = set(kwargs) - set(_LEGACY_POOL_ARGS)
        if unknown:
            raise TypeError(f"LocalPool got unexpected {sorted(unknown)}")
        cfg = config or PoolConfig()
        if "address" in kwargs and kwargs["address"] is not None:
            cfg = cfg.with_(endpoint=Endpoint.parse(kwargs["address"]))
        for name in ("workers", "heartbeat_s", "heartbeat_timeout",
                     "use_kernel", "spawn_timeout"):
            if name in kwargs:
                cfg = cfg.with_(**{name: kwargs[name]})
        self.config = cfg
        self._tmpdir = None
        if cfg.endpoint is None:
            if hasattr(socket, "AF_UNIX"):
                self._tmpdir = tempfile.mkdtemp(prefix="repro-pool-")
                cfg = cfg.with_(endpoint=Endpoint.unix(
                    os.path.join(self._tmpdir, "pool.sock")
                ))
            else:  # pragma: no cover - non-POSIX fallback
                cfg = cfg.with_(endpoint=Endpoint.tcp("127.0.0.1", 0))
        self.master = Master(config=cfg)
        # the launcher owns process spawning; LocalPool is its local case
        from .launch import spawn_local_workers

        self.procs: List[subprocess.Popen] = spawn_local_workers(
            self.master.address, cfg.workers,
            heartbeat_s=cfg.heartbeat_s, name_prefix="local",
        )
        try:
            self.master.wait_for_workers(
                cfg.workers, timeout=cfg.spawn_timeout
            )
        except TimeoutError:
            self.close()
            raise

    @property
    def address(self) -> str:
        return self.master.address

    def execute(self, scheme, A, B, mask=None, key=None, timeout=None,
                batch_fill=None, trace=None):
        return self.master.execute(scheme, A, B, mask=mask, key=key,
                                   timeout=timeout, batch_fill=batch_fill,
                                   trace=trace)

    def stats(self) -> Dict[str, object]:
        """Cumulative pool accounting (shared repro.stats schema)."""
        return self.master.stats()

    def kill(self, k: int = 1, sig: int = signal.SIGKILL) -> List[int]:
        """SIGKILL ``k`` live worker processes; returns the killed pids."""
        killed = []
        for proc in self.procs:
            if len(killed) >= k:
                break
            if proc.poll() is None:
                os.kill(proc.pid, sig)
                killed.append(proc.pid)
        for pid in killed:  # reap promptly so poll() reflects reality
            for proc in self.procs:
                if proc.pid == pid:
                    proc.wait(timeout=30)
        return killed

    def alive_count(self) -> int:
        return sum(1 for p in self.procs if p.poll() is None)

    def close(self) -> None:
        self.master.close()
        for proc in self.procs:
            if proc.poll() is None:
                proc.terminate()
        for proc in self.procs:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover
                proc.kill()
                proc.wait(timeout=10)
        if self._tmpdir:
            try:
                sock = os.path.join(self._tmpdir, "pool.sock")
                if os.path.exists(sock):
                    os.unlink(sock)
                os.rmdir(self._tmpdir)
            except OSError:  # pragma: no cover
                pass

    def __enter__(self) -> "LocalPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
