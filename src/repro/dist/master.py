"""Pool master: real workers, heartbeats, death detection, any-R decode.

:class:`Master` listens on a socket, accepts worker registrations (the
``hello`` capability handshake), and executes coded matmuls against the
pool: the master encodes per-worker shares with the same jitted
``encode_*_at`` closures the elastic backend uses, ships each share to a
live worker process, and fires the LRU-cached any-R ``decode_op`` the
moment the R-th response lands — through
:func:`repro.cdmm.elastic.decode_responses`, the exact decode tail of the
in-process elastic master, so the two paths are bit-identical by
construction.

Failure model.  A worker is dead when its socket drops (SIGKILL, crash,
network) or its heartbeat goes silent past ``heartbeat_timeout``.  Death
mid-request re-dispatches the worker's unanswered shares to surviving
workers (any process can compute any share — the share index, not the
process, is the paper's "worker"), so a request completes as long as one
process survives and R distinct shares can still be computed.  Membership
is tracked by :class:`repro.core.straggler.MembershipEvents`, so the
observed join/leave/response history is available as a real
:class:`~repro.core.straggler.WorkerTrace` (``Master.trace()``) and plugs
into everything built on trace semantics.

Shares are multiplexed: a pool of W processes serves schemes with any N
(round-robin assignment), decoupling pool size from the code's worker
count.  Requests are multiplexed too — every task carries a request id and
responses are routed to per-request queues — which is what lets the
serving scheduler (:mod:`repro.dist.scheduler`) keep several requests in
flight over one pool.

:class:`LocalPool` spawns a master plus N ``python -m repro.dist.worker``
OS processes on a Unix-domain socket (TCP fallback) in one call, with
``kill()`` for failure injection and clean shutdown on ``close()``.
"""
from __future__ import annotations

import os
import queue
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cdmm.elastic import NotEnoughResponders, decode_responses, worker_closures
from repro.core.straggler import MembershipEvents

from .protocol import ProtocolError, listen, recv_msg, send_msg

__all__ = ["LocalPool", "Master", "PoolStats", "WorkerDied"]


def _shutdown_socket(sock: socket.socket) -> None:
    """Force-wake any thread blocked reading ``sock``, then close it.
    ``close()`` alone leaves a blocked ``recv`` sleeping forever;
    ``shutdown(SHUT_RDWR)`` delivers EOF first."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


class WorkerDied(RuntimeError):
    """A request became impossible: too few live workers remain to compute
    R distinct shares (every surviving share was already re-dispatched)."""


@dataclass(frozen=True)
class PoolStats:
    """Accounting of one pool execution (real wall-clock, real processes)."""

    dispatched: Tuple[int, ...]  # share indices shipped to workers
    live_idx: Tuple[int, ...]  # the R-subset actually decoded from
    workers: Tuple[int, ...]  # pool worker ids that served shares
    redispatched: int  # shares re-shipped after a worker death
    wall_ms: float  # master wall-clock for the call
    time_to_R_ms: float  # wall-clock until the R-th response landed
    batch: int = 1  # products the scheme packs per codeword (RMFE slots)
    fill: int = 1  # slots carrying real requests (rest were zero padding)


class _WorkerHandle:
    def __init__(self, wid: int, sock: socket.socket, caps: Dict):
        self.wid = wid
        self.sock = sock
        self.caps = caps
        self.name = caps.get("name", f"worker-{wid}")
        self.alive = True
        self.last_seen = time.time()
        self.send_lock = threading.Lock()

    def send(self, header: Dict, arrays=None) -> None:
        with self.send_lock:
            send_msg(self.sock, header, arrays)


class _Request:
    """Routing state of one in-flight coded matmul."""

    def __init__(self, rid: int, R: int):
        self.rid = rid
        self.R = R
        self.events: "queue.Queue" = queue.Queue()
        self.lock = threading.Lock()
        # task_id -> (share index, fa, gb, wid currently assigned)
        self.pending: Dict[int, Tuple[int, np.ndarray, np.ndarray, int]] = {}
        self.redispatched = 0
        self.done = False


class Master:
    """Accept workers, track membership, execute coded matmuls on the pool."""

    def __init__(
        self,
        address: str = "tcp:127.0.0.1:0",
        heartbeat_timeout: float = 5.0,
        use_kernel: Optional[bool] = None,
    ):
        self._listener, self.address = listen(address)
        self.heartbeat_timeout = heartbeat_timeout
        # None = let each worker auto-select (kernel wherever it compiles on
        # the worker's device); True/False force it pool-wide
        self.use_kernel = use_kernel
        self.membership = MembershipEvents()
        self._workers: Dict[int, _WorkerHandle] = {}
        self._requests: Dict[int, _Request] = {}
        self._lock = threading.Lock()
        self._joined = threading.Condition(self._lock)
        self._next_wid = 0
        self._next_rid = 0
        self._next_task = 0
        self._rr = 0  # round-robin cursor for share -> worker assignment
        self._closed = False
        # failure injection: per-worker-id compute delay stamped into task
        # headers (tests/CI park a victim's compute so SIGKILL lands mid-task)
        self.task_delay_ms: Dict[int, float] = {}
        # error injection: these workers raise instead of computing, which
        # exercises the bounded share-retry path without corrupting state
        self.task_fail_wids: set = set()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="pool-accept", daemon=True
        )
        self._accept_thread.start()
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, name="pool-monitor", daemon=True
        )
        self._monitor_thread.start()

    # -- membership --------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            threading.Thread(
                target=self._register, args=(sock,), daemon=True
            ).start()

    def _register(self, sock: socket.socket) -> None:
        try:
            caps, _ = recv_msg(sock)
        except (ProtocolError, OSError):
            sock.close()
            return
        if caps.get("type") != "hello":
            sock.close()
            return
        with self._lock:
            wid = self._next_wid
            self._next_wid += 1
            handle = _WorkerHandle(wid, sock, caps)
            self._workers[wid] = handle
            self._joined.notify_all()
        self.membership.record_join(wid, time.time())
        threading.Thread(
            target=self._reader_loop, args=(handle,),
            name=f"pool-reader-{wid}", daemon=True,
        ).start()

    def _reader_loop(self, handle: _WorkerHandle) -> None:
        try:
            while True:
                header, arrays = recv_msg(handle.sock)
                handle.last_seen = time.time()
                if header.get("type") == "result":
                    self._route_result(handle, header, arrays)
        except (ProtocolError, OSError):
            self._on_death(handle)

    def _monitor_loop(self) -> None:
        while not self._closed:
            time.sleep(min(self.heartbeat_timeout / 4.0, 0.5))
            deadline = time.time() - self.heartbeat_timeout
            with self._lock:
                stale = [
                    h for h in self._workers.values()
                    if h.alive and h.last_seen < deadline
                ]
            for h in stale:
                # shutdown() (not close()) is what actually wakes a reader
                # thread blocked in recv with EOF, tripping its death path
                _shutdown_socket(h.sock)

    def _on_death(self, handle: _WorkerHandle) -> None:
        with self._lock:
            if not handle.alive:
                return
            handle.alive = False
            self._workers.pop(handle.wid, None)
            requests = list(self._requests.values())
        self.membership.record_leave(handle.wid, time.time())
        _shutdown_socket(handle.sock)
        for req in requests:
            self._redispatch(req, handle.wid)

    def _route_result(
        self, handle: _WorkerHandle, header: Dict, arrays: Dict
    ) -> None:
        rid = header.get("req")
        with self._lock:
            req = self._requests.get(rid)
        if req is None:
            return  # request already decoded (straggler / duplicate)
        with req.lock:
            req.pending.pop(header.get("task"), None)
        self.membership.record_response(
            handle.wid, float(header.get("wall_us", 0.0)) / 1e3
        )
        if header.get("ok"):
            req.events.put(("result", int(header["i"]), arrays.get("h")))
        else:
            req.events.put(
                ("error", int(header["i"]), (handle.wid, header.get("err")))
            )

    # -- introspection -----------------------------------------------------

    def live_workers(self) -> List[int]:
        with self._lock:
            return sorted(w for w, h in self._workers.items() if h.alive)

    def worker_caps(self) -> Dict[int, Dict]:
        with self._lock:
            return {w: dict(h.caps) for w, h in self._workers.items()}

    def trace(self):
        """The observed membership history as a real WorkerTrace."""
        return self.membership.trace()

    def wait_for_workers(self, n: int, timeout: float = 60.0) -> None:
        deadline = time.time() + timeout
        with self._joined:
            while len(self._workers) < n:
                remaining = deadline - time.time()
                if remaining <= 0 or not self._joined.wait(remaining):
                    raise TimeoutError(
                        f"pool has {len(self._workers)}/{n} workers after "
                        f"{timeout:.0f}s"
                    )

    # -- dispatch ----------------------------------------------------------

    def _pick_worker(self, exclude: Tuple[int, ...] = ()) -> _WorkerHandle:
        with self._lock:
            live = [
                h for w, h in sorted(self._workers.items())
                if h.alive and w not in exclude
            ]
            if not live:
                live = [h for _, h in sorted(self._workers.items()) if h.alive]
            if not live:
                raise WorkerDied("pool has no live workers")
            self._rr += 1
            return live[self._rr % len(live)]

    def _send_task(
        self,
        req: _Request,
        scheme,
        i: int,
        fa: np.ndarray,
        gb: np.ndarray,
        exclude: Tuple[int, ...] = (),
    ) -> int:
        tried = set(exclude)
        while True:
            handle = self._pick_worker(tuple(tried))
            with self._lock:
                task = self._next_task
                self._next_task += 1
            header = {
                "type": "task",
                "req": req.rid,
                "task": task,
                "i": i,
                "ring": {
                    "p": scheme.ring.p,
                    "e": scheme.ring.e,
                    "degrees": list(scheme.ring.degrees),
                },
            }
            # None = auto: each worker decides per its own device/ring
            # (kernel_auto_enabled on the worker side)
            header["use_kernel"] = (
                "auto" if self.use_kernel is None else bool(self.use_kernel)
            )
            delay = self.task_delay_ms.get(handle.wid, 0.0)
            if delay > 0.0:
                header["delay_ms"] = delay
            if handle.wid in self.task_fail_wids:
                header["inject_fail"] = True
            with req.lock:
                req.pending[task] = (i, fa, gb, handle.wid)
            try:
                handle.send(header, {"fa": fa, "gb": gb})
                return handle.wid
            except OSError:
                # the send found the corpse; retry on another worker (the
                # death path would skip this task if _on_death already ran)
                with req.lock:
                    req.pending.pop(task, None)
                tried.add(handle.wid)
                self._on_death(handle)

    def _redispatch(self, req: _Request, dead_wid: int) -> None:
        """Re-ship the dead worker's unanswered shares to survivors."""
        with req.lock:
            if req.done:
                return
            orphans = [
                (task, i, fa, gb)
                for task, (i, fa, gb, wid) in req.pending.items()
                if wid == dead_wid
            ]
            for task, *_ in orphans:
                req.pending.pop(task, None)
        for _, i, fa, gb in orphans:
            try:
                self._send_task(req, req.scheme, i, fa, gb,
                                exclude=(dead_wid,))
                with req.lock:
                    req.redispatched += 1
            except WorkerDied as e:
                req.events.put(("dead", -1, str(e)))
                return

    # -- protocol entry point ----------------------------------------------

    def execute(
        self,
        scheme,
        A,
        B,
        mask=None,
        key=None,
        timeout: Optional[float] = None,
        batch_fill: Optional[int] = None,
    ) -> Tuple[np.ndarray, PoolStats]:
        """Run one coded matmul on the pool; returns (C, PoolStats).

        ``mask`` is the usual (N,)-bool share-liveness vector: masked-out
        share indices are never dispatched (the test seam for simulating
        straggler budgets deterministically).  ``key`` feeds the keyed
        encode of secure schemes — encode runs master-side, so workers
        only ever see masked shares.  ``batch_fill`` is observability from
        a coalescing caller: how many of the scheme's batch slots carry
        real requests (the rest are padding), surfaced on PoolStats.
        """
        t0 = time.perf_counter()
        N, R = scheme.N, scheme.R
        shares = list(range(N))
        if mask is not None:
            m = np.asarray(mask, dtype=bool)
            if len(m) != N:
                raise ValueError(f"mask has {len(m)} entries, scheme N={N}")
            shares = [i for i in shares if m[i]]
        if len(shares) < R:
            raise NotEnoughResponders(
                f"{scheme.name}: mask leaves {len(shares)} shares, "
                f"decode needs R={R}"
            )
        encode_at, _ = worker_closures(scheme, keyed=key is not None)

        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = _Request(rid, R)
            req.scheme = scheme
            self._requests[rid] = req
        deadline = time.perf_counter() + timeout if timeout else None
        workers_used: List[int] = []
        try:
            import jax.numpy as jnp

            for i in shares:
                if key is None:
                    fa, gb = encode_at(A, B, jnp.int32(i))
                else:
                    fa, gb = encode_at(A, B, jnp.int32(i), key)
                wid = self._send_task(
                    req, scheme, i, np.asarray(fa), np.asarray(gb)
                )
                workers_used.append(wid)

            got: Dict[int, np.ndarray] = {}
            errors: Dict[int, int] = {}  # share -> failed compute attempts
            t_R = None
            while len(got) < R:
                wait = None
                if deadline is not None:
                    wait = deadline - time.perf_counter()
                    if wait <= 0:
                        raise TimeoutError(
                            f"pool request {rid}: {len(got)}/{R} responses "
                            f"after {timeout}s"
                        )
                try:
                    kind, i, payload = req.events.get(timeout=wait)
                except queue.Empty:
                    raise TimeoutError(
                        f"pool request {rid}: {len(got)}/{R} responses "
                        f"after {timeout}s"
                    ) from None
                if kind == "result":
                    got[i] = payload
                elif kind == "error":
                    # a compute error is a worker failure, not a request
                    # failure: retry the share ONCE on a different worker,
                    # then write it off — the any-R decode only needs R of
                    # the remaining shares
                    bad_wid, err = payload
                    errors[i] = errors.get(i, 0) + 1
                    healthy = [
                        s for s in shares
                        if s in got or errors.get(s, 0) < 2
                    ]
                    if len(healthy) < R:
                        raise RuntimeError(
                            f"pool request {rid}: share {i} failed "
                            f"{errors[i]}x and only {len(healthy)} viable "
                            f"shares remain (R={R}); last error: {err}"
                        )
                    if errors[i] < 2 and i not in got:
                        if key is None:
                            fa, gb = encode_at(A, B, jnp.int32(i))
                        else:
                            fa, gb = encode_at(A, B, jnp.int32(i), key)
                        self._send_task(
                            req, scheme, i, np.asarray(fa), np.asarray(gb),
                            exclude=(bad_wid,),
                        )
                else:  # "dead": no live workers remain for a re-dispatch
                    raise WorkerDied(
                        f"pool request {rid}: {payload} with {len(got)}/{R} "
                        f"responses collected"
                    )
            t_R = (time.perf_counter() - t0) * 1e3
            with req.lock:
                req.done = True
            C = decode_responses(scheme, got)
            stats = PoolStats(
                dispatched=tuple(shares),
                live_idx=tuple(sorted(got))[:R],
                workers=tuple(sorted(set(workers_used))),
                redispatched=req.redispatched,
                wall_ms=(time.perf_counter() - t0) * 1e3,
                time_to_R_ms=t_R,
                batch=int(getattr(scheme, "batch", 1)),
                fill=(int(batch_fill) if batch_fill is not None
                      else int(getattr(scheme, "batch", 1))),
            )
            return C, stats
        finally:
            with self._lock:
                self._requests.pop(rid, None)

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._lock:
            handles = list(self._workers.values())
            self._workers.clear()
        for h in handles:
            try:
                h.send({"type": "shutdown"})
            except OSError:
                pass
            _shutdown_socket(h.sock)
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self) -> "Master":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# local pools: master + N worker OS processes in one call
# --------------------------------------------------------------------------


def _worker_env() -> Dict[str, str]:
    """Child env: inherit, but make sure the repro package resolves."""
    import repro

    env = dict(os.environ)
    # repro may be a namespace package (no __init__.py): __path__ still
    # points at the package directory; its parent is the import root
    pkg_dir = (repro.__file__ and os.path.dirname(repro.__file__)) or list(
        repro.__path__
    )[0]
    src = os.path.dirname(os.path.abspath(pkg_dir))
    parts = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join(dict.fromkeys(parts))
    return env


class LocalPool:
    """A master plus N local worker OS processes (the zero-config pool).

    Prefers a Unix-domain socket under a private tempdir; falls back to
    loopback TCP.  ``kill(k)`` SIGKILLs k workers (failure injection);
    ``close()`` shuts the master down and reaps every child.
    """

    def __init__(
        self,
        workers: int = 4,
        address: Optional[str] = None,
        heartbeat_s: float = 0.5,
        heartbeat_timeout: float = 5.0,
        use_kernel: Optional[bool] = None,
        spawn_timeout: float = 120.0,
    ):
        self._tmpdir = None
        if address is None:
            if hasattr(socket, "AF_UNIX"):
                self._tmpdir = tempfile.mkdtemp(prefix="repro-pool-")
                address = f"unix:{os.path.join(self._tmpdir, 'pool.sock')}"
            else:  # pragma: no cover - non-POSIX fallback
                address = "tcp:127.0.0.1:0"
        self.master = Master(
            address, heartbeat_timeout=heartbeat_timeout, use_kernel=use_kernel
        )
        env = _worker_env()
        # REPRO_POOL_LOG=1 lets worker stderr through for debugging
        sink = None if os.environ.get("REPRO_POOL_LOG") else subprocess.DEVNULL
        self.procs: List[subprocess.Popen] = []
        for i in range(workers):
            self.procs.append(subprocess.Popen(
                [
                    sys.executable, "-m", "repro.dist.worker",
                    "--connect", self.master.address,
                    "--name", f"local-{i}",
                    "--heartbeat", str(heartbeat_s),
                ],
                env=env,
                stdout=subprocess.DEVNULL,
                stderr=sink,
            ))
        try:
            self.master.wait_for_workers(workers, timeout=spawn_timeout)
        except TimeoutError:
            self.close()
            raise

    @property
    def address(self) -> str:
        return self.master.address

    def execute(self, scheme, A, B, mask=None, key=None, timeout=None,
                batch_fill=None):
        return self.master.execute(scheme, A, B, mask=mask, key=key,
                                   timeout=timeout, batch_fill=batch_fill)

    def kill(self, k: int = 1, sig: int = signal.SIGKILL) -> List[int]:
        """SIGKILL ``k`` live worker processes; returns the killed pids."""
        killed = []
        for proc in self.procs:
            if len(killed) >= k:
                break
            if proc.poll() is None:
                os.kill(proc.pid, sig)
                killed.append(proc.pid)
        for pid in killed:  # reap promptly so poll() reflects reality
            for proc in self.procs:
                if proc.pid == pid:
                    proc.wait(timeout=30)
        return killed

    def alive_count(self) -> int:
        return sum(1 for p in self.procs if p.poll() is None)

    def close(self) -> None:
        self.master.close()
        for proc in self.procs:
            if proc.poll() is None:
                proc.terminate()
        for proc in self.procs:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover
                proc.kill()
                proc.wait(timeout=10)
        if self._tmpdir:
            try:
                sock = os.path.join(self._tmpdir, "pool.sock")
                if os.path.exists(sock):
                    os.unlink(sock)
                os.rmdir(self._tmpdir)
            except OSError:  # pragma: no cover
                pass

    def __enter__(self) -> "LocalPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
