"""Pool master: real workers, heartbeats, death detection, any-R decode.

:class:`Master` listens on a socket, accepts worker registrations (the
``hello`` capability handshake, which now negotiates a wire codec per
connection — see :mod:`repro.dist.protocol`), and executes coded matmuls
against the pool: the master encodes per-worker shares with the same
jitted ``encode_*_at`` closures the elastic backend uses, ships each
share to a live worker process (chunked along the contraction axis when
``stream_chunk_bytes`` says the share is big enough to pipeline — the
worker accumulates partial products, so transfer and compute overlap),
and fires the LRU-cached any-R ``decode_op`` the moment the R-th
response lands — through :func:`repro.cdmm.elastic.decode_responses`,
the exact decode tail of the in-process elastic master, so the two paths
are bit-identical by construction.

Failure model.  A worker is dead when its socket drops (SIGKILL, crash,
network) or its heartbeat goes silent past ``heartbeat_timeout``.  Death
mid-request re-dispatches the worker's unanswered shares to surviving
workers (any process can compute any share — the share index, not the
process, is the paper's "worker"), so a request completes as long as one
process survives and R distinct shares can still be computed.  Membership
is tracked by :class:`repro.core.straggler.MembershipEvents`, so the
observed join/leave/response history is available as a real
:class:`~repro.core.straggler.WorkerTrace` (``Master.trace()``) and plugs
into everything built on trace semantics.

Shares are multiplexed: a pool of W processes serves schemes with any N
(round-robin assignment), decoupling pool size from the code's worker
count.  Requests are multiplexed too — every task carries a request id and
responses are routed to per-request queues — which is what lets the
serving scheduler (:mod:`repro.dist.scheduler`) keep several requests in
flight over one pool.

Bandwidth accounting: every connection counts pre-codec (raw) vs. on-wire
bytes; per-request totals land on :class:`PoolStats` and cumulative
totals (plus latency histograms in the shared ``repro.stats`` schema) on
``Master.stats()``.

:class:`LocalPool` spawns a master plus N ``python -m repro.dist.worker``
OS processes on a Unix-domain socket (TCP fallback) in one call, with
``kill()`` for failure injection and clean shutdown on ``close()`` — it
is the single-host specialization of :func:`repro.dist.launch.launch_pool`
and accepts the same :class:`~repro.dist.config.PoolConfig`.
"""
from __future__ import annotations

import math
import os
import queue
import signal
import socket
import subprocess
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import settings
from repro.cdmm.elastic import NotEnoughResponders, decode_responses, worker_closures
from repro.core.straggler import MembershipEvents
from repro.obs import http as obs_http
from repro.obs import trace as obs
from repro.obs.health import DISPATCH_THRESHOLD, HealthTracker
from repro.obs.metrics import MetricsRegistry
from repro.stats import StatsSnapshot

from .config import Endpoint, PoolConfig, warn_deprecated_once
from .protocol import Channel, ProtocolError, listen, negotiate

__all__ = ["LocalPool", "Master", "PoolStats", "WorkerDied"]


def _shutdown_socket(sock: socket.socket) -> None:
    """Force-wake any thread blocked reading ``sock``, then close it.
    ``close()`` alone leaves a blocked ``recv`` sleeping forever;
    ``shutdown(SHUT_RDWR)`` delivers EOF first."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


class WorkerDied(RuntimeError):
    """A request became impossible: too few live workers remain to compute
    R distinct shares (every surviving share was already re-dispatched)."""


@dataclass(frozen=True)
class PoolStats:
    """Accounting of one pool execution (real wall-clock, real processes)."""

    dispatched: Tuple[int, ...]  # share indices shipped to workers
    live_idx: Tuple[int, ...]  # the R-subset actually decoded from
    workers: Tuple[int, ...]  # pool worker ids that served shares
    redispatched: int  # shares re-shipped after a worker death
    wall_ms: float  # master wall-clock for the call
    time_to_R_ms: float  # wall-clock until the R-th response landed
    hedged: int = 0  # shares speculatively re-shipped past their deadline
    batch: int = 1  # products the scheme packs per codeword (RMFE slots)
    fill: int = 1  # slots carrying real requests (rest were zero padding)
    # bandwidth accounting (shared schema: raw = pre-codec payload bytes,
    # bytes_* = what actually crossed the socket, framing included)
    raw_bytes_out: int = 0  # share payloads before the wire codec
    bytes_out: int = 0  # what the master actually sent
    raw_bytes_in: int = 0  # result payloads before the wire codec
    bytes_in: int = 0  # what the master actually received
    codecs: Tuple[str, ...] = ()  # negotiated codecs of the workers used


class _WorkerHandle:
    def __init__(self, wid: int, chan: Channel, caps: Dict):
        self.wid = wid
        self.chan = chan
        self.sock = chan.sock
        self.caps = caps
        self.codec = chan.codec
        self.name = caps.get("name", f"worker-{wid}")
        self.alive = True
        self.last_seen = time.time()
        self.send_lock = threading.Lock()
        # worker-published load figures (heartbeat piggyback)
        self.tasks_done = 0
        self.busy_us = 0.0

    def send(self, header: Dict, arrays=None,
             codec: Optional[str] = None) -> Tuple[int, int]:
        with self.send_lock:
            return self.chan.send(header, arrays, codec=codec)


class _Request:
    """Routing state of one in-flight coded matmul."""

    def __init__(self, rid: int, R: int,
                 trace: Optional[obs.TraceContext] = None):
        self.rid = rid
        self.R = R
        self.trace = trace
        self.events: "queue.Queue" = queue.Queue()
        self.lock = threading.Lock()
        # task_id -> (share index, fa, gb, assigned wid, t_sent)
        self.pending: Dict[
            int, Tuple[int, np.ndarray, np.ndarray, int, float]
        ] = {}
        self.redispatched = 0
        self.satisfied: set = set()  # share indices already answered
        self.hedged_shares: set = set()  # shares hedged (at most once each)
        self.hedged = 0
        self.done = False
        # per-request bandwidth accounting (summed into PoolStats)
        self.raw_out = 0
        self.wire_out = 0
        self.raw_in = 0
        self.wire_in = 0
        self.codecs: set = set()


class Master:
    """Accept workers, track membership, execute coded matmuls on the pool."""

    def __init__(
        self,
        address: Optional[str] = None,
        heartbeat_timeout: Optional[float] = None,
        use_kernel: Optional[bool] = None,
        config: Optional[PoolConfig] = None,
    ):
        cfg = config or PoolConfig()
        if heartbeat_timeout is not None:
            cfg = cfg.with_(heartbeat_timeout=heartbeat_timeout)
        if use_kernel is not None:
            cfg = cfg.with_(use_kernel=use_kernel)
        if address is not None:
            cfg = cfg.with_(endpoint=Endpoint.parse(address))
        self.config = cfg
        listen_addr = (
            cfg.endpoint.address if cfg.endpoint else "tcp:127.0.0.1:0"
        )
        self._listener, self.address = listen(listen_addr)
        self.heartbeat_timeout = cfg.heartbeat_timeout
        # None = let each worker auto-select (kernel wherever it compiles on
        # the worker's device); True/False force it pool-wide
        self.use_kernel = cfg.use_kernel
        self.transport = cfg.transport
        self.compression_level = cfg.compression_level
        self.stream_chunk_bytes = cfg.stream_chunk_bytes
        self.membership = MembershipEvents()
        self._workers: Dict[int, _WorkerHandle] = {}
        self._requests: Dict[int, _Request] = {}
        self._lock = threading.Lock()
        self._joined = threading.Condition(self._lock)
        self._next_wid = 0
        self._next_rid = 0
        self._next_task = 0
        self._next_echo = 0
        self._echo_waiters: Dict[int, Tuple[threading.Event, List]] = {}
        self._rr = 0  # round-robin cursor for share -> worker assignment
        self._closed = False
        # telemetry knobs: explicit config wins, else the settings registry
        # (REPRO_HEDGE_FACTOR / REPRO_HEALTH_EWMA / REPRO_OBS_HTTP_PORT /
        # REPRO_OBS_RETENTION)
        self.hedge_factor = float(
            cfg.hedge_factor if cfg.hedge_factor is not None
            else (settings.get_float("hedge_factor") or 0.0)
        )
        health_ewma = float(
            cfg.health_ewma if cfg.health_ewma is not None
            else (settings.get_float("health_ewma") or 0.2)
        )
        retention_s = float(settings.get_float("obs_retention") or 300.0)
        # cumulative accounting: a live MetricsRegistry the dispatch and
        # result paths record into inline; stats() reads it (shared
        # repro.stats schema, pool_-prefixed)
        self.metrics = MetricsRegistry("pool", retention_s=retention_s)
        for name, doc in (
            ("requests", "coded-matmul requests started on this pool"),
            ("completed", "requests decoded successfully"),
            ("failed", "requests that raised"),
            ("redispatched", "shares re-shipped after a worker death"),
            ("hedged", "shares speculatively re-shipped past the hedge "
                       "deadline"),
            ("hedge_wasted", "hedged shares whose extra reply lost the "
                             "race (duplicate discarded)"),
            ("raw_bytes_out", "share payload bytes before the wire codec"),
            ("bytes_out", "bytes actually sent on the wire"),
            ("raw_bytes_in", "result payload bytes before the wire codec"),
            ("bytes_in", "bytes actually received on the wire"),
            ("heartbeats", "worker heartbeat messages received"),
        ):
            self.metrics.counter(name, doc)
        self._wall_hist = self.metrics.histogram(
            "wall_ms", "request wall-clock (ms)"
        )
        self._time_to_R_hist = self.metrics.histogram(
            "time_to_R_ms", "dispatch -> R-th response (ms)"
        )
        self.metrics.gauge("workers_live", "live workers in the pool")
        self.metrics.gauge(
            "worker_health",
            "per-worker health score in (0, 1]: EWMA share round-trip "
            "and heartbeat jitter vs the pool median",
            label="wid",
        )
        self.metrics.gauge(
            "worker_tasks_done", "tasks completed, as self-reported on "
            "the worker's last heartbeat", label="wid",
        )
        # per-worker health: share round-trips land in _route_result,
        # heartbeat jitter in _reader_loop; dispatch ordering and the
        # hedge deadline both read it
        self.health = HealthTracker(
            alpha=health_ewma, retention_s=retention_s
        )
        # the admin HTTP plane: source/resolver registration is
        # unconditional (cheap, lets an externally started server see this
        # master); the server itself starts only when a port is configured
        self._obs_source = obs_http.register_source("pool", self.stats)
        obs_http.register_trace_resolver(self._resolve_trace)
        self._obs_server = None
        obs_port = (
            cfg.obs_http_port if cfg.obs_http_port is not None
            else settings.get_int("obs_http_port")
        )
        if obs_port is not None:
            self._obs_server = obs_http.start_server(obs_port)
        # rid -> trace_id of recently finished traced requests, so spans
        # from stragglers that answer after the any-R decode still land
        # on the right timeline (bounded: oldest entries roll off)
        self._done_traces: "Dict[int, str]" = {}
        self._done_traces_cap = 256
        # failure injection: per-worker-id compute delay stamped into task
        # headers (tests/CI park a victim's compute so SIGKILL lands mid-task)
        self.task_delay_ms: Dict[int, float] = {}
        # error injection: these workers raise instead of computing, which
        # exercises the bounded share-retry path without corrupting state
        self.task_fail_wids: set = set()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="pool-accept", daemon=True
        )
        self._accept_thread.start()
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, name="pool-monitor", daemon=True
        )
        self._monitor_thread.start()

    # -- membership --------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            threading.Thread(
                target=self._register, args=(sock,), daemon=True
            ).start()

    def _register(self, sock: socket.socket) -> None:
        try:
            chan = Channel(sock, level=self.compression_level)
            caps, _, _, _ = chan.recv()
        except (ProtocolError, OSError):
            sock.close()
            return
        if caps.get("type") != "hello":
            sock.close()
            return
        # per-connection codec: the strongest the peer decodes, or the
        # pinned transport when both sides support it; a v0 worker that
        # advertises nothing gets raw frames (full interop)
        chan.codec = negotiate(caps.get("codecs"), prefer=self.transport)
        with self._lock:
            wid = self._next_wid
            self._next_wid += 1
            handle = _WorkerHandle(wid, chan, caps)
            self._workers[wid] = handle
            self._joined.notify_all()
        self.membership.record_join(wid, time.time())
        threading.Thread(
            target=self._reader_loop, args=(handle,),
            name=f"pool-reader-{wid}", daemon=True,
        ).start()

    def _reader_loop(self, handle: _WorkerHandle) -> None:
        try:
            while True:
                header, arrays, raw, wire = handle.chan.recv()
                handle.last_seen = time.time()
                kind = header.get("type")
                if kind == "result":
                    self._account(raw_bytes_in=raw, bytes_in=wire)
                    self._route_result(handle, header, arrays, raw, wire)
                elif kind == "heartbeat":
                    # heartbeat inter-arrival jitter is a health signal:
                    # a stuttering worker is struggling long before it
                    # trips the death deadline
                    self.health.record_heartbeat(handle.wid)
                    handle.tasks_done = int(header.get("tasks_done", 0))
                    handle.busy_us = float(header.get("busy_us", 0.0))
                    self._account(heartbeats=1)
                elif kind == "echo_reply":
                    with self._lock:
                        waiter = self._echo_waiters.pop(
                            header.get("seq"), None
                        )
                    if waiter is not None:
                        event, slot = waiter
                        slot.append((raw, wire))
                        event.set()
        except (ProtocolError, OSError):
            self._on_death(handle)

    def _monitor_loop(self) -> None:
        while not self._closed:
            time.sleep(min(self.heartbeat_timeout / 4.0, 0.5))
            deadline = time.time() - self.heartbeat_timeout
            with self._lock:
                stale = [
                    h for h in self._workers.values()
                    if h.alive and h.last_seen < deadline
                ]
            for h in stale:
                # shutdown() (not close()) is what actually wakes a reader
                # thread blocked in recv with EOF, tripping its death path
                _shutdown_socket(h.sock)

    def _on_death(self, handle: _WorkerHandle) -> None:
        with self._lock:
            if not handle.alive:
                return
            handle.alive = False
            self._workers.pop(handle.wid, None)
            requests = list(self._requests.values())
        self.membership.record_leave(handle.wid, time.time())
        self.health.forget(handle.wid)
        _shutdown_socket(handle.sock)
        for req in requests:
            self._redispatch(req, handle.wid)

    def _route_result(
        self, handle: _WorkerHandle, header: Dict, arrays: Dict,
        raw: int = 0, wire: int = 0,
    ) -> None:
        rid = header.get("req")
        with self._lock:
            req = self._requests.get(rid)
            done_tid = self._done_traces.get(rid) if req is None else None
        if req is None:
            # request already decoded (straggler / duplicate) — but a
            # traced request still wants the late responder on its
            # timeline, tagged so the viewer can tell it lost the race
            if done_tid is not None:
                self._collect_worker_spans(
                    done_tid, handle, header, wire, late=True
                )
            return
        if req.trace is not None:
            self._collect_worker_spans(
                req.trace.trace_id, handle, header, wire, late=False
            )
        with req.lock:
            entry = req.pending.pop(header.get("task"), None)
            req.raw_in += raw
            req.wire_in += wire
        if entry is not None and header.get("ok"):
            # master-observed send->result round-trip: the health signal
            # covering comm + compute in one number (hedged duplicates
            # measure too — both round-trips really happened)
            self.health.record_share(
                handle.wid, (time.perf_counter() - entry[4]) * 1e3
            )
        self.membership.record_response(
            handle.wid, float(header.get("wall_us", 0.0)) / 1e3
        )
        if header.get("ok"):
            req.events.put(("result", int(header["i"]), arrays.get("h")))
        else:
            req.events.put(
                ("error", int(header["i"]), (handle.wid, header.get("err")))
            )

    def _collect_worker_spans(
        self, trace_id: str, handle: _WorkerHandle, header: Dict,
        wire: int, late: bool,
    ) -> None:
        """Land a result frame's compute span on the request's timeline.

        Tracing-capable workers piggyback their span on the reply
        (``spans`` header field); a v0 peer sends none, so the master
        synthesizes one from the ``wall_us`` it already reports, ending
        at receipt time — same schema either way, tagged so readers know
        which clock produced it.
        """
        entries = header.get("spans")
        tags = {
            "wid": handle.wid, "worker": handle.name,
            "share": header.get("i"), "wire_bytes": wire,
        }
        if late:
            tags["late"] = True
        tracer = obs.tracer()
        if entries:
            for span in obs.spans_from_wire(entries, trace_id, **tags):
                tracer.record(span)
        else:
            t1 = obs.now()
            wall_s = float(header.get("wall_us", 0.0)) / 1e6
            tracer.record(obs.Span(
                trace_id=trace_id, name="compute", component="worker",
                t_start=t1 - wall_s, t_end=t1,
                tags={**tags, "synthesized": True,
                      "ok": bool(header.get("ok"))},
            ))

    # -- introspection -----------------------------------------------------

    def live_workers(self) -> List[int]:
        with self._lock:
            return sorted(w for w, h in self._workers.items() if h.alive)

    def worker_caps(self) -> Dict[int, Dict]:
        with self._lock:
            return {w: dict(h.caps) for w, h in self._workers.items()}

    def worker_codecs(self) -> Dict[int, str]:
        """Negotiated wire codec per live worker."""
        with self._lock:
            return {w: h.codec for w, h in self._workers.items()}

    def trace(self):
        """The observed membership history as a real WorkerTrace."""
        return self.membership.trace()

    def _account(self, **deltas) -> None:
        for k, v in deltas.items():
            self.metrics.counter(k).inc(v)

    def stats(self) -> StatsSnapshot:
        """Cumulative master accounting in the shared ``repro.stats``
        snapshot schema (``pool_``-prefixed keys): counters,
        ``pool_bytes_in/out`` vs ``pool_raw_bytes_in/out`` (on-wire vs
        pre-codec), ``pool_wall_ms``/``pool_time_to_R_ms`` histograms
        with p50/p99/sum, and the live gauges (``pool_workers_live``,
        per-worker ``pool_worker_health_by_wid`` scores).  Legacy
        unprefixed keys still resolve (with one DeprecationWarning per
        key)."""
        with self._lock:
            live = {
                w: h for w, h in self._workers.items() if h.alive
            }
        self.metrics.gauge("workers_live").set(len(live))
        scores = self.health.scores()
        health_gauge = self.metrics.gauge("worker_health")
        tasks_gauge = self.metrics.gauge("worker_tasks_done")
        health_gauge.clear_labels(keep=list(live))
        tasks_gauge.clear_labels(keep=list(live))
        for wid, handle in live.items():
            health_gauge.set(round(scores.get(wid, 1.0), 4), key=wid)
            tasks_gauge.set(handle.tasks_done, key=wid)
        return self.metrics.snapshot()

    def _resolve_trace(self, key: str):
        """Map a ``/trace/<key>`` request id to its merged Timeline (or
        None when this master never saw it).  Accepts the pool's integer
        request id; raw trace-id strings fall through to the process
        tracer inside :mod:`repro.obs.http`."""
        try:
            rid = int(key)
        except ValueError:
            return None
        with self._lock:
            req = self._requests.get(rid)
            tid = (
                req.trace.trace_id
                if req is not None and req.trace is not None
                else self._done_traces.get(rid)
            )
        if tid is None:
            return None
        timeline = obs.tracer().timeline(tid)
        return timeline if timeline.spans else None

    def wait_for_workers(self, n: int, timeout: float = 60.0) -> None:
        deadline = time.time() + timeout
        with self._joined:
            while len(self._workers) < n:
                remaining = deadline - time.time()
                if remaining <= 0 or not self._joined.wait(remaining):
                    raise TimeoutError(
                        f"pool has {len(self._workers)}/{n} workers after "
                        f"{timeout:.0f}s"
                    )

    # -- calibration probe -------------------------------------------------

    def echo(
        self, nbytes: int, wid: Optional[int] = None,
        timeout: float = 30.0, codec: Optional[str] = None,
    ) -> Dict[str, float]:
        """Time one real round-trip of an ``nbytes`` share-shaped payload
        to a worker and back (the calibration probe behind the pool
        backend's measured comm coefficients).  Returns seconds and byte
        counts: ``{"rtt_s", "raw_bytes", "wire_bytes"}``."""
        with self._lock:
            handle = (
                self._workers.get(wid) if wid is not None
                else next(iter(sorted(self._workers.items())), (None, None))[1]
            )
        if handle is None or not handle.alive:
            raise WorkerDied("no live worker for echo probe")
        payload = np.arange(max(1, nbytes // 4), dtype=np.uint32)
        with self._lock:
            seq = self._next_echo
            self._next_echo += 1
            event, slot = threading.Event(), []
            self._echo_waiters[seq] = (event, slot)
        t0 = time.perf_counter()
        use = handle.codec if codec is None else codec
        raw, wire = handle.send(
            {"type": "echo", "seq": seq, "codec": use},
            {"x": payload}, codec=use,
        )
        if not event.wait(timeout):
            with self._lock:
                self._echo_waiters.pop(seq, None)
            raise TimeoutError(f"echo probe {seq} got no reply in {timeout}s")
        rtt = time.perf_counter() - t0
        raw_back, wire_back = slot[0]
        return {
            "rtt_s": rtt,
            "raw_bytes": float(raw + raw_back),
            "wire_bytes": float(wire + wire_back),
        }

    # -- dispatch ----------------------------------------------------------

    def _pick_worker(self, exclude: Tuple[int, ...] = ()) -> _WorkerHandle:
        # health read happens before the dispatch lock (the tracker has
        # its own lock and never takes this one: no ordering cycle)
        scores = self.health.scores()
        with self._lock:
            live = [
                h for w, h in sorted(self._workers.items())
                if h.alive and w not in exclude
            ]
            if not live:
                live = [h for _, h in sorted(self._workers.items()) if h.alive]
            if not live:
                raise WorkerDied("pool has no live workers")
            # health-aware ordering: round-robin over the healthy subset;
            # known-slow workers (score < threshold) only serve when no
            # healthier worker is available.  With no health data every
            # score is 1.0 and this is exactly the old pure round-robin.
            healthy = [
                h for h in live
                if scores.get(h.wid, 1.0) >= DISPATCH_THRESHOLD
            ]
            pool = healthy or live
            self._rr += 1
            return pool[self._rr % len(pool)]

    def _stream_chunks(self, fa: np.ndarray, gb: np.ndarray) -> int:
        """How many chunks to pipeline this share in (1 = single message).
        Only 3-D planar block shares with a shared contraction axis are
        chunkable: ``fa (t,r,D) @ gb (r,s,D)`` splits along r exactly."""
        if self.stream_chunk_bytes <= 0:
            return 1
        if (
            getattr(fa, "ndim", 0) != 3 or getattr(gb, "ndim", 0) != 3
            or fa.shape[1] != gb.shape[0]
        ):
            return 1
        r = int(fa.shape[1])
        total = int(fa.nbytes) + int(gb.nbytes)
        if total <= self.stream_chunk_bytes:
            return 1
        return max(1, min(r, math.ceil(total / self.stream_chunk_bytes)))

    def _send_task(
        self,
        req: _Request,
        scheme,
        i: int,
        fa: np.ndarray,
        gb: np.ndarray,
        exclude: Tuple[int, ...] = (),
        redispatch: bool = False,
        hedge: bool = False,
    ) -> int:
        tried = set(exclude)
        while True:
            handle = self._pick_worker(tuple(tried))
            with self._lock:
                task = self._next_task
                self._next_task += 1
            header = {
                "type": "task",
                "req": req.rid,
                "task": task,
                "i": i,
                "codec": handle.codec,
                "ring": {
                    "p": scheme.ring.p,
                    "e": scheme.ring.e,
                    "degrees": list(scheme.ring.degrees),
                },
            }
            # trace_id rides the task header only when this worker's hello
            # advertised tracing — a v0 peer never sees the field and the
            # master synthesizes its compute span from wall_us instead
            if req.trace is not None and handle.caps.get("tracing"):
                header["trace"] = req.trace.trace_id
            # None = auto: each worker decides per its own device/ring
            # (kernel_auto_enabled on the worker side)
            header["use_kernel"] = (
                "auto" if self.use_kernel is None else bool(self.use_kernel)
            )
            delay = self.task_delay_ms.get(handle.wid, 0.0)
            if delay > 0.0:
                header["delay_ms"] = delay
            if handle.wid in self.task_fail_wids:
                header["inject_fail"] = True
            with req.lock:
                req.pending[task] = (
                    i, fa, gb, handle.wid, time.perf_counter()
                )
            try:
                t_send = obs.now()
                chunks = self._stream_chunks(fa, gb)
                if chunks <= 1:
                    raw, wire = handle.send(header, {"fa": fa, "gb": gb})
                else:
                    # pipelined transfer: ship the share as contraction-
                    # axis slices so the worker computes partial products
                    # while later chunks are still in flight.  The header
                    # must promise exactly the number of chunk messages
                    # that follow (ceil(r/step) can undershoot the chunk
                    # target when step rounds up), or the worker's
                    # accumulator waits forever on a phantom chunk.
                    r = fa.shape[1]
                    step = math.ceil(r / chunks)
                    starts = range(0, r, step)
                    header["stream"] = len(starts)
                    raw, wire = handle.send(header)
                    for seq, lo in enumerate(starts):
                        hi = min(lo + step, r)
                        craw, cwire = handle.send(
                            {
                                "type": "chunk", "req": req.rid,
                                "task": task, "seq": seq,
                            },
                            {
                                "fa": np.ascontiguousarray(fa[:, lo:hi, :]),
                                "gb": np.ascontiguousarray(gb[lo:hi, :, :]),
                            },
                        )
                        raw += craw
                        wire += cwire
                with req.lock:
                    req.raw_out += raw
                    req.wire_out += wire
                    req.codecs.add(handle.codec)
                self._account(raw_bytes_out=raw, bytes_out=wire)
                # the send span IS the dead worker's footprint when it
                # never answers: timeline evidence the share went there
                obs.tracer().add(
                    req.trace, "send", "pool", t_send, obs.now(),
                    wid=handle.wid, share=i, task=task,
                    raw_bytes=raw, wire_bytes=wire, chunks=chunks,
                    codec=handle.codec, redispatch=redispatch,
                    hedge=hedge,
                )
                return handle.wid
            except OSError:
                # the send found the corpse; retry on another worker (the
                # death path would skip this task if _on_death already ran)
                with req.lock:
                    req.pending.pop(task, None)
                tried.add(handle.wid)
                self._on_death(handle)

    def _redispatch(self, req: _Request, dead_wid: int) -> None:
        """Re-ship the dead worker's unanswered shares to survivors."""
        with req.lock:
            if req.done:
                return
            orphans = [
                (task, i, fa, gb)
                for task, (i, fa, gb, wid, _t) in req.pending.items()
                # a share already satisfied (its hedge or twin answered)
                # has nothing left to recover
                if wid == dead_wid and i not in req.satisfied
            ]
            for task, *_ in orphans:
                req.pending.pop(task, None)
        for _, i, fa, gb in orphans:
            try:
                self._send_task(req, req.scheme, i, fa, gb,
                                exclude=(dead_wid,), redispatch=True)
                with req.lock:
                    req.redispatched += 1
                self._account(redispatched=1)
            except WorkerDied as e:
                req.events.put(("dead", -1, str(e)))
                return

    def _maybe_hedge(self, req: _Request, scheme, got) -> Optional[float]:
        """Speculative re-dispatch sweep: any share outstanding past the
        health-derived deadline (p95 of recent round-trips x
        ``hedge_factor``) is re-shipped once to a different live worker
        — *before* the heartbeat timeout would declare its holder dead.
        First valid reply wins; the duplicate is discarded idempotently
        in ``execute``.  Returns seconds until the next share becomes
        hedge-due (None when hedging is off/armed with no evidence), so
        the wait loop knows how long it may block.
        """
        deadline_ms = self.health.hedge_deadline_ms(self.hedge_factor)
        if deadline_ms is None:
            return None
        now_pc = time.perf_counter()
        due: List[Tuple[int, np.ndarray, np.ndarray, int]] = []
        next_due: Optional[float] = None
        with req.lock:
            for task, (i, fa, gb, wid, t_sent) in req.pending.items():
                if i in got or i in req.satisfied or i in req.hedged_shares:
                    continue
                age_ms = (now_pc - t_sent) * 1e3
                if age_ms >= deadline_ms:
                    due.append((i, fa, gb, wid))
                else:
                    remain = (deadline_ms - age_ms) / 1e3
                    if next_due is None or remain < next_due:
                        next_due = remain
        for i, fa, gb, wid in due:
            # hedging needs a genuinely spare worker: another live
            # process besides the one still holding the share
            # (_pick_worker's exclude falls back to everyone otherwise)
            if not (set(self.live_workers()) - {wid}):
                continue
            with req.lock:
                if i in req.satisfied or i in req.hedged_shares:
                    continue
                req.hedged_shares.add(i)
            try:
                self._send_task(
                    req, scheme, i, fa, gb, exclude=(wid,), hedge=True
                )
            except WorkerDied:
                continue  # the original dispatch may still answer
            with req.lock:
                req.hedged += 1
            self._account(hedged=1)
        return next_due

    # -- protocol entry point ----------------------------------------------

    def execute(
        self,
        scheme,
        A,
        B,
        mask=None,
        key=None,
        timeout: Optional[float] = None,
        batch_fill: Optional[int] = None,
        trace: Optional[obs.TraceContext] = None,
    ) -> Tuple[np.ndarray, PoolStats]:
        """Run one coded matmul on the pool; returns (C, PoolStats).

        ``mask`` is the usual (N,)-bool share-liveness vector: masked-out
        share indices are never dispatched (the test seam for simulating
        straggler budgets deterministically).  ``key`` feeds the keyed
        encode of secure schemes — encode runs master-side, so workers
        only ever see masked shares.  ``batch_fill`` is observability from
        a coalescing caller: how many of the scheme's batch slots carry
        real requests (the rest are padding), surfaced on PoolStats.
        ``trace`` carries an upstream :class:`repro.obs.TraceContext`
        (scheduler/serving); when tracing is enabled and none is passed, a
        fresh one is opened so direct ``Master.execute`` calls trace too.
        """
        t0 = time.perf_counter()
        if trace is None:
            trace = obs.maybe_context("pool")
        tracer = obs.tracer()
        N, R = scheme.N, scheme.R
        shares = list(range(N))
        if mask is not None:
            m = np.asarray(mask, dtype=bool)
            if len(m) != N:
                raise ValueError(f"mask has {len(m)} entries, scheme N={N}")
            shares = [i for i in shares if m[i]]
        if len(shares) < R:
            raise NotEnoughResponders(
                f"{scheme.name}: mask leaves {len(shares)} shares, "
                f"decode needs R={R}"
            )
        encode_at, _ = worker_closures(scheme, keyed=key is not None)

        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = _Request(rid, R, trace=trace)
            req.scheme = scheme
            self._requests[rid] = req
        self._account(requests=1)
        deadline = time.perf_counter() + timeout if timeout else None
        workers_used: List[int] = []
        ok = False
        try:
            import jax.numpy as jnp

            for i in shares:
                t_enc = obs.now()
                if key is None:
                    fa, gb = encode_at(A, B, jnp.int32(i))
                else:
                    fa, gb = encode_at(A, B, jnp.int32(i), key)
                fa, gb = np.asarray(fa), np.asarray(gb)
                tracer.add(trace, "encode", "pool", t_enc, obs.now(),
                           share=i, scheme=scheme.name)
                wid = self._send_task(req, scheme, i, fa, gb)
                workers_used.append(wid)
            t_wait = obs.now()

            got: Dict[int, np.ndarray] = {}
            errors: Dict[int, int] = {}  # share -> failed compute attempts
            t_R = None
            while len(got) < R:
                wait = None
                if deadline is not None:
                    wait = deadline - time.perf_counter()
                    if wait <= 0:
                        raise TimeoutError(
                            f"pool request {rid}: {len(got)}/{R} responses "
                            f"after {timeout}s"
                        )
                poll = wait
                if self.hedge_factor > 0:
                    # hedge sweep, then bound the blocking get by the next
                    # share's hedge deadline so overdue shares re-ship
                    # promptly instead of waiting out the request timeout
                    next_due = self._maybe_hedge(req, scheme, got)
                    if next_due is None:
                        next_due = 0.25  # deadline not armed yet: re-check
                    poll = max(
                        1e-3,
                        next_due if poll is None else min(poll, next_due),
                    )
                try:
                    kind, i, payload = req.events.get(timeout=poll)
                except queue.Empty:
                    if self.hedge_factor > 0:
                        continue  # hedge wakeup; loop top re-checks deadline
                    raise TimeoutError(
                        f"pool request {rid}: {len(got)}/{R} responses "
                        f"after {timeout}s"
                    ) from None
                if kind == "result":
                    if i in got:
                        # duplicate reply (a hedge twin, or an error-retry
                        # racing its original): first valid reply already
                        # won — discard idempotently
                        if i in req.hedged_shares:
                            self._account(hedge_wasted=1)
                    else:
                        got[i] = payload
                        with req.lock:
                            req.satisfied.add(i)
                elif kind == "error":
                    # a compute error is a worker failure, not a request
                    # failure: retry the share ONCE on a different worker,
                    # then write it off — the any-R decode only needs R of
                    # the remaining shares
                    bad_wid, err = payload
                    errors[i] = errors.get(i, 0) + 1
                    healthy = [
                        s for s in shares
                        if s in got or errors.get(s, 0) < 2
                    ]
                    if len(healthy) < R:
                        raise RuntimeError(
                            f"pool request {rid}: share {i} failed "
                            f"{errors[i]}x and only {len(healthy)} viable "
                            f"shares remain (R={R}); last error: {err}"
                        )
                    if errors[i] < 2 and i not in got:
                        t_enc = obs.now()
                        if key is None:
                            fa, gb = encode_at(A, B, jnp.int32(i))
                        else:
                            fa, gb = encode_at(A, B, jnp.int32(i), key)
                        fa, gb = np.asarray(fa), np.asarray(gb)
                        tracer.add(trace, "encode", "pool", t_enc,
                                   obs.now(), share=i, retry=True)
                        self._send_task(
                            req, scheme, i, fa, gb,
                            exclude=(bad_wid,), redispatch=True,
                        )
                else:  # "dead": no live workers remain for a re-dispatch
                    raise WorkerDied(
                        f"pool request {rid}: {payload} with {len(got)}/{R} "
                        f"responses collected"
                    )
            t_R = (time.perf_counter() - t0) * 1e3
            with req.lock:
                req.done = True
            # the any-R race: dispatch done -> R-th response landed
            tracer.add(trace, "wait_R", "pool", t_wait, obs.now(),
                       R=R, responders=sorted(got),
                       redispatched=req.redispatched, hedged=req.hedged)
            t_dec = obs.now()
            C = decode_responses(scheme, got)
            tracer.add(trace, "decode", "pool", t_dec, obs.now(),
                       live_idx=sorted(got)[:R], scheme=scheme.name)
            wall_ms = (time.perf_counter() - t0) * 1e3
            stats = PoolStats(
                dispatched=tuple(shares),
                live_idx=tuple(sorted(got))[:R],
                workers=tuple(sorted(set(workers_used))),
                redispatched=req.redispatched,
                wall_ms=wall_ms,
                time_to_R_ms=t_R,
                hedged=req.hedged,
                batch=int(getattr(scheme, "batch", 1)),
                fill=(int(batch_fill) if batch_fill is not None
                      else int(getattr(scheme, "batch", 1))),
                raw_bytes_out=req.raw_out,
                bytes_out=req.wire_out,
                raw_bytes_in=req.raw_in,
                bytes_in=req.wire_in,
                codecs=tuple(sorted(req.codecs)),
            )
            ok = True
            self._account(completed=1)
            self._wall_hist.observe(wall_ms)
            self._time_to_R_hist.observe(t_R)
            return C, stats
        finally:
            if not ok:
                self._account(failed=1)
            with self._lock:
                self._requests.pop(rid, None)
                if trace is not None:
                    # keep routing late responders' spans to this timeline
                    self._done_traces[rid] = trace.trace_id
                    while len(self._done_traces) > self._done_traces_cap:
                        self._done_traces.pop(next(iter(self._done_traces)))

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        obs_http.unregister_source(self._obs_source)
        obs_http.unregister_trace_resolver(self._resolve_trace)
        with self._lock:
            handles = list(self._workers.values())
            self._workers.clear()
        for h in handles:
            try:
                h.send({"type": "shutdown"})
            except OSError:
                pass
            _shutdown_socket(h.sock)
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self) -> "Master":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# local pools: master + N worker OS processes in one call
# --------------------------------------------------------------------------


def _worker_env() -> Dict[str, str]:
    """Child env: inherit, but make sure the repro package resolves."""
    import repro

    env = dict(os.environ)
    # repro may be a namespace package (no __init__.py): __path__ still
    # points at the package directory; its parent is the import root
    pkg_dir = (repro.__file__ and os.path.dirname(repro.__file__)) or list(
        repro.__path__
    )[0]
    src = os.path.dirname(os.path.abspath(pkg_dir))
    parts = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join(dict.fromkeys(parts))
    return env


_LEGACY_POOL_ARGS = (
    "workers", "address", "heartbeat_s", "heartbeat_timeout", "use_kernel",
    "spawn_timeout",
)


class LocalPool:
    """A master plus N local worker OS processes (the zero-config pool).

    The single-host specialization of the launcher
    (:func:`repro.dist.launch.launch_pool`): prefers a Unix-domain socket
    under a private tempdir, falls back to loopback TCP.  ``kill(k)``
    SIGKILLs k workers (failure injection); ``close()`` shuts the master
    down and reaps every child.

    Preferred construction is ``LocalPool(config=PoolConfig(...))``;
    keyword arguments (``workers=``, ``address=``, ...) remain supported
    and override the config.  Positional arguments are deprecated (one
    ``DeprecationWarning`` per process) but keep working.
    """

    def __init__(self, *args, config: Optional[PoolConfig] = None, **kwargs):
        if args:
            warn_deprecated_once(
                "LocalPool-positional",
                "positional LocalPool arguments are deprecated; pass "
                "LocalPool(config=PoolConfig(workers=...)) or keyword "
                "arguments",
            )
            for name, val in zip(_LEGACY_POOL_ARGS, args):
                if name in kwargs:
                    raise TypeError(
                        f"LocalPool got multiple values for {name!r}"
                    )
                kwargs[name] = val
        unknown = set(kwargs) - set(_LEGACY_POOL_ARGS)
        if unknown:
            raise TypeError(f"LocalPool got unexpected {sorted(unknown)}")
        cfg = config or PoolConfig()
        if "address" in kwargs and kwargs["address"] is not None:
            cfg = cfg.with_(endpoint=Endpoint.parse(kwargs["address"]))
        for name in ("workers", "heartbeat_s", "heartbeat_timeout",
                     "use_kernel", "spawn_timeout"):
            if name in kwargs:
                cfg = cfg.with_(**{name: kwargs[name]})
        self.config = cfg
        self._tmpdir = None
        if cfg.endpoint is None:
            if hasattr(socket, "AF_UNIX"):
                self._tmpdir = tempfile.mkdtemp(prefix="repro-pool-")
                cfg = cfg.with_(endpoint=Endpoint.unix(
                    os.path.join(self._tmpdir, "pool.sock")
                ))
            else:  # pragma: no cover - non-POSIX fallback
                cfg = cfg.with_(endpoint=Endpoint.tcp("127.0.0.1", 0))
        self.master = Master(config=cfg)
        # the launcher owns process spawning; LocalPool is its local case
        from .launch import spawn_local_workers

        self.procs: List[subprocess.Popen] = spawn_local_workers(
            self.master.address, cfg.workers,
            heartbeat_s=cfg.heartbeat_s, name_prefix="local",
        )
        try:
            self.master.wait_for_workers(
                cfg.workers, timeout=cfg.spawn_timeout
            )
        except TimeoutError:
            self.close()
            raise

    @property
    def address(self) -> str:
        return self.master.address

    def execute(self, scheme, A, B, mask=None, key=None, timeout=None,
                batch_fill=None, trace=None):
        return self.master.execute(scheme, A, B, mask=mask, key=key,
                                   timeout=timeout, batch_fill=batch_fill,
                                   trace=trace)

    def stats(self) -> Dict[str, object]:
        """Cumulative pool accounting (shared repro.stats schema)."""
        return self.master.stats()

    def kill(self, k: int = 1, sig: int = signal.SIGKILL) -> List[int]:
        """SIGKILL ``k`` live worker processes; returns the killed pids."""
        killed = []
        for proc in self.procs:
            if len(killed) >= k:
                break
            if proc.poll() is None:
                os.kill(proc.pid, sig)
                killed.append(proc.pid)
        for pid in killed:  # reap promptly so poll() reflects reality
            for proc in self.procs:
                if proc.pid == pid:
                    proc.wait(timeout=30)
        return killed

    def alive_count(self) -> int:
        return sum(1 for p in self.procs if p.poll() is None)

    def close(self) -> None:
        self.master.close()
        for proc in self.procs:
            if proc.poll() is None:
                proc.terminate()
        for proc in self.procs:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover
                proc.kill()
                proc.wait(timeout=10)
        if self._tmpdir:
            try:
                sock = os.path.join(self._tmpdir, "pool.sock")
                if os.path.exists(sock):
                    os.unlink(sock)
                os.rmdir(self._tmpdir)
            except OSError:  # pragma: no cover
                pass

    def __enter__(self) -> "LocalPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
