"""Worker-process entrypoint: ``python -m repro.dist.worker --connect ADDR``.

On connect the worker sends a ``hello`` capability handshake — device kind
(``jax.default_backend()``), pid, the ring-arithmetic envelope it can serve
(the p=2 machine-word fast path plus the general small-modulus path), the
wire codecs it can decode (``protocol.supported_codecs()`` — the master
picks one per connection, so a v0 peer that advertises nothing simply gets
raw frames), and its autotune-cache coverage — then serves ``task``
messages until the master says ``shutdown`` or the socket drops.

A task carries the codeword-ring constructor args, a share index and the
two encoded shares; the worker computes the block product ``h = fa @ gb``
in that ring (jitted once per ring; routed through the tuned Pallas
``gr_matmul`` kernel when the master asks for it and the ring is inside the
kernel envelope) and replies with the result encoded in the connection's
codec.  Workers never see the operands A and B, only their own shares —
exactly the paper's upload model, and what makes the T-private schemes
private against the pool.

Pipelined streaming: a task header with ``stream: k`` carries no arrays;
``k`` ``chunk`` messages follow (interleavable with other tasks — chunks
are keyed by ``(req, task)``), each holding a slice of ``fa``/``gb`` along
the contraction axis.  The worker computes each chunk's partial product as
it lands and accumulates ``h = ring.add(h, partial)`` — exact, because
partial block products over Z_{p^e}/GR are already reduced and addition is
associative — so master-side encode, socket transfer and worker compute
overlap instead of serializing.

A daemon thread pushes ``heartbeat`` messages every ``--heartbeat``
seconds; the master treats a silent worker as dead after a grace window
and re-dispatches its shares.  ``delay_ms`` in a task header is a
failure-injection knob (tests/CI sleep a victim worker so SIGKILL lands
provably mid-compute); it is ignored unless the master sets it.  An
``echo`` message bounces its payload straight back (``echo_reply``) — the
master's calibration probe for measuring real socket round-trips.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs.trace import now as obs_now

from .protocol import (
    PROTOCOL_VERSION,
    ProtocolError,
    connect,
    recv_msg,
    send_msg,
    supported_codecs,
)

__all__ = ["WorkerRuntime", "main"]


def _capabilities() -> Dict:
    """The capability handshake payload (device, rings, codecs, autotune)."""
    import jax

    from repro.kernels.autotune import load_cache

    device = jax.default_backend()
    try:
        cache = load_cache()
        prefix = f"{device}|"
        coverage = sum(1 for k in cache if k.startswith(prefix))
        entries = len(cache)
    except Exception:  # a corrupt cache must not keep a worker out of the pool
        coverage, entries = 0, 0
    return {
        "protocol": PROTOCOL_VERSION,
        "pid": os.getpid(),
        "device": device,
        "jax_version": jax.__version__,
        # ring envelope mirrors Ring.__init__'s overflow discipline
        "rings": {"p2_max_e": 32, "general_max_q": 1 << 12},
        # wire codecs this worker can decode; the master negotiates one
        # per connection (absent = v0 peer = raw)
        "codecs": list(supported_codecs()),
        "streaming": True,
        # understands the optional "trace" task-header field and ships
        # compute spans back on result frames (absent = v0 peer: the
        # master synthesizes a span from wall_us instead)
        "tracing": True,
        "autotune": {"entries": entries, "device_entries": coverage},
    }


class _StreamState:
    """Accumulator for one in-flight streamed task."""

    def __init__(self, header: Dict, remaining: int):
        self.header = header  # the original task header (ring, knobs, ids)
        self.remaining = remaining
        self.h: Optional[np.ndarray] = None
        self.wall_us = 0.0
        self.failed = False
        self.t0 = obs_now()  # span start when the task is traced


class WorkerRuntime:
    """One worker's serve loop over an established socket."""

    def __init__(
        self,
        sock: socket.socket,
        name: str = "worker",
        heartbeat_s: float = 1.0,
    ):
        self.sock = sock
        self.name = name
        self.heartbeat_s = heartbeat_s
        self._send_lock = threading.Lock()
        self._stop = threading.Event()
        # (p, e, degrees, use_kernel) -> (ring, jitted product, jitted add)
        self._compute: Dict[Tuple, Tuple] = {}
        # (req, task) -> _StreamState for chunked tasks
        self._streams: Dict[Tuple[int, int], _StreamState] = {}
        self.tasks_done = 0
        self.busy_us = 0.0  # cumulative compute wall, shipped in heartbeats

    # -- ring-matmul closures (jitted once per ring) -----------------------

    def _closure(self, p: int, e: int, degrees: Tuple[int, ...], use_kernel):
        import jax

        from repro.core.galois import make_ring
        from repro.kernels import (
            gr_matmul,
            kernel_auto_enabled,
            kernel_supported,
        )

        key = (p, e, degrees, use_kernel)
        if key not in self._compute:
            ring = make_ring(p, e, degrees)
            # "auto" = kernel wherever it compiles on THIS device (the
            # worker decides; the master doesn't know worker hardware)
            use = (
                kernel_auto_enabled(ring)
                if use_kernel == "auto" else bool(use_kernel)
            )
            if use and kernel_supported(ring):
                fn = jax.jit(lambda fa, gb: gr_matmul(fa, gb, ring))
            else:
                fn = jax.jit(ring.matmul)
            # chunk accumulation: partial products are already reduced, so
            # ring addition combines them exactly
            add = jax.jit(ring.add)
            self._compute[key] = (ring, fn, add)
        return self._compute[key]

    # -- messaging ---------------------------------------------------------

    def _send(self, header: Dict, arrays=None, codec: str = "raw") -> None:
        with self._send_lock:
            send_msg(self.sock, header, arrays, codec=codec)

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_s):
            try:
                self._send({"type": "heartbeat", "t": time.time(),
                            "tasks_done": self.tasks_done,
                            "busy_us": round(self.busy_us, 1)})
            except OSError:
                return  # master gone; the main loop notices on recv

    def _reply(self, header: Dict, ok: bool, h=None, err: str = "",
               wall_us: float = 0.0, t0: Optional[float] = None,
               streamed: int = 0) -> None:
        reply = {
            "type": "result",
            "req": header["req"],
            "task": header["task"],
            "i": header["i"],
            "ok": ok,
            "wall_us": wall_us,
        }
        if header.get("trace") and t0 is not None:
            # piggyback the compute span on the result frame; the master
            # re-stamps it with the request's trace id and worker id
            tags: Dict = {"pid": os.getpid(), "ok": ok}
            if streamed:
                # streamed tasks: span covers arrival..final-chunk wall,
                # busy_us is the actual accumulated compute inside it
                tags["streamed"] = streamed
                tags["busy_us"] = round(wall_us, 1)
            reply["spans"] = [{
                "name": "compute", "t0": t0, "t1": obs_now(), "tags": tags,
            }]
        out = {}
        if ok:
            out["h"] = np.asarray(h)
        else:
            reply["err"] = err
        # results travel in the codec the master stamped on the task —
        # the negotiated connection codec, raw for v0-style masters
        self._send(reply, out, codec=header.get("codec", "raw"))
        self.tasks_done += 1
        self.busy_us += wall_us

    def _apply_injection(self, header: Dict) -> None:
        delay_ms = float(header.get("delay_ms", 0.0))
        if delay_ms > 0.0:  # failure-injection knob (see module doc)
            time.sleep(delay_ms / 1e3)
        if header.get("inject_fail"):  # error-injection knob: exercises
            # the master's bounded share-retry path in tests/CI
            raise RuntimeError("injected worker failure")

    def _handle_task(self, header: Dict, arrays: Dict) -> None:
        stream = int(header.get("stream", 0))
        if stream > 0:
            # chunked task: remember the header, accumulate as chunks land
            key = (header["req"], header["task"])
            state = _StreamState(header, stream)
            t0 = time.perf_counter()
            try:
                self._apply_injection(header)
            except Exception as e:
                state.failed = True
                self._reply(header, ok=False,
                            err=f"{type(e).__name__}: {e}",
                            wall_us=(time.perf_counter() - t0) * 1e6,
                            t0=state.t0, streamed=stream)
            self._streams[key] = state
            return
        tw0 = obs_now()
        t0 = time.perf_counter()
        try:
            self._apply_injection(header)
            _, fn, _ = self._closure(
                int(header["ring"]["p"]),
                int(header["ring"]["e"]),
                tuple(int(d) for d in header["ring"]["degrees"]),
                header.get("use_kernel", "auto"),
            )
            h = fn(arrays["fa"], arrays["gb"])
        except Exception as e:  # computation errors surface at the master
            self._reply(header, ok=False, err=f"{type(e).__name__}: {e}",
                        wall_us=(time.perf_counter() - t0) * 1e6, t0=tw0)
            return
        self._reply(header, ok=True, h=h,
                    wall_us=(time.perf_counter() - t0) * 1e6, t0=tw0)

    def _handle_chunk(self, header: Dict, arrays: Dict) -> None:
        key = (header.get("req"), header.get("task"))
        state = self._streams.get(key)
        if state is None:
            return  # task was re-dispatched elsewhere; drop silently
        state.remaining -= 1
        last = state.remaining <= 0
        if not state.failed:
            t0 = time.perf_counter()
            try:
                _, fn, add = self._closure(
                    int(state.header["ring"]["p"]),
                    int(state.header["ring"]["e"]),
                    tuple(int(d) for d in state.header["ring"]["degrees"]),
                    state.header.get("use_kernel", "auto"),
                )
                part = fn(arrays["fa"], arrays["gb"])
                state.h = part if state.h is None else add(state.h, part)
            except Exception as e:
                state.failed = True
                state.wall_us += (time.perf_counter() - t0) * 1e6
                self._reply(state.header, ok=False,
                            err=f"{type(e).__name__}: {e}",
                            wall_us=state.wall_us, t0=state.t0,
                            streamed=int(state.header.get("stream", 0)))
            else:
                state.wall_us += (time.perf_counter() - t0) * 1e6
        if last:
            self._streams.pop(key, None)
            if not state.failed:
                self._reply(state.header, ok=True, h=state.h,
                            wall_us=state.wall_us, t0=state.t0,
                            streamed=int(state.header.get("stream", 0)))

    def serve(self) -> int:
        self._send({"type": "hello", "name": self.name, **_capabilities()})
        hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
        hb.start()
        try:
            while True:
                try:
                    header, arrays = recv_msg(self.sock)
                except (ProtocolError, OSError):
                    return 0  # master hung up: clean exit
                kind = header.get("type")
                if kind == "task":
                    self._handle_task(header, arrays)
                elif kind == "chunk":
                    self._handle_chunk(header, arrays)
                elif kind == "echo":
                    # calibration probe: bounce the payload straight back
                    # so the master can time a real round-trip
                    self._send({"type": "echo_reply",
                                "seq": header.get("seq")}, arrays,
                               codec=header.get("codec", "raw"))
                elif kind == "ping":
                    self._send({"type": "heartbeat", "t": time.time(),
                                "tasks_done": self.tasks_done,
                                "busy_us": round(self.busy_us, 1)})
                elif kind == "shutdown":
                    return 0
                # unknown types are ignored: forward-compatible masters
        finally:
            self._stop.set()
            try:
                self.sock.close()
            except OSError:
                pass


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--connect", required=True, metavar="ADDR",
        help="master address: tcp:HOST:PORT or unix:/path/to.sock",
    )
    ap.add_argument("--name", default=f"worker-{os.getpid()}")
    ap.add_argument(
        "--heartbeat", type=float, default=1.0, metavar="SECONDS",
        help="heartbeat push interval (default 1s)",
    )
    ap.add_argument(
        "--connect-timeout", type=float, default=30.0, metavar="SECONDS",
    )
    args = ap.parse_args(argv)
    sock = connect(args.connect, timeout=args.connect_timeout)
    return WorkerRuntime(sock, args.name, args.heartbeat).serve()


if __name__ == "__main__":
    sys.exit(main())
