"""Worker-process entrypoint: ``python -m repro.dist.worker --connect ADDR``.

On connect the worker sends a ``hello`` capability handshake — device kind
(``jax.default_backend()``), pid, the ring-arithmetic envelope it can serve
(the p=2 machine-word fast path plus the general small-modulus path), and
its autotune-cache coverage (how many tuned block schedules the committed
cache carries for this device) — then serves ``task`` messages until the
master says ``shutdown`` or the socket drops.

A task carries the codeword-ring constructor args, a share index and the
two encoded shares; the worker computes the block product ``h = fa @ gb``
in that ring (jitted once per ring; routed through the tuned Pallas
``gr_matmul`` kernel when the master asks for it and the ring is inside the
kernel envelope) and replies with the raw result bytes.  Workers never see
the operands A and B, only their own shares — exactly the paper's upload
model, and what makes the T-private schemes private against the pool.

A daemon thread pushes ``heartbeat`` messages every ``--heartbeat``
seconds; the master treats a silent worker as dead after a grace window
and re-dispatches its shares.  ``delay_ms`` in a task header is a
failure-injection knob (tests/CI sleep a victim worker so SIGKILL lands
provably mid-compute); it is ignored unless the master sets it.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from .protocol import PROTOCOL_VERSION, ProtocolError, connect, recv_msg, send_msg

__all__ = ["WorkerRuntime", "main"]


def _capabilities() -> Dict:
    """The capability handshake payload (device, rings, autotune coverage)."""
    import jax

    from repro.kernels.autotune import load_cache

    device = jax.default_backend()
    try:
        cache = load_cache()
        prefix = f"{device}|"
        coverage = sum(1 for k in cache if k.startswith(prefix))
        entries = len(cache)
    except Exception:  # a corrupt cache must not keep a worker out of the pool
        coverage, entries = 0, 0
    return {
        "protocol": PROTOCOL_VERSION,
        "pid": os.getpid(),
        "device": device,
        "jax_version": jax.__version__,
        # ring envelope mirrors Ring.__init__'s overflow discipline
        "rings": {"p2_max_e": 32, "general_max_q": 1 << 12},
        "autotune": {"entries": entries, "device_entries": coverage},
    }


class WorkerRuntime:
    """One worker's serve loop over an established socket."""

    def __init__(
        self,
        sock: socket.socket,
        name: str = "worker",
        heartbeat_s: float = 1.0,
    ):
        self.sock = sock
        self.name = name
        self.heartbeat_s = heartbeat_s
        self._send_lock = threading.Lock()
        self._stop = threading.Event()
        # (p, e, degrees, use_kernel) -> (ring, jitted share-product)
        self._compute: Dict[Tuple, Tuple] = {}
        self.tasks_done = 0

    # -- ring-matmul closures (jitted once per ring) -----------------------

    def _closure(self, p: int, e: int, degrees: Tuple[int, ...], use_kernel):
        import jax

        from repro.core.galois import make_ring
        from repro.kernels import (
            gr_matmul,
            kernel_auto_enabled,
            kernel_supported,
        )

        key = (p, e, degrees, use_kernel)
        if key not in self._compute:
            ring = make_ring(p, e, degrees)
            # "auto" = kernel wherever it compiles on THIS device (the
            # worker decides; the master doesn't know worker hardware)
            use = (
                kernel_auto_enabled(ring)
                if use_kernel == "auto" else bool(use_kernel)
            )
            if use and kernel_supported(ring):
                fn = jax.jit(lambda fa, gb: gr_matmul(fa, gb, ring))
            else:
                fn = jax.jit(ring.matmul)
            self._compute[key] = (ring, fn)
        return self._compute[key]

    # -- messaging ---------------------------------------------------------

    def _send(self, header: Dict, arrays=None) -> None:
        with self._send_lock:
            send_msg(self.sock, header, arrays)

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_s):
            try:
                self._send({"type": "heartbeat", "t": time.time(),
                            "tasks_done": self.tasks_done})
            except OSError:
                return  # master gone; the main loop notices on recv

    def _handle_task(self, header: Dict, arrays: Dict) -> None:
        t0 = time.perf_counter()
        reply = {
            "type": "result",
            "req": header["req"],
            "task": header["task"],
            "i": header["i"],
            "ok": True,
        }
        out = {}
        try:
            delay_ms = float(header.get("delay_ms", 0.0))
            if delay_ms > 0.0:  # failure-injection knob (see module doc)
                time.sleep(delay_ms / 1e3)
            if header.get("inject_fail"):  # error-injection knob: exercises
                # the master's bounded share-retry path in tests/CI
                raise RuntimeError("injected worker failure")
            _, fn = self._closure(
                int(header["ring"]["p"]),
                int(header["ring"]["e"]),
                tuple(int(d) for d in header["ring"]["degrees"]),
                header.get("use_kernel", "auto"),
            )
            h = fn(arrays["fa"], arrays["gb"])
            out["h"] = np.asarray(h)
        except Exception as e:  # computation errors surface at the master
            reply.update(ok=False, err=f"{type(e).__name__}: {e}")
        reply["wall_us"] = (time.perf_counter() - t0) * 1e6
        self._send(reply, out)
        self.tasks_done += 1

    def serve(self) -> int:
        self._send({"type": "hello", "name": self.name, **_capabilities()})
        hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
        hb.start()
        try:
            while True:
                try:
                    header, arrays = recv_msg(self.sock)
                except (ProtocolError, OSError):
                    return 0  # master hung up: clean exit
                kind = header.get("type")
                if kind == "task":
                    self._handle_task(header, arrays)
                elif kind == "ping":
                    self._send({"type": "heartbeat", "t": time.time(),
                                "tasks_done": self.tasks_done})
                elif kind == "shutdown":
                    return 0
                # unknown types are ignored: forward-compatible masters
        finally:
            self._stop.set()
            try:
                self.sock.close()
            except OSError:
                pass


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--connect", required=True, metavar="ADDR",
        help="master address: tcp:HOST:PORT or unix:/path/to.sock",
    )
    ap.add_argument("--name", default=f"worker-{os.getpid()}")
    ap.add_argument(
        "--heartbeat", type=float, default=1.0, metavar="SECONDS",
        help="heartbeat push interval (default 1s)",
    )
    ap.add_argument(
        "--connect-timeout", type=float, default=30.0, metavar="SECONDS",
    )
    args = ap.parse_args(argv)
    sock = connect(args.connect, timeout=args.connect_timeout)
    return WorkerRuntime(sock, args.name, args.heartbeat).serve()


if __name__ == "__main__":
    sys.exit(main())
