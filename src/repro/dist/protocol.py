"""Framed RPC wire protocol for the worker pool.

A *message* is one header frame followed by zero or more binary array
frames; every frame is a 4-byte big-endian length prefix + payload.  The
header is a small dict serialized with msgpack when available (JSON
otherwise — the first payload byte tags the codec, so mixed installs still
interoperate) and carries an ``_arrays`` manifest ``[(name, dtype, shape),
...]`` describing the binary frames that follow.  Arrays travel as raw
C-order bytes: a share of GR(p^e, D) is a uint32 coefficient tensor, and
shipping it verbatim keeps the hot path allocation-free on the send side
and a single ``np.frombuffer`` on the receive side.

Addresses are strings: ``tcp:HOST:PORT`` or ``unix:/path/to.sock`` (the
latter preferred for local pools — no TCP stack, no port collisions).
``tcp:HOST:0`` binds an ephemeral port; ``listen`` returns the resolved
address so workers can be pointed at it.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Dict, Optional, Tuple

import numpy as np

try:  # msgpack is the preferred header codec; JSON is the stdlib fallback
    import msgpack  # type: ignore

    _HAVE_MSGPACK = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    _HAVE_MSGPACK = False

__all__ = [
    "ProtocolError",
    "connect",
    "listen",
    "parse_address",
    "recv_msg",
    "send_msg",
]

PROTOCOL_VERSION = 1
_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 31  # 2 GiB: anything larger is a corrupt length prefix


class ProtocolError(RuntimeError):
    """Malformed frame or peer hangup mid-message."""


# --------------------------------------------------------------------------
# framing
# --------------------------------------------------------------------------


def _recvall(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ProtocolError(f"peer closed mid-frame ({got}/{n} bytes)")
        got += k
    return bytes(buf)


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = _LEN.unpack(_recvall(sock, 4))
    if n > MAX_FRAME:
        raise ProtocolError(f"frame length {n} exceeds {MAX_FRAME}")
    return _recvall(sock, n)


# --------------------------------------------------------------------------
# messages
# --------------------------------------------------------------------------


def send_msg(
    sock: socket.socket,
    header: Dict,
    arrays: Optional[Dict[str, np.ndarray]] = None,
) -> None:
    """Send one message: header dict + named raw-bytes array payloads."""
    arrays = arrays or {}
    manifest = []
    blobs = []
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        manifest.append([name, arr.dtype.str, list(arr.shape)])
        # zero-copy send: the length prefix goes out separately and the
        # array's own buffer feeds sendall directly (no tobytes() copy)
        blobs.append(memoryview(arr).cast("B"))
    header = dict(header, _arrays=manifest)
    if _HAVE_MSGPACK:
        head = b"M" + msgpack.packb(header, use_bin_type=True)
    else:
        head = b"J" + json.dumps(header).encode("utf-8")
    _send_frame(sock, head)
    for blob in blobs:
        sock.sendall(_LEN.pack(blob.nbytes))
        sock.sendall(blob)


def recv_msg(
    sock: socket.socket,
) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Receive one message: (header dict, {name: np.ndarray})."""
    head = _recv_frame(sock)
    if not head:
        raise ProtocolError("empty header frame")
    codec, body = head[:1], head[1:]
    if codec == b"M":
        if not _HAVE_MSGPACK:  # pragma: no cover - mixed-install edge
            raise ProtocolError("peer sent msgpack but msgpack is missing")
        header = msgpack.unpackb(body, raw=False)
    elif codec == b"J":
        header = json.loads(body.decode("utf-8"))
    else:
        raise ProtocolError(f"unknown header codec {codec!r}")
    arrays: Dict[str, np.ndarray] = {}
    for name, dtype, shape in header.pop("_arrays", []):
        blob = _recv_frame(sock)
        arrays[name] = np.frombuffer(blob, dtype=np.dtype(dtype)).reshape(
            tuple(shape)
        )
    return header, arrays


# --------------------------------------------------------------------------
# addresses
# --------------------------------------------------------------------------


def parse_address(address: str) -> Tuple[str, object]:
    """``tcp:HOST:PORT`` -> ("tcp", (host, port)); ``unix:PATH`` ->
    ("unix", path)."""
    kind, _, rest = address.partition(":")
    if kind == "unix" and rest:
        return "unix", rest
    if kind == "tcp" and rest:
        host, _, port = rest.rpartition(":")
        if host and port.isdigit():
            return "tcp", (host, int(port))
    raise ValueError(
        f"bad address {address!r}; expected tcp:HOST:PORT or unix:/path"
    )


def listen(address: str, backlog: int = 64) -> Tuple[socket.socket, str]:
    """Bind + listen; returns (socket, resolved address string)."""
    kind, where = parse_address(address)
    if kind == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.bind(where)
        sock.listen(backlog)
        return sock, address
    host, port = where
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((host, port))
    sock.listen(backlog)
    host, port = sock.getsockname()[:2]
    return sock, f"tcp:{host}:{port}"


def connect(address: str, timeout: Optional[float] = None) -> socket.socket:
    kind, where = parse_address(address)
    if kind == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout)
        sock.connect(where)
    else:
        sock = socket.create_connection(where, timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return sock
