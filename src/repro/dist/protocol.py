"""Framed RPC wire protocol for the worker pool.

A *message* is one header frame followed by zero or more binary array
frames; every frame is a 4-byte big-endian length prefix + payload.  The
header is a small dict serialized with msgpack when available (JSON
otherwise — the first payload byte tags the codec, so mixed installs still
interoperate) and carries an ``_arrays`` manifest describing the binary
frames that follow.

Array payload codecs.  Shares of GR(p^e, D) are planar uint32 coefficient
tensors whose elements rarely use the carrier's full bit-width — a
Z_{2^16} share wastes half of every 32-bit limb, and masked/padded slots
are all-zero.  Each array frame therefore carries a per-array codec:

- ``"raw"``      — verbatim C-order bytes (v0 wire format; manifest entry
  is the 3-element ``[name, dtype, shape]`` so v0 peers interoperate);
- ``"pack"``     — bit-packed to the array's true bit-width ``w``
  (``w = max(x).bit_length()``; ``w=0`` ships zero payload bytes), an
  8x-or-better win whenever the ring's modulus is below the carrier;
- ``"pack+zlib"``/``"pack+zstd"`` — bit-packing followed by a general
  compressor for the residual structure (zstd only when the optional
  ``zstandard`` module is installed — never a hard dependency).

Coded entries extend the manifest to ``[name, dtype, shape, codec, width,
raw_nbytes]``; the receive side dispatches on entry length, so either
peer may be older.  The codec each connection uses is negotiated in the
capability handshake (see :func:`negotiate`): a v0 worker that advertises
nothing gets ``"raw"`` frames and never sees a packed byte.

Addresses are strings: ``tcp:HOST:PORT`` or ``unix:/path/to.sock`` (the
latter preferred for local pools — no TCP stack, no port collisions).
``tcp:HOST:0`` binds an ephemeral port; ``listen`` returns the resolved
address so workers can be pointed at it.
"""
from __future__ import annotations

import json
import socket
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

try:  # msgpack is the preferred header codec; JSON is the stdlib fallback
    import msgpack  # type: ignore

    _HAVE_MSGPACK = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    _HAVE_MSGPACK = False

try:  # optional: zstd beats zlib on ratio and speed when present
    import zstandard  # type: ignore

    _HAVE_ZSTD = True
except ImportError:  # this container has no zstandard wheel; zlib covers it
    _HAVE_ZSTD = False

__all__ = [
    "Channel",
    "ProtocolError",
    "connect",
    "decode_array",
    "encode_array",
    "listen",
    "negotiate",
    "pack_bits",
    "parse_address",
    "recv_msg",
    "send_msg",
    "supported_codecs",
    "unpack_bits",
]

PROTOCOL_VERSION = 2  # v2 adds codec negotiation + streamed chunk frames
_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 31  # 2 GiB: anything larger is a corrupt length prefix


class ProtocolError(RuntimeError):
    """Malformed frame or peer hangup mid-message."""


# --------------------------------------------------------------------------
# bit packing
# --------------------------------------------------------------------------

_UNSIGNED = {np.dtype(d) for d in ("u1", "u2", "u4", "u8")}


def pack_bits(arr: np.ndarray, width: Optional[int] = None) -> Tuple[bytes, int]:
    """Bit-pack an unsigned integer array to ``width`` bits per element.

    ``width=None`` measures the minimal width (``max(arr).bit_length()``);
    ``width=0`` (an all-zeros array) packs to zero bytes.  Returns
    ``(payload, width)``; round-trips through :func:`unpack_bits` for any
    width 0..64.
    """
    a = np.ascontiguousarray(arr)
    if a.dtype not in _UNSIGNED:
        raise TypeError(f"pack_bits needs an unsigned dtype, got {a.dtype}")
    if width is None:
        width = int(a.max()).bit_length() if a.size else 0
    if not 0 <= width <= 64:
        raise ValueError(f"width {width} outside 0..64")
    if width == 0:
        return b"", 0
    # little-endian bit plane: each element becomes 64 LSB-first bits, of
    # which the low `width` are kept — packbits re-packs them 8 per byte
    a64 = a.astype("<u8", copy=False).reshape(-1)
    bits = np.unpackbits(
        a64.view(np.uint8).reshape(-1, 8), axis=1, bitorder="little"
    )[:, :width]
    return np.packbits(bits.reshape(-1), bitorder="little").tobytes(), width


def unpack_bits(
    payload: bytes, width: int, dtype, shape: Tuple[int, ...]
) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    dtype = np.dtype(dtype)
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if width == 0:
        return np.zeros(shape, dtype=dtype)
    bits = np.unpackbits(
        np.frombuffer(payload, dtype=np.uint8), bitorder="little"
    )[: n * width].reshape(n, width)
    full = np.zeros((n, 64), dtype=np.uint8)
    full[:, :width] = bits
    a64 = np.packbits(full, axis=1, bitorder="little").view("<u8").reshape(n)
    return a64.astype(dtype).reshape(shape)


# --------------------------------------------------------------------------
# array codecs + negotiation
# --------------------------------------------------------------------------

# preference order for negotiation: strongest first
_CODEC_PREFERENCE = ("pack+zstd", "pack+zlib", "pack", "raw")


def supported_codecs() -> Tuple[str, ...]:
    """Codecs this process can decode, strongest first."""
    return tuple(
        c for c in _CODEC_PREFERENCE if c != "pack+zstd" or _HAVE_ZSTD
    )


def negotiate(peer_codecs: Optional[List[str]], prefer: str = "auto") -> str:
    """Pick the connection codec from the peer's advertised list.

    A v0 peer advertises nothing (``None``) and gets ``"raw"``.
    ``prefer`` pins a specific codec when both sides support it
    (``"auto"`` takes the strongest mutual codec).
    """
    theirs = set(peer_codecs or ("raw",))
    mutual = [c for c in supported_codecs() if c in theirs]
    if not mutual:
        return "raw"
    if prefer != "auto" and prefer in mutual:
        return prefer
    if prefer != "auto":
        return "raw"  # pinned codec unsupported by the peer: stay safe
    return mutual[0]


def encode_array(
    arr: np.ndarray, codec: str, level: int = 3
) -> Tuple[bytes, List]:
    """Encode one array for the wire; returns ``(payload, manifest_entry)``.

    Falls back to raw (with a 3-element v0 manifest entry) for dtypes the
    packer can't handle, so the codec layer is always safe to apply.
    """
    arr = np.ascontiguousarray(arr)
    raw_nbytes = arr.nbytes
    if codec == "raw" or arr.dtype not in _UNSIGNED:
        return memoryview(arr).cast("B"), [
            "", arr.dtype.str, list(arr.shape)
        ]
    payload, width = pack_bits(arr)
    used = "pack"
    if codec == "pack+zlib":
        z = zlib.compress(payload, level)
        if len(z) < len(payload):  # compressors can inflate tiny payloads
            payload, used = z, "pack+zlib"
    elif codec == "pack+zstd":
        if not _HAVE_ZSTD:  # pragma: no cover - env without zstandard
            raise ProtocolError("pack+zstd negotiated but zstandard missing")
        z = zstandard.ZstdCompressor(level=level).compress(payload)
        if len(z) < len(payload):
            payload, used = z, "pack+zstd"
    return payload, ["", arr.dtype.str, list(arr.shape), used, width,
                     raw_nbytes]


def decode_array(payload: bytes, entry: List) -> np.ndarray:
    """Decode one array frame from its manifest entry (v0 or coded)."""
    if len(entry) == 3:  # v0 raw entry: [name, dtype, shape]
        _, dtype, shape = entry
        return np.frombuffer(payload, dtype=np.dtype(dtype)).reshape(
            tuple(shape)
        )
    _, dtype, shape, codec, width, _raw = entry
    if codec == "pack+zlib":
        payload = zlib.decompress(payload)
    elif codec == "pack+zstd":
        if not _HAVE_ZSTD:  # pragma: no cover - mixed-install edge
            raise ProtocolError("peer sent pack+zstd but zstandard missing")
        payload = zstandard.ZstdDecompressor().decompress(payload)
    elif codec != "pack":
        raise ProtocolError(f"unknown array codec {codec!r}")
    return unpack_bits(payload, int(width), dtype, tuple(shape))


# --------------------------------------------------------------------------
# framing
# --------------------------------------------------------------------------


def _recvall(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ProtocolError(f"peer closed mid-frame ({got}/{n} bytes)")
        got += k
    return bytes(buf)


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = _LEN.unpack(_recvall(sock, 4))
    if n > MAX_FRAME:
        raise ProtocolError(f"frame length {n} exceeds {MAX_FRAME}")
    return _recvall(sock, n)


# --------------------------------------------------------------------------
# messages
# --------------------------------------------------------------------------


def send_msg(
    sock: socket.socket,
    header: Dict,
    arrays: Optional[Dict[str, np.ndarray]] = None,
    codec: str = "raw",
    level: int = 3,
) -> Tuple[int, int]:
    """Send one message: header dict + named array payloads.

    ``codec`` selects the array wire encoding (see module doc); the
    default ``"raw"`` emits the v0 frame layout byte for byte.  Returns
    ``(raw_bytes, wire_bytes)`` — the pre-codec array payload size and
    what actually hit the socket (framing included), for bandwidth
    accounting.
    """
    arrays = arrays or {}
    manifest = []
    blobs = []
    raw_total = 0
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        raw_total += arr.nbytes
        if codec == "raw":
            # zero-copy send: the array's own buffer feeds sendall
            # directly (no tobytes() copy) behind a v0 manifest entry
            manifest.append([name, arr.dtype.str, list(arr.shape)])
            blobs.append(memoryview(arr).cast("B"))
        else:
            payload, entry = encode_array(arr, codec, level)
            entry[0] = name
            manifest.append(entry)
            blobs.append(payload)
    header = dict(header, _arrays=manifest)
    if _HAVE_MSGPACK:
        head = b"M" + msgpack.packb(header, use_bin_type=True)
    else:
        head = b"J" + json.dumps(header).encode("utf-8")
    _send_frame(sock, head)
    wire_total = 4 + len(head)
    for blob in blobs:
        nbytes = blob.nbytes if isinstance(blob, memoryview) else len(blob)
        sock.sendall(_LEN.pack(nbytes))
        sock.sendall(blob)
        wire_total += 4 + nbytes
    return raw_total, wire_total


def _recv_msg_ex(
    sock: socket.socket,
) -> Tuple[Dict, Dict[str, np.ndarray], int, int]:
    """Receive one message; returns (header, arrays, raw_bytes, wire_bytes)."""
    head = _recv_frame(sock)
    if not head:
        raise ProtocolError("empty header frame")
    codec, body = head[:1], head[1:]
    if codec == b"M":
        if not _HAVE_MSGPACK:  # pragma: no cover - mixed-install edge
            raise ProtocolError("peer sent msgpack but msgpack is missing")
        header = msgpack.unpackb(body, raw=False)
    elif codec == b"J":
        header = json.loads(body.decode("utf-8"))
    else:
        raise ProtocolError(f"unknown header codec {codec!r}")
    arrays: Dict[str, np.ndarray] = {}
    raw_total = 0
    wire_total = 4 + len(head)
    for entry in header.pop("_arrays", []):
        blob = _recv_frame(sock)
        wire_total += 4 + len(blob)
        arr = decode_array(blob, entry)
        raw_total += arr.nbytes
        arrays[entry[0]] = arr
    return header, arrays, raw_total, wire_total


def recv_msg(
    sock: socket.socket,
) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Receive one message: (header dict, {name: np.ndarray})."""
    header, arrays, _, _ = _recv_msg_ex(sock)
    return header, arrays


class Channel:
    """A socket plus its negotiated codec and cumulative byte accounting.

    Every pool connection sends/receives through a Channel so raw
    (pre-codec) vs. on-wire bytes are counted in one place; the counters
    feed ``PoolStats`` and ``Master.stats()``.  Not thread-safe on its
    own — callers serialize sends (the pool wraps sends in a per-worker
    lock).
    """

    def __init__(self, sock: socket.socket, codec: str = "raw",
                 level: int = 3):
        self.sock = sock
        self.codec = codec
        self.level = level
        self.raw_out = 0
        self.wire_out = 0
        self.raw_in = 0
        self.wire_in = 0

    def send(self, header: Dict, arrays=None,
             codec: Optional[str] = None) -> Tuple[int, int]:
        raw, wire = send_msg(
            self.sock, header, arrays,
            codec=self.codec if codec is None else codec, level=self.level,
        )
        self.raw_out += raw
        self.wire_out += wire
        return raw, wire

    def recv(self) -> Tuple[Dict, Dict[str, np.ndarray], int, int]:
        header, arrays, raw, wire = _recv_msg_ex(self.sock)
        self.raw_in += raw
        self.wire_in += wire
        return header, arrays, raw, wire


# --------------------------------------------------------------------------
# addresses
# --------------------------------------------------------------------------


def parse_address(address) -> Tuple[str, object]:
    """``tcp:HOST:PORT`` -> ("tcp", (host, port)); ``unix:PATH`` ->
    ("unix", path).  ``Endpoint`` instances are accepted too."""
    address = str(address)  # Endpoint.__str__ is the canonical address
    kind, _, rest = address.partition(":")
    if kind == "unix" and rest:
        return "unix", rest
    if kind == "tcp" and rest:
        host, _, port = rest.rpartition(":")
        if host and port.isdigit():
            return "tcp", (host, int(port))
    raise ValueError(
        f"bad address {address!r}; expected tcp:HOST:PORT or unix:/path"
    )


def listen(address, backlog: int = 64) -> Tuple[socket.socket, str]:
    """Bind + listen; returns (socket, resolved address string)."""
    kind, where = parse_address(address)
    if kind == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.bind(where)
        sock.listen(backlog)
        return sock, str(address)
    host, port = where
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind((host, port))
    sock.listen(backlog)
    host, port = sock.getsockname()[:2]
    return sock, f"tcp:{host}:{port}"


def connect(address, timeout: Optional[float] = None) -> socket.socket:
    kind, where = parse_address(address)
    if kind == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout)
        sock.connect(where)
    else:
        sock = socket.create_connection(where, timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return sock
