"""Polynomial evaluation / interpolation over Galois rings.

Host-side (``s_``-prefixed, exact python ints) variants are used for
setup-time constants (RMFE matrices, fixed evaluation points).  The jnp
variants are jit-traceable and are used for *runtime-dependent* point sets —
decoding from whichever R workers responded first.

TPU adaptation note: encode/decode are expressed as (block) matmuls with
Vandermonde / Lagrange-coefficient matrices rather than the O(N log^2 N)
subproduct-tree algorithms of [vzGathen&Gerhard]; for N <= 512 and matrix
blocks >> N this is strictly MXU-friendlier (see DESIGN.md §3.2).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax, vmap

from .galois import Ring

# ---------------------------------------------------------------------------
# host-side exact versions
# ---------------------------------------------------------------------------


def s_vandermonde(ring: Ring, points: np.ndarray, K: int) -> np.ndarray:
    """V[i, k] = points[i]^k for k < K. Shape (n, K, D), object dtype."""
    n = points.shape[0]
    V = np.zeros((n, K, ring.D), dtype=object)
    for i in range(n):
        acc = ring.s_one()
        for k in range(K):
            V[i, k] = acc
            if k + 1 < K:
                acc = ring.s_mul(acc, points[i].astype(object))
    return V


def s_lagrange_coeff_matrix(ring: Ring, points: np.ndarray) -> np.ndarray:
    """M[k, i] = k-th coefficient of the i-th Lagrange basis polynomial.

    For values y_i at ``points``, the interpolating polynomial of degree < n
    has coefficients  c_k = sum_i M[k, i] * y_i.  Shape (n, n, D), object.
    """
    n = points.shape[0]
    pts = [points[i].astype(object) for i in range(n)]
    # full = prod (x - x_j): coefficients full[0..n], monic
    full = np.zeros((n + 1, ring.D), dtype=object)
    full[0] = ring.s_one()
    deg = 0
    for j in range(n):
        # multiply by (x - x_j)
        new = np.zeros_like(full)
        for k in range(deg, -1, -1):
            new[k + 1] = ring.s_add(new[k + 1], full[k])
            new[k] = ring.s_sub(new[k], ring.s_mul(full[k], pts[j]))
        full = new
        deg += 1
    M = np.zeros((n, n, ring.D), dtype=object)
    for i in range(n):
        # synthetic division: num_i = full / (x - x_i), degree n-1
        b = np.zeros((n, ring.D), dtype=object)
        b[n - 1] = full[n]
        for k in range(n - 1, 0, -1):
            b[k - 1] = ring.s_add(full[k], ring.s_mul(pts[i], b[k]))
        # lambda_i = 1 / num_i(x_i)
        val = ring.s_zero()
        for k in range(n - 1, -1, -1):
            val = ring.s_add(ring.s_mul(val, pts[i]), b[k])
        lam = ring.s_inv(val)
        for k in range(n):
            M[k, i] = ring.s_mul(lam, b[k])
    return M


def as_u32(obj_arr: np.ndarray) -> np.ndarray:
    return np.vectorize(int, otypes=[np.uint64])(obj_arr).astype(np.uint32)


# ---------------------------------------------------------------------------
# jnp traceable versions (runtime point sets)
# ---------------------------------------------------------------------------


def vandermonde(ring: Ring, points: jnp.ndarray, K: int) -> jnp.ndarray:
    """V[i, k] = points[i]^k, shape (n, K, D); traceable scan over K."""
    n = points.shape[0]
    one = ring.ones((n,))

    def step(acc, _):
        nxt = ring.mul(acc, points)
        return nxt, acc

    _, cols = lax.scan(step, one, None, length=K)
    return jnp.moveaxis(cols, 0, 1)  # (n, K, D)


def eval_poly_horner(ring: Ring, coeffs: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Evaluate sum_k coeffs[k] x^k; coeffs (K, ..., D), x (D,) -> (..., D)."""
    K = coeffs.shape[0]

    def step(acc, c):
        return ring.add(ring.mul(acc, x), c), None

    init = jnp.zeros_like(coeffs[0])
    out, _ = lax.scan(step, init, coeffs[::-1])
    return out


def lagrange_coeff_matrix(ring: Ring, points: jnp.ndarray) -> jnp.ndarray:
    """Traceable M[k, i]: coefficients of Lagrange basis polys. (n, n, D).

    ``points`` (n, D) may be a runtime value (gathered from responsive
    workers); all pairwise differences must be units.
    """
    n = points.shape[0]
    D = ring.D

    # full product prod (x - x_j) via scan; buffer (n+1, D)
    def mul_linear(poly, xj):
        # poly * (x - xj): c'_k = c_{k-1} - xj c_k
        shifted = jnp.roll(poly, 1, axis=0).at[0].set(0)
        return ring.sub(shifted, ring.mul(poly, xj[None, :])), None

    init = jnp.zeros((n + 1, D), dtype=ring.dtype).at[0, 0].set(1)
    full, _ = lax.scan(mul_linear, init, points)

    def basis_for(xi):
        # synthetic division by (x - xi): b[n-1] = full[n]; b[k-1] = full[k] + xi b[k]
        def div_step(bk, fk):
            bkm1 = ring.add(fk, ring.mul(xi, bk))
            return bkm1, bk

        # iterate over full[n-1] .. full[1]; step emits the incoming carry b[k]
        # so outputs are b[n-1], ..., b[1] and the final carry is b[0]
        b_last = full[n]
        carry, bs = lax.scan(div_step, b_last, full[1:n][::-1])
        b = jnp.concatenate([carry[None], bs[::-1]], axis=0)  # b[0..n-1]
        # evaluate num_i at xi (Horner over b)
        def hstep(acc, c):
            return ring.add(ring.mul(acc, xi), c), None

        val, _ = lax.scan(hstep, jnp.zeros((D,), ring.dtype), b[::-1])
        lam = ring.inv(val)
        return ring.mul(lam[None, :], b)  # (n, D) coefficients of ell_i

    basis = vmap(basis_for)(points)  # (n_i, n_k, D)
    return jnp.moveaxis(basis, 0, 1)  # (k, i, D)


def interpolate_coeffs(
    ring: Ring, points: jnp.ndarray, values: jnp.ndarray
) -> jnp.ndarray:
    """Coefficients (n, ..., D) of the unique deg<n poly through the points.

    values: (n, ..., D).
    """
    M = lagrange_coeff_matrix(ring, points)  # (n, n, D)
    batch = values.shape[1:-1]
    flat = values.reshape(values.shape[0], -1, ring.D)
    out = ring.matmul(M, flat)
    return out.reshape((M.shape[0],) + batch + (ring.D,))
