"""Straggler modelling and responsive-worker selection (traceable).

The whole point of CDMM is that the master decodes from the FIRST R
responses.  In the SPMD emulation, worker liveness is a runtime boolean mask
(from fault injection, deadline simulation or real collective timeouts);
``select_workers`` turns it into a worker-index vector usable by the
traceable decoders (EPCode.decode / CSACode.decode take `idx` tracers).

For the elastic backend (``repro.cdmm.elastic``) liveness is richer than a
bool: workers join late, leave mid-batch, or run slow.  ``WorkerTrace``
captures one realization of that membership process — per-worker join time,
leave time and compute latency — and ``sample_trace`` draws randomized
traces from the same heavy-tailed latency model the benchmarks use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "select_workers",
    "simulate_stragglers",
    "straggler_latencies",
    "MembershipEvents",
    "WorkerTrace",
    "sample_trace",
]


def select_workers(mask: jnp.ndarray, R: int) -> jnp.ndarray:
    """First R responsive worker indices (stable order). mask: (N,) bool.

    Requires sum(mask) >= R for a valid decode; with fewer responders the
    trailing indices repeat dead workers and the caller must treat the
    result as failed (see `enough` flag from `simulate_stragglers`).
    """
    order = jnp.argsort(~mask, stable=True)
    return order[:R].astype(jnp.int32)


def simulate_stragglers(
    key: jax.Array, N: int, fail_prob: float, min_live: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random liveness mask; guarantees at least ``min_live`` workers live.

    Returns (mask (N,) bool, enough: () bool — whether the raw draw already
    had >= min_live responders before the guarantee kicked in).
    """
    raw = jax.random.uniform(key, (N,)) >= fail_prob
    enough = jnp.sum(raw) >= min_live
    # force the first min_live workers alive if the draw was too harsh —
    # models re-dispatch/retry in a real scheduler
    forced = jnp.where(jnp.arange(N) < min_live, True, raw)
    mask = jnp.where(enough, raw, forced)
    return mask, enough


def straggler_latencies(
    key: jax.Array, N: int, base_ms: float = 1.0, tail: float = 3.0
) -> jnp.ndarray:
    """Pareto-ish latency model: most workers ~base, a heavy tail of
    stragglers.  Used by benchmarks to compute time-to-R-th-response."""
    u = jax.random.uniform(key, (N,), minval=1e-6, maxval=1.0)
    return base_ms * (1.0 + tail * (u ** (-0.5) - 1.0))


@dataclass(frozen=True)
class WorkerTrace:
    """One realization of an elastic worker-membership process.

    Worker i joins at ``join_ms[i]``, leaves (forever) at ``leave_ms[i]``
    (+inf = never leaves), and — once joined — takes ``compute_ms[i]`` of
    wall-clock to produce its response.  A worker responds iff it finishes
    before leaving; its response lands at ``join + compute``.
    """

    join_ms: np.ndarray  # (N,) float
    leave_ms: np.ndarray  # (N,) float, +inf = stays for the whole batch
    compute_ms: np.ndarray  # (N,) float

    def __post_init__(self):
        n = len(self.join_ms)
        if not (len(self.leave_ms) == len(self.compute_ms) == n):
            raise ValueError("WorkerTrace arrays must share one length N")

    @property
    def N(self) -> int:
        return len(self.join_ms)

    def response_ms(self) -> np.ndarray:
        """(N,) virtual arrival time of each worker's response; +inf for
        workers that leave before finishing (they never respond)."""
        done = self.join_ms + self.compute_ms
        return np.where(done <= self.leave_ms, done, np.inf)

    def mask(self) -> np.ndarray:
        """(N,) bool liveness: workers whose response eventually lands."""
        return np.isfinite(self.response_ms())

    def restrict(self, mask) -> "WorkerTrace":
        """Trace with workers where ``mask`` is False forced dead (they
        leave before joining) — composes an external fault mask with the
        membership process."""
        mask = np.asarray(mask, dtype=bool)
        leave = np.where(mask, self.leave_ms, self.join_ms - 1.0)
        return WorkerTrace(self.join_ms, leave, self.compute_ms)

    def time_to_kth_response(self, k: int) -> float:
        """Virtual time at which the k-th response lands (inf if < k land)."""
        resp = np.sort(self.response_ms())
        return float(resp[k - 1]) if k <= self.N else float("inf")

    @staticmethod
    def all_live(N: int) -> "WorkerTrace":
        """Degenerate trace: everyone present from t=0, instant compute."""
        z = np.zeros(N)
        return WorkerTrace(z, np.full(N, np.inf), z)


class MembershipEvents:
    """Live join/leave/response bookkeeping that *produces* WorkerTraces.

    ``WorkerTrace`` is one frozen realization of a membership process; a
    running master (``repro.dist.master``) observes that process as events
    instead.  This accumulator records real wall-clock joins, leaves and
    response latencies per worker id and renders the history as a
    ``WorkerTrace`` on demand, so everything built on trace semantics
    (expected time-to-R, elastic-style stats, benchmark plots) applies
    unchanged to a real multi-process pool.  Thread-safe: the master's
    reader threads record concurrently.
    """

    def __init__(self):
        import threading

        self._lock = threading.Lock()
        self._t0 = None  # epoch of the first event; trace times are relative
        self._join: dict = {}
        self._leave: dict = {}
        self._last_response: dict = {}
        self._order: list = []  # worker ids in join order (stable slots)

    def _now_ms(self, t: float) -> float:
        if self._t0 is None:
            self._t0 = t
        return (t - self._t0) * 1e3

    def record_join(self, wid, t: float) -> None:
        with self._lock:
            if wid not in self._join:
                self._join[wid] = self._now_ms(t)
                self._order.append(wid)
            self._leave.pop(wid, None)  # re-join after a recorded leave

    def record_leave(self, wid, t: float) -> None:
        with self._lock:
            if wid in self._join and wid not in self._leave:
                self._leave[wid] = self._now_ms(t)

    def record_response(self, wid, compute_ms: float) -> None:
        with self._lock:
            if wid in self._join:
                self._last_response[wid] = float(compute_ms)

    def live(self) -> Tuple:
        """Worker ids currently joined and not left, in join order."""
        with self._lock:
            return tuple(w for w in self._order if w not in self._leave)

    def seen(self) -> Tuple:
        with self._lock:
            return tuple(self._order)

    def trace(self) -> WorkerTrace:
        """The observed history as a WorkerTrace over every worker seen.

        Workers still in the pool get ``leave_ms = +inf``; a worker that
        never responded gets ``compute_ms = +inf`` (it contributes no
        response, exactly like a leaver mid-compute).
        """
        with self._lock:
            join = np.array(
                [self._join[w] for w in self._order], dtype=float
            )
            leave = np.array(
                [self._leave.get(w, np.inf) for w in self._order],
                dtype=float,
            )
            compute = np.array(
                [self._last_response.get(w, np.inf) for w in self._order],
                dtype=float,
            )
        return WorkerTrace(join, leave, compute)


def sample_trace(
    key: jax.Array,
    N: int,
    *,
    base_ms: float = 1.0,
    tail: float = 3.0,
    join_spread_ms: float = 0.0,
    leave_prob: float = 0.0,
    slowdown_prob: float = 0.0,
    slowdown_factor: float = 10.0,
) -> WorkerTrace:
    """Randomized join/leave/slowdown trace over the benchmark latency model.

    Each worker draws a heavy-tailed compute latency; a ``slowdown_prob``
    fraction is further slowed by ``slowdown_factor`` (persistent straggler);
    joins are uniform in [0, join_spread_ms]; a ``leave_prob`` fraction
    leaves halfway through its compute and never responds.
    """
    k_lat, k_join, k_leave, k_slow = jax.random.split(key, 4)
    compute = np.asarray(straggler_latencies(k_lat, N, base_ms, tail), float)
    slow = np.asarray(jax.random.uniform(k_slow, (N,))) < slowdown_prob
    compute = np.where(slow, compute * slowdown_factor, compute)
    join = np.asarray(jax.random.uniform(k_join, (N,))) * join_spread_ms
    leaves = np.asarray(jax.random.uniform(k_leave, (N,))) < leave_prob
    leave = np.where(leaves, join + 0.5 * compute, np.inf)
    return WorkerTrace(join, leave, compute)
