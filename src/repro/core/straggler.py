"""Straggler modelling and responsive-worker selection (traceable).

The whole point of CDMM is that the master decodes from the FIRST R
responses.  In the SPMD emulation, worker liveness is a runtime boolean mask
(from fault injection, deadline simulation or real collective timeouts);
``select_workers`` turns it into a worker-index vector usable by the
traceable decoders (EPCode.decode / CSACode.decode take `idx` tracers).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["select_workers", "simulate_stragglers", "straggler_latencies"]


def select_workers(mask: jnp.ndarray, R: int) -> jnp.ndarray:
    """First R responsive worker indices (stable order). mask: (N,) bool.

    Requires sum(mask) >= R for a valid decode; with fewer responders the
    trailing indices repeat dead workers and the caller must treat the
    result as failed (see `enough` flag from `simulate_stragglers`).
    """
    order = jnp.argsort(~mask, stable=True)
    return order[:R].astype(jnp.int32)


def simulate_stragglers(
    key: jax.Array, N: int, fail_prob: float, min_live: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random liveness mask; guarantees at least ``min_live`` workers live.

    Returns (mask (N,) bool, enough: () bool — whether the raw draw already
    had >= min_live responders before the guarantee kicked in).
    """
    raw = jax.random.uniform(key, (N,)) >= fail_prob
    enough = jnp.sum(raw) >= min_live
    # force the first min_live workers alive if the draw was too harsh —
    # models re-dispatch/retry in a real scheduler
    forced = jnp.where(jnp.arange(N) < min_live, True, raw)
    mask = jnp.where(enough, raw, forced)
    return mask, enough


def straggler_latencies(
    key: jax.Array, N: int, base_ms: float = 1.0, tail: float = 3.0
) -> jnp.ndarray:
    """Pareto-ish latency model: most workers ~base, a heavy tail of
    stragglers.  Used by benchmarks to compute time-to-R-th-response."""
    u = jax.random.uniform(key, (N,), minval=1e-6, maxval=1.0)
    return base_ms * (1.0 + tail * (u ** (-0.5) - 1.0))
