"""CSA / GCSA baseline for batch DMM over a Galois ring (paper Table 1).

We implement the executable *CSA* instance of the GCSA family — the point
(u, v, w) = (1, 1, 1), kappa = n, which is the configuration GCSA uses for
its best communication costs (and the one Table 1 contrasts most sharply
with Batch-EP_RMFE: R_CSA = 2n-1 vs R_RMFE = uvw + w - 1).

Construction (Jia-Jafar CSA, ported to Galois rings with digit-lift
exceptional points so that all f_gamma - alpha_i differences are units):

    A~_i = Delta(a_i) * sum_g A_g / (f_g - a_i),   B~_i = sum_g B_g / (f_g - a_i)
    H_i  = A~_i B~_i = sum_g c_g A_g B_g / (f_g - a_i)  +  P(a_i),  deg P <= L-2
    c_g  = prod_{d != g} (f_d - f_g)       (a unit)

Any R = 2L-1 responses give a generalized Cauchy-Vandermonde system, solved
on device by unit-pivot Gauss-Jordan elimination (valid over a local ring:
an invertible matrix always has a unit pivot in every elimination column).

General (u, v, w, kappa) GCSA is provided as an *analytic* cost model with
the Table-1 formulas (`gcsa_cost_model`) — the paper's own comparison is
likewise analytic.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax import lax, vmap

from .ep_codes import EPCosts
from .galois import Ring
from .polyops import as_u32, s_vandermonde

__all__ = ["CSACode", "gcsa_cost_model", "gr_solve"]


def is_unit_mask(ring: Ring, x: jnp.ndarray) -> jnp.ndarray:
    """(…, D) -> (…,) bool: element is a unit iff some coeff != 0 mod p."""
    return jnp.any(x % jnp.uint32(ring.p) != 0, axis=-1)


def gr_solve(ring: Ring, M: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """Solve M X = Y over the ring; M (n, n, D) invertible, Y (n, b, D).

    Unit-pivot Gauss-Jordan, traceable (n is static, pivot row is dynamic).
    """
    n = M.shape[0]
    for k in range(n):
        col = M[:, k]  # (n, D)
        units = is_unit_mask(ring, col) & (jnp.arange(n) >= k)
        j = jnp.argmax(units)
        perm = jnp.arange(n)
        perm = perm.at[k].set(j).at[j].set(k)
        M = M[perm]
        Y = Y[perm]
        inv = ring.inv(M[k, k])
        Mk = ring.mul(inv[None, :], M[k])  # (n, D)
        Yk = ring.mul(inv[None, :], Y[k])  # (b, D)
        M = M.at[k].set(Mk)
        Y = Y.at[k].set(Yk)
        factors = M[:, k].at[k].set(0)  # (n, D)
        M = ring.sub(M, ring.mul(factors[:, None, :], Mk[None, :, :]))
        Y = ring.sub(Y, ring.mul(factors[:, None, :], Yk[None, :, :]))
    return Y


class CSACode:
    """Batch DMM of L products over ``ring`` with N workers, R = 2L-1."""

    def __init__(self, ring: Ring, L: int, N: int):
        self.ring = ring
        self.L, self.N = L, N
        self.R = 2 * L - 1
        if self.R > N:
            raise ValueError(f"R={self.R} > N={N}")
        if L + N > ring.p**ring.D:
            raise ValueError(
                f"need {L + N} exceptional points, |T| = {ring.p}^{ring.D}"
            )
        pts = ring.exceptional_points(L + N)
        fs, alphas = pts[:L], pts[L:]
        self.fs_np, self.alphas_np = fs, alphas

        # host precompute: cauchy terms, Delta(alpha), c_g
        cau = np.zeros((N, L, ring.D), dtype=object)  # 1/(f_g - a_i)
        delta = np.zeros((N, ring.D), dtype=object)
        for i in range(N):
            d = ring.s_one()
            for g in range(L):
                diff = ring.s_sub(fs[g].astype(object), alphas[i].astype(object))
                cau[i, g] = ring.s_inv(diff)
                d = ring.s_mul(d, diff)
            delta[i] = d
        cg = np.zeros((L, ring.D), dtype=object)
        for g in range(L):
            c = ring.s_one()
            for d_ in range(L):
                if d_ != g:
                    c = ring.s_mul(
                        c, ring.s_sub(fs[d_].astype(object), fs[g].astype(object))
                    )
            cg[g] = c
        self.cauchy = jnp.asarray(as_u32(cau))  # (N, L, D)
        self.enc_a = jnp.asarray(
            as_u32(
                np.array(
                    [[ring.s_mul(delta[i], cau[i, g]) for g in range(L)] for i in range(N)],
                    dtype=object,
                )
            )
        )  # (N, L, D): Delta(a_i)/(f_g - a_i)
        self.cg_inv = jnp.asarray(
            as_u32(np.array([ring.s_inv(cg[g]) for g in range(L)], dtype=object))
        )  # (L, D)
        V = s_vandermonde(ring, alphas, max(L - 1, 1))  # (N, L-1, D)
        self.vand = jnp.asarray(as_u32(V))
        self.points = jnp.asarray(alphas)

    # -- encode ---------------------------------------------------------------

    def encode_a(self, As: jnp.ndarray) -> jnp.ndarray:
        """As (L, t, r, D) -> (N, t, r, D)."""
        L, t, r, D = As.shape
        return self.ring.matmul(self.enc_a, As.reshape(L, t * r, D)).reshape(
            self.N, t, r, D
        )

    def encode_b(self, Bs: jnp.ndarray) -> jnp.ndarray:
        L, r, s, D = Bs.shape
        return self.ring.matmul(self.cauchy, Bs.reshape(L, r * s, D)).reshape(
            self.N, r, s, D
        )

    def encode_a_at(self, As: jnp.ndarray, i) -> jnp.ndarray:
        """Worker i's A~_i only (encode-at-worker; ``i`` may be a tracer)."""
        L, t, r, D = As.shape
        row = lax.dynamic_index_in_dim(self.enc_a, i, axis=0, keepdims=False)
        return self.ring.matmul(row[None], As.reshape(L, t * r, D))[0].reshape(
            t, r, D
        )

    def encode_b_at(self, Bs: jnp.ndarray, i) -> jnp.ndarray:
        L, r, s, D = Bs.shape
        row = lax.dynamic_index_in_dim(self.cauchy, i, axis=0, keepdims=False)
        return self.ring.matmul(row[None], Bs.reshape(L, r * s, D))[0].reshape(
            r, s, D
        )

    def worker_compute(self, FA, GB):
        return vmap(self.ring.matmul)(FA, GB)

    # -- decode -----------------------------------------------------------------

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        """H (R, t, s, D) from workers idx (R,) -> (L, t, s, D) products."""
        ring = self.ring
        R, t, s, D = H.shape
        assert R == self.R
        cau = jnp.take(self.cauchy, idx, axis=0)  # (R, L, D)
        van = jnp.take(self.vand, idx, axis=0)  # (R, L-1, D)
        M = jnp.concatenate([cau, van], axis=1)  # (R, R, D)
        X = gr_solve(ring, M, H.reshape(R, t * s, D))  # (R, t*s, D)
        U = X[: self.L].reshape(self.L, t, s, D)
        C = ring.mul(self.cg_inv[:, None, None, :], U)
        return C

    def run(self, As, Bs, idx: Optional[jnp.ndarray] = None):
        FA, GB = self.encode_a(As), self.encode_b(Bs)
        H = self.worker_compute(FA, GB)
        if idx is None:
            idx = jnp.arange(self.R, dtype=jnp.int32)
        return self.decode(jnp.take(H, idx, axis=0), idx)

    def costs(self, spec, r: Optional[int] = None, s: Optional[int] = None,
              base: Optional[Ring] = None) -> EPCosts:
        """Analytic costs for a ProblemSpec (shared ``costs(spec)`` surface).

        The legacy positional form ``costs(t, r, s, base)`` still works but
        is deprecated.
        """
        if r is not None:
            warnings.warn(
                "CSACode.costs(t, r, s, base) is deprecated; pass a "
                "repro.cdmm.api.ProblemSpec instead",
                DeprecationWarning,
                stacklevel=2,
            )
            t = int(spec)
        else:
            t, r, s, base = spec.t, spec.r, spec.s, spec.ring
        return gcsa_cost_model(
            t, r, s, 1, 1, 1, self.L, self.L, self.N, self.ring.D / base.D
        )


def gcsa_cost_model(
    t: int, r: int, s: int, u: int, v: int, w: int,
    n: int, kappa: int, N: int, m_eff: float,
) -> EPCosts:
    """Table-1 GCSA costs, per product, in base-ring elements.

    R = uvw(n + kappa - 1) + w - 1;   upload x n/kappa;   worker x n/kappa.
    GCSA needs >= N + n exceptional points (vs N for Batch-EP_RMFE).
    """
    R = u * v * w * (n + kappa - 1) + w - 1
    tb, rb, sb = t // u, r // w, s // v
    up = (tb * rb + rb * sb) * (n / kappa) * N * m_eff
    down = R * tb * sb * m_eff / n
    enc = (tb * rb * u * w + rb * sb * w * v) * (n / kappa) * N * m_eff**2
    dec = R * R * tb * sb * m_eff**2 / n
    worker = tb * rb * sb * (n / kappa) * m_eff**2
    return EPCosts(N, R, m_eff, up, down, enc, dec, worker)
