"""CSA / GCSA batch codes for batch DMM over a Galois ring (paper Table 1).

Two executable members of the GCSA family:

* :class:`CSACode` — the (u, v, w) = (1, 1, 1), kappa = n point (the
  configuration GCSA uses for its best communication costs, and the one
  Table 1 contrasts most sharply with Batch-EP_RMFE: R_CSA = 2n-1 vs
  R_RMFE = uvw + w - 1).

* :class:`GCSACode` — the general (u, v, w, kappa) construction:
  Entangled-Polynomial inner partitioning (t/u x r/w and r/w x s/v
  blocks) composed with the CSA outer Cauchy structure over
  kappa-grouped batches, R = uvw(n + kappa - 1) + w - 1.

Construction (Jia-Jafar GCSA, ported to Galois rings with digit-lift
exceptional points so all beta_g - alpha_i differences are units).  The
n products are grouped into ell = n/kappa groups of kappa; with
x_g = beta_g - alpha_i and Delta_l = prod_{g in group l} x_g, worker i
receives per group l the EP-in-Cauchy evaluations

    A~_{l,i} = Delta_l^{uvw} sum_{g in l} sum_{e in Ef} A_g^(e) x_g^{e-uvw}
    B~_{l,i} =               sum_{g in l} sum_{e in Eg} B_g^(e) x_g^{e-uvw}

shipped as ONE pair of block-concatenated shares

    fa_i = [A~_{0,i} | ... | A~_{ell-1,i}]   (t/u, ell*r/w)
    gb_i = [B~_{0,i} ; ... ; B~_{ell-1,i}]   (ell*r/w, s/v)

so a worker's single plain ring matmul H_i = fa_i @ gb_i computes
sum_l A~_{l,i} B~_{l,i} — the same worker surface as every other scheme
(kernel substitution, contraction-axis streaming and at-worker encode
all apply unchanged).  Every EP exponent satisfies e <= uvw - 1, so H_i
decomposes into per-product pole terms of order 1..uvw at each beta_g
plus a polynomial of interference terms of degree
<= (kappa - 1) uvw + w - 2: any R responses form a generalized
Cauchy-Vandermonde system, solved on device by unit-pivot Gauss-Jordan
elimination (:func:`gr_solve`).  The recovered pole coefficients at
beta_g are a lower-triangular Toeplitz transform — with unit diagonal
prod_{g' != g} (beta_{g'} - beta_g)^{uvw} — of product g's EP
convolution coefficients; a precomputed truncated power-series inverse
undoes it, and the useful coefficients assemble C_g exactly as in
``EPCode.decode``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax, vmap

from repro import settings

from .ep_codes import EPCosts
from .galois import Ring
from .polyops import as_u32, s_vandermonde

__all__ = ["CSACode", "GCSACode", "gcsa_cost_model", "gr_solve"]


def is_unit_mask(ring: Ring, x: jnp.ndarray) -> jnp.ndarray:
    """(…, D) -> (…,) bool: element is a unit iff some coeff != 0 mod p."""
    return jnp.any(x % jnp.uint32(ring.p) != 0, axis=-1)


def _raise_singular(ok) -> None:
    if not bool(ok):
        raise ValueError(
            "gr_solve: singular system detected at run time (some "
            "elimination column has no unit pivot)"
        )


def gr_solve(
    ring: Ring, M: jnp.ndarray, Y: jnp.ndarray, *, check: bool = True
) -> jnp.ndarray:
    """Solve M X = Y over the ring; M (n, n, D) invertible, Y (n, b, D).

    Unit-pivot Gauss-Jordan, traceable (n is static, pivot row is dynamic).

    ``check=True`` guards against silent garbage on singular systems: over
    a local ring M is invertible iff every elimination column holds a unit
    pivot, and ``jnp.argmax`` over the all-False unit mask of a singular
    column would silently select row 0 and "invert" a non-unit.  On eager
    (non-traced) calls the pivot masks are concrete and a singular system
    raises ``ValueError`` host-side.  Under jit every mask is a tracer, so
    the check degrades to an accumulated flag, raised from a
    ``jax.debug.callback`` at run time under ``REPRO_DEBUG_SOLVE=1`` (off
    by default: the callback has per-call cost).  The jitted ``decode_op``
    seam is instead covered by the duplicate-live-set check in
    ``CSACode.decode`` / ``GCSACode.decode``, which inspects the concrete
    ``idx`` closure before tracing touches it.
    """
    n = M.shape[0]
    ok = None
    for k in range(n):
        col = M[:, k]  # (n, D)
        units = is_unit_mask(ring, col) & (jnp.arange(n) >= k)
        if check:
            has = jnp.any(units)
            if isinstance(has, jax.core.Tracer):
                ok = has if ok is None else ok & has
            elif not bool(has):
                raise ValueError(
                    f"gr_solve: singular system over {ring}: no unit pivot "
                    f"in elimination column {k} (matrix not invertible mod "
                    f"p — e.g. a decode live set indexing dependent "
                    f"responses)"
                )
        j = jnp.argmax(units)
        perm = jnp.arange(n)
        perm = perm.at[k].set(j).at[j].set(k)
        M = M[perm]
        Y = Y[perm]
        inv = ring.inv(M[k, k])
        Mk = ring.mul(inv[None, :], M[k])  # (n, D)
        Yk = ring.mul(inv[None, :], Y[k])  # (b, D)
        M = M.at[k].set(Mk)
        Y = Y.at[k].set(Yk)
        factors = M[:, k].at[k].set(0)  # (n, D)
        M = ring.sub(M, ring.mul(factors[:, None, :], Mk[None, :, :]))
        Y = ring.sub(Y, ring.mul(factors[:, None, :], Yk[None, :, :]))
    if ok is not None and settings.get_bool("debug_solve"):
        jax.debug.callback(_raise_singular, ok)
    return Y


def _check_live_set(idx) -> None:
    """Host-side decode guard: duplicate worker indices make the decode
    system singular (repeated Cauchy-Vandermonde rows).  ``idx`` is concrete
    even inside the jitted ``decode_op`` seam (the live set is closed over
    as a constant), so this raises before tracing hides the pivot masks;
    fully dynamic (traced) live sets fall through to ``gr_solve``'s
    ``REPRO_DEBUG_SOLVE`` run-time guard."""
    if isinstance(idx, jax.core.Tracer):
        return
    ii = np.asarray(idx).ravel()
    if np.unique(ii).shape[0] != ii.shape[0]:
        raise ValueError(
            "decode: singular live set — duplicate worker indices "
            f"{sorted(ii.tolist())} (repeated responses carry no new "
            "information; the decode system is not invertible)"
        )


class CSACode:
    """Batch DMM of L products over ``ring`` with N workers, R = 2L-1."""

    def __init__(self, ring: Ring, L: int, N: int):
        self.ring = ring
        self.L, self.N = L, N
        self.R = 2 * L - 1
        if self.R > N:
            raise ValueError(f"R={self.R} > N={N}")
        if L + N > ring.p**ring.D:
            raise ValueError(
                f"need {L + N} exceptional points, |T| = {ring.p}^{ring.D}"
            )
        pts = ring.exceptional_points(L + N)
        fs, alphas = pts[:L], pts[L:]
        self.fs_np, self.alphas_np = fs, alphas

        # host precompute: cauchy terms, Delta(alpha), c_g
        cau = np.zeros((N, L, ring.D), dtype=object)  # 1/(f_g - a_i)
        delta = np.zeros((N, ring.D), dtype=object)
        for i in range(N):
            d = ring.s_one()
            for g in range(L):
                diff = ring.s_sub(fs[g].astype(object), alphas[i].astype(object))
                cau[i, g] = ring.s_inv(diff)
                d = ring.s_mul(d, diff)
            delta[i] = d
        cg = np.zeros((L, ring.D), dtype=object)
        for g in range(L):
            c = ring.s_one()
            for d_ in range(L):
                if d_ != g:
                    c = ring.s_mul(
                        c, ring.s_sub(fs[d_].astype(object), fs[g].astype(object))
                    )
            cg[g] = c
        self.cauchy = jnp.asarray(as_u32(cau))  # (N, L, D)
        self.enc_a = jnp.asarray(
            as_u32(
                np.array(
                    [[ring.s_mul(delta[i], cau[i, g]) for g in range(L)] for i in range(N)],
                    dtype=object,
                )
            )
        )  # (N, L, D): Delta(a_i)/(f_g - a_i)
        self.cg_inv = jnp.asarray(
            as_u32(np.array([ring.s_inv(cg[g]) for g in range(L)], dtype=object))
        )  # (L, D)
        V = s_vandermonde(ring, alphas, max(L - 1, 1))  # (N, L-1, D)
        self.vand = jnp.asarray(as_u32(V))
        self.points = jnp.asarray(alphas)

    # -- encode ---------------------------------------------------------------

    def encode_a(self, As: jnp.ndarray) -> jnp.ndarray:
        """As (L, t, r, D) -> (N, t, r, D)."""
        L, t, r, D = As.shape
        return self.ring.matmul(self.enc_a, As.reshape(L, t * r, D)).reshape(
            self.N, t, r, D
        )

    def encode_b(self, Bs: jnp.ndarray) -> jnp.ndarray:
        L, r, s, D = Bs.shape
        return self.ring.matmul(self.cauchy, Bs.reshape(L, r * s, D)).reshape(
            self.N, r, s, D
        )

    def encode_a_at(self, As: jnp.ndarray, i) -> jnp.ndarray:
        """Worker i's A~_i only (encode-at-worker; ``i`` may be a tracer)."""
        L, t, r, D = As.shape
        row = lax.dynamic_index_in_dim(self.enc_a, i, axis=0, keepdims=False)
        return self.ring.matmul(row[None], As.reshape(L, t * r, D))[0].reshape(
            t, r, D
        )

    def encode_b_at(self, Bs: jnp.ndarray, i) -> jnp.ndarray:
        L, r, s, D = Bs.shape
        row = lax.dynamic_index_in_dim(self.cauchy, i, axis=0, keepdims=False)
        return self.ring.matmul(row[None], Bs.reshape(L, r * s, D))[0].reshape(
            r, s, D
        )

    def worker_compute(self, FA, GB):
        return vmap(self.ring.matmul)(FA, GB)

    # -- decode -----------------------------------------------------------------

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        """H (R, t, s, D) from workers idx (R,) -> (L, t, s, D) products.

        Guarded against silent garbage: duplicate live-set indices raise
        host-side whenever ``idx`` is concrete (including the jitted
        ``decode_op`` seam, whose live set is a static closure constant),
        and ``gr_solve`` raises on any singular system when called eagerly.
        """
        _check_live_set(idx)
        ring = self.ring
        R, t, s, D = H.shape
        assert R == self.R
        cau = jnp.take(self.cauchy, idx, axis=0)  # (R, L, D)
        van = jnp.take(self.vand, idx, axis=0)  # (R, L-1, D)
        M = jnp.concatenate([cau, van], axis=1)  # (R, R, D)
        X = gr_solve(ring, M, H.reshape(R, t * s, D))  # (R, t*s, D)
        U = X[: self.L].reshape(self.L, t, s, D)
        C = ring.mul(self.cg_inv[:, None, None, :], U)
        return C

    def run(self, As, Bs, idx: Optional[jnp.ndarray] = None):
        FA, GB = self.encode_a(As), self.encode_b(Bs)
        H = self.worker_compute(FA, GB)
        if idx is None:
            idx = jnp.arange(self.R, dtype=jnp.int32)
        return self.decode(jnp.take(H, idx, axis=0), idx)

    def costs(self, spec, r: Optional[int] = None, s: Optional[int] = None,
              base: Optional[Ring] = None) -> EPCosts:
        """Analytic costs for a ProblemSpec (shared ``costs(spec)`` surface).

        The legacy positional form ``costs(t, r, s, base)`` still works but
        is deprecated.
        """
        if r is not None:
            warnings.warn(
                "CSACode.costs(t, r, s, base) is deprecated; pass a "
                "repro.cdmm.api.ProblemSpec instead",
                DeprecationWarning,
                stacklevel=2,
            )
            t = int(spec)
        else:
            t, r, s, base = spec.t, spec.r, spec.s, spec.ring
        return gcsa_cost_model(
            t, r, s, 1, 1, 1, self.L, self.L, self.N, self.ring.D / base.D
        )


def _trunc_pow_prod(ring: Ring, cs, e: int, K: int):
    """First K coefficients (in x) of prod_c (c + x)^e, object arithmetic."""
    poly = [ring.s_one()] + [ring.s_zero() for _ in range(K - 1)]
    for c in cs:
        for _ in range(e):
            nxt = []
            for j in range(K):
                term = ring.s_mul(poly[j], c)
                if j:
                    term = ring.s_add(term, poly[j - 1])
                nxt.append(term)
            poly = nxt
    return poly


def _series_inv(ring: Ring, rho, K: int):
    """sigma with sigma * rho = 1 mod x^K (rho[0] must be a unit)."""
    sigma = [ring.s_inv(rho[0])]
    for j in range(1, K):
        acc = ring.s_zero()
        for i in range(1, j + 1):
            acc = ring.s_add(acc, ring.s_mul(rho[i], sigma[j - i]))
        sigma.append(ring.s_sub(ring.s_zero(), ring.s_mul(sigma[0], acc)))
    return sigma


class GCSACode:
    """General-(u, v, w, kappa) GCSA: batch DMM of L products over ``ring``
    with N workers, R = uvw(L + kappa - 1) + w - 1 (see module docstring).

    ``kappa`` must divide L; ``kappa = L`` with u = v = w = 1 is the
    :class:`CSACode` point (bit-identical shares and decode), ``kappa = 1``
    is the per-product-poles end of the family (R = uvw L + w - 1), and
    L = 1 degenerates to a single EP execution (R = uvw + w - 1).
    Shapes are taken at encode time, so one instance serves any (t, r, s)
    divisible by the partition.
    """

    def __init__(
        self, ring: Ring, L: int, N: int, u: int = 1, v: int = 1,
        w: int = 1, kappa: Optional[int] = None,
    ):
        kappa = L if kappa is None else kappa
        if min(u, v, w, kappa) < 1:
            raise ValueError(
                f"partition (u={u}, v={v}, w={w}, kappa={kappa}) must be >= 1"
            )
        if L % kappa:
            raise ValueError(f"kappa={kappa} must divide the batch L={L}")
        self.ring = ring
        self.L, self.N = L, N
        self.u, self.v, self.w, self.kappa = u, v, w, kappa
        self.nl = L // kappa  # number of kappa-groups ("ell")
        uvw = u * v * w
        self.uvw = uvw
        self.R = uvw * (L + kappa - 1) + w - 1
        if self.R > N:
            raise ValueError(f"R={self.R} > N={N}")
        if L + N > ring.p**ring.D:
            raise ValueError(
                f"need {L + N} exceptional points, |T| = {ring.p}^{ring.D}"
            )
        pts = ring.exceptional_points(L + N)
        betas, alphas = pts[:L], pts[L:]
        self.betas_np, self.alphas_np = betas, alphas
        self.points = jnp.asarray(alphas)

        # EP exponent layout (same zero-based layout as EPCode)
        exp_f = [i * w + j for i in range(u) for j in range(w)]
        exp_g = [(w - 1 - k) + l * u * w for k in range(w) for l in range(v)]
        self.exp_c = np.array(
            [[i * w + (w - 1) + l * u * w for l in range(v)] for i in range(u)]
        )  # (u, v): exponents carrying the useful blocks, all <= uvw - 1

        # host precompute (exact object-int arithmetic): per-group encode
        # coefficient tensors + the pole half of the decode basis.  Column
        # order within a group is (k, EP-block) — matching the grouped
        # reshape of the split operand blocks in encode_*.
        Ea = np.zeros((N, self.nl, kappa * u * w, ring.D), dtype=object)
        Eb = np.zeros((N, self.nl, kappa * w * v, ring.D), dtype=object)
        pole = np.zeros((N, L * uvw, ring.D), dtype=object)
        for i in range(N):
            a_i = alphas[i].astype(object)
            for l in range(self.nl):
                xs = [
                    ring.s_sub(betas[l * kappa + k].astype(object), a_i)
                    for k in range(kappa)
                ]
                delta = ring.s_one()
                for x in xs:
                    delta = ring.s_mul(delta, x)
                dpow = ring.s_pow(delta, uvw)  # Delta_l^{uvw}
                for k in range(kappa):
                    g = l * kappa + k
                    xinv = ring.s_inv(xs[k])
                    xp = [None, xinv]  # xp[m] = (beta_g - alpha_i)^{-m}
                    for _ in range(uvw - 1):
                        xp.append(ring.s_mul(xp[-1], xinv))
                    for m in range(1, uvw + 1):
                        pole[i, g * uvw + (m - 1)] = xp[m]
                    # x^{e - uvw} = xinv^{uvw - e}; every EP exponent is
                    # <= uvw - 1, so the shifted power stays negative
                    for a, e in enumerate(exp_f):
                        Ea[i, l, k * u * w + a] = ring.s_mul(dpow, xp[uvw - e])
                    for b, e in enumerate(exp_g):
                        Eb[i, l, k * w * v + b] = xp[uvw - e]
        self.Ea = jnp.asarray(as_u32(Ea))  # (N, nl, kappa*u*w, D)
        self.Eb = jnp.asarray(as_u32(Eb))  # (N, nl, kappa*w*v, D)

        # decode basis: per product g the pole columns x_g^{-m} (m=1..uvw),
        # then a Vandermonde block absorbing the polynomial interference of
        # degree <= (kappa-1)uvw + w - 2 (absent when that is negative)
        polyK = (kappa - 1) * uvw + w - 1
        if polyK > 0:
            V = s_vandermonde(ring, alphas, polyK)  # (N, polyK, D)
            M = np.concatenate([pole, V], axis=1)
        else:
            M = pole
        assert M.shape[1] == self.R, (M.shape, self.R)
        self.M = jnp.asarray(as_u32(M))  # (N, R, D)

        # per-product Toeplitz recovery: the solved pole coefficients
        # Gamma'_{g,e} (e = uvw - pole order) relate to product g's EP
        # convolution coefficients h_d by Gamma'_e = sum_d rho_{e-d} h_d,
        # rho = coefficients of prod_{g' != g, same group}
        # ((beta_{g'} - beta_g) + x)^{uvw} — lower-triangular Toeplitz with
        # unit diagonal rho_0.  T[g] holds the truncated power-series
        # inverse sigma as T[d, e] = sigma_{d-e}, so h = T @ Gamma'.
        T = np.zeros((L, uvw, uvw, ring.D), dtype=object)
        for g in range(L):
            l, k = divmod(g, kappa)
            cs = [
                ring.s_sub(
                    betas[l * kappa + k2].astype(object), betas[g].astype(object)
                )
                for k2 in range(kappa)
                if k2 != k
            ]
            rho = _trunc_pow_prod(ring, cs, uvw, uvw)
            sigma = _series_inv(ring, rho, uvw)
            for d in range(uvw):
                for e in range(d + 1):
                    T[g, d, e] = sigma[d - e]
        self.Tinv = jnp.asarray(as_u32(T))  # (L, uvw, uvw, D)

    # -- partitioning ---------------------------------------------------------

    def _split_a(self, As: jnp.ndarray) -> jnp.ndarray:
        """(L, t, r, D) -> (L, uw, t/u, r/w, D), ordered to match exp_f."""
        L, t, r, D = As.shape
        u, w = self.u, self.w
        if L != self.L or t % u or r % w:
            raise ValueError(
                f"As {As.shape} not partitionable by (L={self.L}, u={u}, w={w})"
            )
        blocks = As.reshape(L, u, t // u, w, r // w, D)
        return blocks.transpose(0, 1, 3, 2, 4, 5).reshape(
            L, u * w, t // u, r // w, D
        )

    def _split_b(self, Bs: jnp.ndarray) -> jnp.ndarray:
        """(L, r, s, D) -> (L, wv, r/w, s/v, D), ordered to match exp_g."""
        L, r, s, D = Bs.shape
        w, v = self.w, self.v
        if L != self.L or r % w or s % v:
            raise ValueError(
                f"Bs {Bs.shape} not partitionable by (L={self.L}, w={w}, v={v})"
            )
        blocks = Bs.reshape(L, w, r // w, v, s // v, D)
        return blocks.transpose(0, 1, 3, 2, 4, 5).reshape(
            L, w * v, r // w, s // v, D
        )

    # -- encode ---------------------------------------------------------------

    def encode_a(self, As: jnp.ndarray) -> jnp.ndarray:
        """As (L, t, r, D) -> block-concat shares (N, t/u, nl * r/w, D)."""
        blocks = self._split_a(As)  # (L, uw, tb, rb, D)
        L, K, tb, rb, D = blocks.shape
        grp = blocks.reshape(self.nl, self.kappa * K, tb * rb, D)
        out = vmap(self.ring.matmul, in_axes=(1, 0))(self.Ea, grp)
        out = out.reshape(self.nl, self.N, tb, rb, D)
        return out.transpose(1, 2, 0, 3, 4).reshape(
            self.N, tb, self.nl * rb, D
        )

    def encode_b(self, Bs: jnp.ndarray) -> jnp.ndarray:
        """Bs (L, r, s, D) -> block-concat shares (N, nl * r/w, s/v, D)."""
        blocks = self._split_b(Bs)  # (L, wv, rb, sb, D)
        L, K, rb, sb, D = blocks.shape
        grp = blocks.reshape(self.nl, self.kappa * K, rb * sb, D)
        out = vmap(self.ring.matmul, in_axes=(1, 0))(self.Eb, grp)
        out = out.reshape(self.nl, self.N, rb, sb, D)
        return out.transpose(1, 0, 2, 3, 4).reshape(
            self.N, self.nl * rb, sb, D
        )

    def encode_a_at(self, As: jnp.ndarray, i) -> jnp.ndarray:
        """Worker i's fa_i only (``i`` may be a tracer)."""
        blocks = self._split_a(As)
        L, K, tb, rb, D = blocks.shape
        grp = blocks.reshape(self.nl, self.kappa * K, tb * rb, D)
        row = lax.dynamic_index_in_dim(self.Ea, i, axis=0, keepdims=False)
        out = vmap(lambda e, g: self.ring.matmul(e[None], g)[0])(row, grp)
        out = out.reshape(self.nl, tb, rb, D)
        return out.transpose(1, 0, 2, 3).reshape(tb, self.nl * rb, D)

    def encode_b_at(self, Bs: jnp.ndarray, i) -> jnp.ndarray:
        blocks = self._split_b(Bs)
        L, K, rb, sb, D = blocks.shape
        grp = blocks.reshape(self.nl, self.kappa * K, rb * sb, D)
        row = lax.dynamic_index_in_dim(self.Eb, i, axis=0, keepdims=False)
        out = vmap(lambda e, g: self.ring.matmul(e[None], g)[0])(row, grp)
        return out.reshape(self.nl * rb, sb, D)

    # -- worker ---------------------------------------------------------------

    def worker_compute(self, FA, GB):
        """(N, tb, nl*rb, D) x (N, nl*rb, sb, D) -> (N, tb, sb, D)."""
        return vmap(self.ring.matmul)(FA, GB)

    # -- decode ---------------------------------------------------------------

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        """H (R, t/u, s/v, D) from workers idx (R,) -> (L, t, s, D).

        Guarded like :meth:`CSACode.decode`: duplicate live-set indices
        raise host-side whenever ``idx`` is concrete, and ``gr_solve``
        raises on any singular system when called eagerly.
        """
        _check_live_set(idx)
        ring = self.ring
        R, tb, sb, D = H.shape
        assert R == self.R, (R, self.R)
        M = jnp.take(self.M, idx, axis=0)  # (R, R, D)
        X = gr_solve(ring, M, H.reshape(R, tb * sb, D))  # (R, tb*sb, D)
        P = X[: self.L * self.uvw].reshape(self.L, self.uvw, tb * sb, D)
        # P[g, m-1] is the coefficient of (beta_g - alpha)^{-m}; flipping m
        # gives Gamma'[g, e] (e = uvw - m), the Toeplitz image of the EP
        # convolution coefficients h — undone by the precomputed inverse
        h = vmap(ring.matmul)(self.Tinv, jnp.flip(P, axis=1))
        h = h.reshape(self.L, self.uvw, tb, sb, D)
        cb = jnp.take(h, jnp.asarray(self.exp_c.ravel()), axis=1)
        cb = cb.reshape(self.L, self.u, self.v, tb, sb, D)
        return cb.transpose(0, 1, 3, 2, 4, 5).reshape(
            self.L, self.u * tb, self.v * sb, D
        )

    # -- end to end -----------------------------------------------------------

    def run(self, As, Bs, idx: Optional[jnp.ndarray] = None):
        FA, GB = self.encode_a(As), self.encode_b(Bs)
        H = self.worker_compute(FA, GB)
        if idx is None:
            idx = jnp.arange(self.R, dtype=jnp.int32)
        return self.decode(jnp.take(H, idx, axis=0), idx)

    def costs(self, spec) -> EPCosts:
        return gcsa_cost_model(
            spec.t, spec.r, spec.s, self.u, self.v, self.w, self.L,
            self.kappa, self.N, self.ring.D / spec.ring.D,
        )


def gcsa_cost_model(
    t: int, r: int, s: int, u: int, v: int, w: int,
    n: int, kappa: int, N: int, m_eff: float,
) -> EPCosts:
    """Table-1 GCSA costs, per product, in base-ring elements.

    R = uvw(n + kappa - 1) + w - 1 with the batch grouped into
    ell = n/kappa groups of kappa.  Each worker holds ONE pair of
    block-concatenated shares fa (t/u, ell*r/w) and gb (ell*r/w, s/v) —
    see :class:`GCSACode` — so, per product (divide totals by n and use
    ell/n = 1/kappa):

      upload   N * (tb*rb + rb*sb) * m_eff / kappa
      download R * tb*sb * m_eff / n
      encode   N * (uw*tb*rb + wv*rb*sb) * m_eff^2      (kappa*ell = n)
      decode   R^2 * tb*sb * m_eff^2 / n                (one gr_solve)
      worker   tb*rb*sb * m_eff^2 / kappa

    (The pre-audit formulas scaled upload/encode/worker by n/kappa instead
    — double-counting the batch: at the kappa = n CSA point they priced
    the whole batch's upload per *product*.  Pinned against the
    executable code's true share shapes in tests/test_codes.py.)

    GCSA needs >= N + n exceptional points (vs N for Batch-EP_RMFE).
    """
    if n % kappa:
        raise ValueError(f"kappa={kappa} must divide the batch n={n}")
    R = u * v * w * (n + kappa - 1) + w - 1
    tb, rb, sb = t // u, r // w, s // v
    up = N * (tb * rb + rb * sb) * m_eff / kappa
    down = R * tb * sb * m_eff / n
    enc = N * (tb * rb * u * w + rb * sb * w * v) * m_eff**2
    dec = R * R * tb * sb * m_eff**2 / n
    worker = tb * rb * sb * m_eff**2 / kappa
    return EPCosts(N, R, m_eff, up, down, enc, dec, worker)
