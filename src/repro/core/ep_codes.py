"""Entangled Polynomial codes (and Polynomial / MatDot specialisations) over a
Galois ring with enough exceptional points, plus the plain-embedding CDMM
baseline of Lemma III.1.

EP code [Yu-Maddah-Ali-Avestimehr], paper §III-B layout:

    A (t x r) -> u x w blocks A_ij;   f(x) = sum A_ij x^{(i-1)w + (j-1)}
    B (r x s) -> w x v blocks B_kl;   g(x) = sum B_kl x^{(w-k) + (l-1)uw}
    h = f*g has degree uvw + w - 2;   R = uvw + w - 1
    C_il = coeff of x^{(i-1)w + (w-1) + (l-1)uw} in h.

Encoding is a ring matmul against a fixed Vandermonde slice (MXU-friendly;
see DESIGN.md §3.2).  Decoding interpolates h from ANY R worker responses —
the point subset is a runtime value, so the Lagrange coefficient matrix is
built traceably on device (straggler tolerance inside jit).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax, vmap

from .galois import Ring
from .polyops import (
    as_u32,
    lagrange_coeff_matrix,
    s_vandermonde,
    vandermonde,
)

__all__ = [
    "EPCode",
    "PlainCDMM",
    "ep_cost_model",
    "secure_recovery_threshold",
    "smallest_embedding_ext",
]


def smallest_embedding_ext(base: Ring, npoints: int) -> Ring:
    """Smallest extension of ``base`` with >= npoints exceptional points
    (the coprimality bump in Ring.extend may make the first guess short).

    Keep in lockstep with the analytic mirror ``repro.cdmm.api._embed_ext_D``
    or planner predictions desynchronize from the instantiated ring.
    """
    m = 1
    while base.p ** (base.D * m) < npoints:
        m += 1
    ext = base.extend(m) if m > 1 else base
    while ext.p**ext.D < npoints:
        m += 1
        ext = base.extend(m)
    return ext


@dataclass(frozen=True)
class EPCosts:
    """Analytic cost model, counted in elements/ops of a reference base ring
    (the paper counts everything in GR(p^e, d)).

    ``privacy_t`` is the collusion tolerance the configuration provides: any
    ``privacy_t`` workers' shares are statistically independent of the
    inputs (0 = no privacy — every non-secure scheme family).
    """

    N: int
    R: int
    m_eff: float  # extension factor over the reference base ring
    upload: float
    download: float
    encode_ops: float
    decode_ops: float
    worker_ops: float
    privacy_t: int = 0


def secure_recovery_threshold(u: int, v: int, w: int, T: int) -> int:
    """R of the T-private EP code: mask degrees sit at uvw..uvw+T-1 on both
    operands, so deg h = 2uvw + 2T - 2 (see repro.core.secure)."""
    return 2 * u * v * w + 2 * T - 1


def ep_cost_model(
    t: int, r: int, s: int, u: int, v: int, w: int, N: int, m_eff: float,
    batch: int = 1, privacy_t: int = 0,
) -> EPCosts:
    """Costs of one EP execution over an extension with [ext:base] = m_eff,
    amortized over ``batch`` products (paper Thm III.2 accounting).

    ``privacy_t > 0`` switches to the T-private variant: the recovery
    threshold jumps to 2uvw + 2T - 1 (interference terms) and each encode
    carries T extra mask coefficients per operand; per-worker share sizes —
    hence upload — are unchanged.
    """
    T = privacy_t
    R = secure_recovery_threshold(u, v, w, T) if T else u * v * w + w - 1
    tb, rb, sb = t // u, r // w, s // v
    up = N * (tb * rb + rb * sb) * m_eff / batch
    down = R * tb * sb * m_eff / batch
    # soft-O op counts (log^2 factors reported separately in benchmarks)
    enc = N * (tb * rb * (u * w + T) + rb * sb * (w * v + T)) * m_eff**2 / batch
    dec = R * R * tb * sb * m_eff**2 / batch
    worker = tb * rb * sb * m_eff**2 / batch
    return EPCosts(N, R, m_eff, up, down, enc, dec, worker, T)


class EPCode:
    """EP code over ``ring`` with N workers and partition (u, v, w).

    Polynomial codes: w = 1.  MatDot codes: u = v = 1.
    """

    def __init__(self, ring: Ring, N: int, u: int, v: int, w: int):
        self.ring = ring
        self.N, self.u, self.v, self.w = N, u, v, w
        self.R = u * v * w + w - 1
        if self.R > N:
            raise ValueError(f"recovery threshold {self.R} > N={N}")
        if N > ring.p**ring.D:
            raise ValueError(
                f"N={N} workers need {N} exceptional points but |T|="
                f"{ring.p}^{ring.D}; extend the ring"
            )
        pts = ring.exceptional_points(N)
        self.points_np = pts
        self.points = jnp.asarray(pts)
        # exponents (0-indexed i<u, j<w, k<w, l<v)
        self.exp_f = [i * w + j for i in range(u) for j in range(w)]
        self.exp_g = [(w - 1 - k) + l * u * w for k in range(w) for l in range(v)]
        self.deg_h = (u * w - 1) + ((w - 1) + (v - 1) * u * w)
        assert self.deg_h + 1 == self.R
        V = s_vandermonde(ring, pts, self.R)  # (N, R, D) object
        self.Vf = jnp.asarray(as_u32(V[:, self.exp_f]))  # (N, uw, D)
        self.Vg = jnp.asarray(as_u32(V[:, self.exp_g]))  # (N, wv, D)
        self.exp_c = np.array(
            [[i * w + (w - 1) + l * u * w for l in range(v)] for i in range(u)]
        )  # (u, v)

    # -- partitioning ------------------------------------------------------

    def split_a(self, A: jnp.ndarray) -> jnp.ndarray:
        """(t, r, D) -> (uw, t/u, r/w, D), ordered to match exp_f."""
        t, r, D = A.shape
        u, w = self.u, self.w
        assert t % u == 0 and r % w == 0, (A.shape, (u, w))
        blocks = A.reshape(u, t // u, w, r // w, D)
        return blocks.transpose(0, 2, 1, 3, 4).reshape(u * w, t // u, r // w, D)

    def split_b(self, B: jnp.ndarray) -> jnp.ndarray:
        """(r, s, D) -> (wv, r/w, s/v, D), ordered to match exp_g."""
        r, s, D = B.shape
        w, v = self.w, self.v
        assert r % w == 0 and s % v == 0, (B.shape, (w, v))
        blocks = B.reshape(w, r // w, v, s // v, D)
        return blocks.transpose(0, 2, 1, 3, 4).reshape(w * v, r // w, s // v, D)

    # -- encode ------------------------------------------------------------

    def encode_a(self, A: jnp.ndarray) -> jnp.ndarray:
        """master-side encode: (t, r, D) -> per-worker (N, t/u, r/w, D)."""
        blocks = self.split_a(A)
        K, tb, rb, D = blocks.shape
        flat = blocks.reshape(K, tb * rb, D)
        out = self.ring.matmul(self.Vf, flat)  # (N, tb*rb, D)
        return out.reshape(self.N, tb, rb, D)

    def encode_b(self, B: jnp.ndarray) -> jnp.ndarray:
        blocks = self.split_b(B)
        K, rb, sb, D = blocks.shape
        flat = blocks.reshape(K, rb * sb, D)
        out = self.ring.matmul(self.Vg, flat)
        return out.reshape(self.N, rb, sb, D)

    def encode_a_at(self, A: jnp.ndarray, i) -> jnp.ndarray:
        """Worker i's share f(alpha_i) only: (t, r, D) -> (tb, rb, D).

        ``i`` may be a tracer (e.g. lax.axis_index inside shard_map) — this
        is the encode-at-worker mode: each worker evaluates its own point
        instead of materialising all N evaluations.
        """
        blocks = self.split_a(A)
        K, tb, rb, D = blocks.shape
        vf = lax.dynamic_index_in_dim(self.Vf, i, axis=0, keepdims=False)
        out = self.ring.matmul(vf[None], blocks.reshape(K, tb * rb, D))[0]
        return out.reshape(tb, rb, D)

    def encode_b_at(self, B: jnp.ndarray, i) -> jnp.ndarray:
        blocks = self.split_b(B)
        K, rb, sb, D = blocks.shape
        vg = lax.dynamic_index_in_dim(self.Vg, i, axis=0, keepdims=False)
        out = self.ring.matmul(vg[None], blocks.reshape(K, rb * sb, D))[0]
        return out.reshape(rb, sb, D)

    # -- worker --------------------------------------------------------------

    def worker_compute(self, FA: jnp.ndarray, GB: jnp.ndarray) -> jnp.ndarray:
        """(N, tb, rb, D) x (N, rb, sb, D) -> (N, tb, sb, D)."""
        return vmap(self.ring.matmul)(FA, GB)

    # -- decode ----------------------------------------------------------------

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        """Recover C from responses of workers ``idx`` (any R of them).

        H: (R, tb, sb, D) responses; idx: (R,) int32 worker ids (may be a
        traced runtime value — straggler-dependent).
        """
        ring = self.ring
        R, tb, sb, D = H.shape
        assert R == self.R, (R, self.R)
        pts = jnp.take(self.points, idx, axis=0)  # (R, D)
        M = lagrange_coeff_matrix(ring, pts)  # (R, R, D)
        coeffs = ring.matmul(M, H.reshape(R, tb * sb, D))  # (R, tb*sb, D)
        coeffs = coeffs.reshape(R, tb, sb, D)
        cblocks = jnp.take(coeffs, jnp.asarray(self.exp_c.ravel()), axis=0)
        cblocks = cblocks.reshape(self.u, self.v, tb, sb, D)
        C = cblocks.transpose(0, 2, 1, 3, 4).reshape(self.u * tb, self.v * sb, D)
        return C

    # -- end to end -------------------------------------------------------------

    def run(
        self, A: jnp.ndarray, B: jnp.ndarray, idx: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """Full pipeline with an optional worker subset (defaults to first R)."""
        FA, GB = self.encode_a(A), self.encode_b(B)
        H = self.worker_compute(FA, GB)
        if idx is None:
            idx = jnp.arange(self.R, dtype=jnp.int32)
        return self.decode(jnp.take(H, idx, axis=0), idx)

    def costs(self, t: int, r: int, s: int, base: Ring, batch: int = 1) -> EPCosts:
        return ep_cost_model(
            t, r, s, self.u, self.v, self.w, self.N,
            m_eff=self.ring.D / base.D, batch=batch,
        )


class PlainCDMM:
    """Baseline of Lemma III.1: matrices over a small base ring are *embedded*
    into the degree-m extension (no RMFE packing) and EP codes run there.

    Every transferred/computed extension element costs m base elements —
    the overhead the paper's RMFE batching removes.
    """

    def __init__(self, base: Ring, N: int, u: int, v: int, w: int):
        self.base = base
        self.ext = smallest_embedding_ext(base, N)
        self.code = EPCode(self.ext, N, u, v, w)

    @property
    def R(self) -> int:
        return self.code.R

    def run(
        self, A: jnp.ndarray, B: jnp.ndarray, idx: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """A: (t, r, baseD), B: (r, s, baseD) -> C = AB over the base ring."""
        eA = self.ext.embed_base(A, self.base)
        eB = self.ext.embed_base(B, self.base)
        C = self.code.run(eA, eB, idx)
        # products of embedded elements stay in the embedded base ring
        return C[..., : self.base.D]

    def costs(self, t: int, r: int, s: int) -> EPCosts:
        return self.code.costs(t, r, s, self.base)
