"""Galois ring arithmetic GR(p^e, d) and extension towers, in JAX.

Representation
--------------
An element of ``GR(p^e, d * m_1 * ... * m_L)`` is a flat coefficient vector of
length ``D = d * prod(m_k)`` with entries in ``Z_{p^e}`` (dtype uint32).  The
ring is built as a *tower*::

    Z_{p^e}[x]/(f)            -- degree d,   f irreducible mod p
      [y_1]/(g_1)             -- degree m_1, g_1 irreducible mod p, gcd(m_1, d)=1
        [y_2]/(g_2)           -- degree m_2, gcd(m_2, d*m_1)=1 ...

All moduli have coefficients in {0..p-1} (lifts of GF(p) polynomials).  A
degree-m polynomial irreducible over GF(p) stays irreducible over GF(p^D0)
iff gcd(m, D0) = 1, so every tower level only needs a *prime-field*
irreducibility search (Rabin test).  Because the moduli have scalar
coefficients, reduction never mixes tower levels and the reduction of a
product factorises as a Kronecker product of per-level power-reduction
matrices (``FOLD``).

Multiplication = multi-level coefficient convolution (positions ``CONVPOS``)
followed by the linear ``FOLD`` map.  Structure constants
``T[i,j,k] = FOLD[CONVPOS[i,j], k]`` are also materialised for scalar paths.

Exceptional sets
----------------
Instead of the Teichmuller set (needs a primitive root of GF(p^D)), we use
digit lifts: the i-th point is the base-p digit vector of i.  Two distinct
digit vectors differ in some coordinate by a value in {1..p-1}, which is
non-zero mod p, hence the difference is a unit.  This gives the same maximal
cardinality p^D used by the paper and is jit-constant.

Overflow discipline
-------------------
* p = 2, e <= 32: uint32 arithmetic wraps mod 2^32 and 2^e | 2^32, so all
  intermediate sums are exact; a single mask is applied at the end.
* general p^e <= 2^12: products fit uint32; contractions are chunked so that
  partial sums never exceed 2^32 before an explicit ``% q``.
"""
from __future__ import annotations

import math
from functools import lru_cache, partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "Ring",
    "make_ring",
    "find_irreducible_gfp",
    "is_irreducible_gfp",
]

# ---------------------------------------------------------------------------
# GF(p)[x] utilities (host-side, numpy int64 coefficient arrays, index=degree)
# ---------------------------------------------------------------------------


def _poly_trim(a: np.ndarray) -> np.ndarray:
    nz = np.nonzero(a)[0]
    if len(nz) == 0:
        return a[:1] * 0
    return a[: nz[-1] + 1]


def _poly_mulmod(a: np.ndarray, b: np.ndarray, f: np.ndarray, p: int) -> np.ndarray:
    """(a*b) mod f over GF(p); f monic."""
    prod = np.convolve(a.astype(np.int64), b.astype(np.int64)) % p
    return _poly_mod(prod, f, p)


def _poly_mod(a: np.ndarray, f: np.ndarray, p: int) -> np.ndarray:
    a = a.astype(np.int64) % p
    d = len(f) - 1
    a = a.copy()
    for k in range(len(a) - 1, d - 1, -1):
        c = a[k]
        if c:
            a[k - d : k + 1] = (a[k - d : k + 1] - c * f) % p
    out = a[:d]
    if len(out) < d:
        out = np.pad(out, (0, d - len(out)))
    return out


def _poly_powmod(a: np.ndarray, n: int, f: np.ndarray, p: int) -> np.ndarray:
    result = np.zeros(len(f) - 1, dtype=np.int64)
    result[0] = 1
    base = _poly_mod(a, f, p)
    while n:
        if n & 1:
            result = _poly_mulmod(result, base, f, p)
        base = _poly_mulmod(base, base, f, p)
        n >>= 1
    return result


def _poly_gcd(a: np.ndarray, b: np.ndarray, p: int) -> np.ndarray:
    a, b = _poly_trim(a % p), _poly_trim(b % p)
    while len(b) > 1 or (len(b) == 1 and b[0] != 0):
        # make b monic
        inv_lead = pow(int(b[-1]), p - 2, p)
        bm = (b * inv_lead) % p
        # a mod bm
        r = a.astype(np.int64) % p
        db = len(bm) - 1
        r = r.copy()
        for k in range(len(r) - 1, db - 1, -1):
            c = r[k]
            if c:
                r[k - db : k + 1] = (r[k - db : k + 1] - c * bm) % p
        r = _poly_trim(r[:db] if db > 0 else r[:1] * 0)
        a, b = bm, r
    return a


def _prime_factors(n: int) -> Tuple[int, ...]:
    out = []
    d = 2
    while d * d <= n:
        while n % d == 0:
            if not out or out[-1] != d:
                out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return tuple(out)


def is_irreducible_gfp(f: np.ndarray, p: int) -> bool:
    """Rabin irreducibility test for a monic polynomial over GF(p)."""
    n = len(f) - 1
    if n <= 0:
        return False
    x = np.array([0, 1], dtype=np.int64)
    # x^(p^n) == x (mod f)
    xq = _poly_powmod(x, p**n, f, p)
    xx = _poly_mod(x, f, p)
    if not np.array_equal(xq, xx):
        return False
    for ell in _prime_factors(n):
        h = _poly_powmod(x, p ** (n // ell), f, p)
        diff = (h - xx) % p
        g = _poly_gcd(f.astype(np.int64), diff, p)
        if not (len(_poly_trim(g)) == 1 and _poly_trim(g)[0] != 0):
            return False
    return True


@lru_cache(maxsize=None)
def find_irreducible_gfp(p: int, d: int) -> Tuple[int, ...]:
    """Deterministic search for a monic degree-d irreducible over GF(p).

    Returns the coefficient tuple (len d+1, entries in 0..p-1, monic).
    """
    if d == 1:
        return (0, 1)  # x
    # iterate low coefficients as base-p counter; constant term must be != 0
    for c in range(p ** d):
        digits = []
        cc = c
        for _ in range(d):
            digits.append(cc % p)
            cc //= p
        if digits[0] == 0:
            continue
        f = np.array(digits + [1], dtype=np.int64)
        if is_irreducible_gfp(f, p):
            return tuple(int(v) for v in f)
    raise RuntimeError(f"no irreducible polynomial found for p={p}, d={d}")


# ---------------------------------------------------------------------------
# The Ring class
# ---------------------------------------------------------------------------


def _power_reduction_matrix(f: Sequence[int], q: int) -> np.ndarray:
    """Rows r = 0..2d-2: coefficients of x^r mod f, over Z_q. Shape (2d-1, d)."""
    f = np.array(f, dtype=object)
    d = len(f) - 1
    rows = np.zeros((2 * d - 1, d), dtype=object)
    cur = np.zeros(d, dtype=object)
    cur[0] = 1
    rows[0] = cur
    for r in range(1, 2 * d - 1):
        nxt = np.zeros(d, dtype=object)
        nxt[1:] = cur[: d - 1]
        top = cur[d - 1]
        if top:
            # x^d = -(f[0] + f[1] x + ... + f[d-1] x^{d-1}) mod q
            for i in range(d):
                nxt[i] = (nxt[i] - top * f[i]) % q
        nxt %= q
        rows[r] = nxt
        cur = nxt
    return rows


class Ring:
    """GR(p^e, D) with D = prod(degrees), tower representation (see module doc).

    All jnp methods are jit-traceable; ``s_*`` methods are host-side exact
    python-int mirrors used for setup-time constant computation.
    """

    def __init__(self, p: int, e: int, degrees: Tuple[int, ...]):
        degrees = tuple(int(d) for d in degrees if int(d) > 1)
        self.p = int(p)
        self.e = int(e)
        self.q = p**e
        self.degrees = degrees
        self.D = int(np.prod(degrees)) if degrees else 1
        self.p2fast = (p == 2 and e <= 32)
        if not self.p2fast and self.q > (1 << 12):
            raise NotImplementedError(
                f"general modulus q={self.q} > 2^12 needs wider accumulators; "
                "use p=2, e<=32 for the machine-word fast path"
            )
        self.dtype = jnp.uint32
        self._mask = np.uint32(2**e - 1) if (p == 2 and e < 32) else None

        # validate coprimality of tower degrees
        acc = 1
        self.moduli = []
        for m in degrees:
            if acc > 1 and math.gcd(m, acc) != 1:
                raise ValueError(
                    f"tower degree {m} not coprime with lower degrees (prod={acc}); "
                    "use Ring.extend() which auto-adjusts"
                )
            self.moduli.append(find_irreducible_gfp(p, m))
            acc *= m

        self._build_tables()

    # -- construction of CONVPOS / FOLD / T --------------------------------

    def _build_tables(self):
        q = self.q
        if not self.degrees:
            self.conv_shape = (1,)
            self.K = 1
            self.CONVPOS = np.zeros((1, 1), dtype=np.int32)
            self.FOLD = np.ones((1, 1), dtype=object)
        else:
            # Flat coefficient layout: innermost (base, degrees[0]) level is the
            # FASTEST-varying axis; the outermost extension is the slowest.
            shapes_rev = tuple(reversed(self.degrees))  # outer ... inner
            conv_shape = tuple(2 * m - 1 for m in shapes_rev)
            K = int(np.prod(conv_shape))
            D = self.D
            idx = np.arange(D)
            multis = np.stack(np.unravel_index(idx, shapes_rev), axis=-1)  # (D, L)
            conv_pos = np.zeros((D, D), dtype=np.int64)
            for i in range(D):
                summed = multis[i][None, :] + multis  # (D, L)
                conv_pos[i] = np.ravel_multi_index(
                    tuple(summed[:, k] for k in range(summed.shape[1])), conv_shape
                )
            self.conv_shape = conv_shape
            self.K = K
            self.CONVPOS = conv_pos.astype(np.int32)
            # FOLD = kron over levels, outermost first so innermost lands inner
            fold = np.ones((1, 1), dtype=object)
            for m, modulus in zip(shapes_rev, reversed(self.moduli)):
                red = _power_reduction_matrix(modulus, q)  # (2m-1, m)
                A0, B0 = fold.shape
                C0, D0 = red.shape
                newf = np.zeros((A0 * C0, B0 * D0), dtype=object)
                for a in range(A0):
                    for b in range(B0):
                        if fold[a, b]:
                            newf[a * C0 : (a + 1) * C0, b * D0 : (b + 1) * D0] = (
                                fold[a, b] * red
                            ) % q
                fold = newf
            assert fold.shape == (K, D), (fold.shape, K, D)
            self.FOLD = fold % q

        # structure constants T[i,j,k] = FOLD[CONVPOS[i,j], k]
        D = self.D
        T = np.zeros((D, D, D), dtype=object)
        for i in range(D):
            T[i] = self.FOLD[self.CONVPOS[i]]
        self.T = T

        # jnp constants
        self.FOLDJ = jnp.asarray(self.FOLD.astype(np.uint32))
        self.CONVJ = jnp.asarray(self.CONVPOS)
        self.TJ = jnp.asarray(T.astype(np.uint32))

        # chunking for general-q contractions
        if self.p2fast:
            self.max_terms = None
        else:
            self.max_terms = max(1, (2**32 - 1) // ((self.q - 1) ** 2))

    # -- basics -------------------------------------------------------------

    def __repr__(self):
        return f"GR({self.p}^{self.e}, {self.D}) degrees={self.degrees}"

    def __eq__(self, other):
        return (
            isinstance(other, Ring)
            and (self.p, self.e, self.degrees) == (other.p, other.e, other.degrees)
        )

    def __hash__(self):
        return hash((self.p, self.e, self.degrees))

    @property
    def size(self) -> int:
        return self.q**self.D

    def extend(self, m: int) -> "Ring":
        """Extension of degree >= m with the coprimality constraint auto-fixed."""
        if m <= 1:
            return self
        mm = m
        while math.gcd(mm, self.D) != 1:
            mm += 1
        return make_ring(self.p, self.e, self.degrees + (mm,))

    @property
    def ext_degree_of_top(self) -> int:
        return self.degrees[-1] if self.degrees else 1

    def base_ring(self) -> "Ring":
        if not self.degrees:
            return self
        return make_ring(self.p, self.e, self.degrees[:-1])

    # -- host-side exact scalar ops (python ints) ---------------------------

    def s_zero(self) -> np.ndarray:
        return np.zeros(self.D, dtype=object)

    def s_one(self) -> np.ndarray:
        z = self.s_zero()
        z[0] = 1
        return z

    def s_from_int(self, v: int) -> np.ndarray:
        z = self.s_zero()
        z[0] = v % self.q
        return z

    def s_add(self, a, b) -> np.ndarray:
        return (np.asarray(a, dtype=object) + np.asarray(b, dtype=object)) % self.q

    def s_sub(self, a, b) -> np.ndarray:
        return (np.asarray(a, dtype=object) - np.asarray(b, dtype=object)) % self.q

    def s_mul(self, a, b) -> np.ndarray:
        a = np.asarray(a, dtype=object)
        b = np.asarray(b, dtype=object)
        conv = np.zeros(self.K, dtype=object)
        for i in range(self.D):
            ai = a[i]
            if ai:
                pos = self.CONVPOS[i]
                for j in range(self.D):
                    bj = b[j]
                    if bj:
                        conv[pos[j]] += ai * bj
        out = np.zeros(self.D, dtype=object)
        for c in range(self.K):
            v = conv[c]
            if v:
                out = out + v * self.FOLD[c]
        return out % self.q

    def s_pow(self, a, n: int) -> np.ndarray:
        result = self.s_one()
        base = np.asarray(a, dtype=object) % self.q
        while n:
            if n & 1:
                result = self.s_mul(result, base)
            base = self.s_mul(base, base)
            n >>= 1
        return result

    def s_is_unit(self, a) -> bool:
        return any(int(v) % self.p for v in np.asarray(a).ravel())

    def s_inv(self, a) -> np.ndarray:
        """Inverse of a unit: Fermat inverse mod p + Hensel lifting."""
        if not self.s_is_unit(a):
            raise ZeroDivisionError("not a unit in " + repr(self))
        # inverse mod p via Fermat in GF(p^D)
        x = self.s_pow(a, self.p**self.D - 2)
        # Hensel: x <- x(2 - a x), doubling p-adic precision
        two = self.s_from_int(2)
        k = 1
        while k < self.e:
            ax = self.s_mul(a, x)
            x = self.s_mul(x, self.s_sub(two, ax))
            k *= 2
        return x % self.q

    def s_matmul(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        """Host matmul of (t,r,D) x (r,s,D) object arrays."""
        t, r, _ = A.shape
        r2, s, _ = B.shape
        assert r == r2
        out = np.zeros((t, s, self.D), dtype=object)
        for i in range(t):
            for j in range(s):
                acc = self.s_zero()
                for k in range(r):
                    acc = self.s_add(acc, self.s_mul(A[i, k], B[k, j]))
                out[i, j] = acc
        return out

    # -- exceptional set -----------------------------------------------------

    def exceptional_points(self, count: int) -> np.ndarray:
        """First ``count`` digit-lift points; pairwise differences are units.

        Returns uint32 array (count, D).
        """
        if count > self.p**self.D:
            raise ValueError(
                f"need {count} exceptional points but |T| = {self.p}^{self.D}"
            )
        pts = np.zeros((count, self.D), dtype=np.uint32)
        for i in range(count):
            c = i
            for k in range(self.D):
                pts[i, k] = c % self.p
                c //= self.p
        return pts

    # -- device-side helpers --------------------------------------------------

    def _modq(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.p2fast:
            if self._mask is not None:
                return x & self._mask
            return x
        return x % jnp.uint32(self.q)

    def mask_final(self, x: jnp.ndarray) -> jnp.ndarray:
        return self._modq(x)

    def _chunk_dot(self, X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
        """(a, b) @ (b, c) with overflow-safe accumulation, reduced output."""
        if self.p2fast:
            return lax.dot(X, Y, preferred_element_type=jnp.uint32)
        b = X.shape[-1]
        mt = self.max_terms
        if b <= mt:
            return lax.dot(X, Y, preferred_element_type=jnp.uint32) % jnp.uint32(self.q)
        nchunk = -(-b // mt)
        pad = nchunk * mt - b
        Xp = jnp.pad(X, ((0, 0), (0, pad)))
        Yp = jnp.pad(Y, ((0, pad), (0, 0)))
        Xc = Xp.reshape(X.shape[0], nchunk, mt)
        Yc = Yp.reshape(nchunk, mt, Y.shape[1])

        def body(carry, xy):
            xc, yc = xy
            d = lax.dot(xc, yc, preferred_element_type=jnp.uint32) % jnp.uint32(self.q)
            return (carry + d) % jnp.uint32(self.q), None

        init = jnp.zeros((X.shape[0], Y.shape[1]), dtype=jnp.uint32)
        out, _ = lax.scan(body, init, (jnp.moveaxis(Xc, 1, 0), Yc))
        return out

    # -- elementwise ops -------------------------------------------------------

    def zeros(self, shape: Tuple[int, ...]) -> jnp.ndarray:
        return jnp.zeros(tuple(shape) + (self.D,), dtype=self.dtype)

    def ones(self, shape: Tuple[int, ...]) -> jnp.ndarray:
        z = np.zeros(tuple(shape) + (self.D,), dtype=np.uint32)
        z[..., 0] = 1
        return jnp.asarray(z)

    def add(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        return self._modq(a + b)

    def sub(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        if self.p2fast:
            return self._modq(a - b)  # wraps correctly mod 2^e
        return (a + jnp.uint32(self.q) - b) % jnp.uint32(self.q)

    def neg(self, a: jnp.ndarray) -> jnp.ndarray:
        if self.p2fast:
            return self._modq(jnp.uint32(0) - a)
        return (jnp.uint32(self.q) - a) % jnp.uint32(self.q)

    def mul(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Elementwise ring product; a, b broadcastable with trailing dim D."""
        a, b = jnp.broadcast_arrays(a, b)
        batch = a.shape[:-1]
        D, K = self.D, self.K
        conv = jnp.zeros(batch + (K,), dtype=jnp.uint32)

        def body(i, conv):
            ai = lax.dynamic_index_in_dim(a, i, axis=a.ndim - 1, keepdims=True)
            contrib = ai * b  # (..., D)
            if not self.p2fast:
                contrib = contrib % jnp.uint32(self.q)
            pos = self.CONVJ[i]
            return conv.at[..., pos].add(contrib)

        conv = lax.fori_loop(0, D, body, conv)
        conv = self._modq(conv)
        flat = conv.reshape(-1, K)
        out = self._chunk_dot(flat, self.FOLDJ)
        return self._modq(out.reshape(batch + (D,)))

    def matmul(self, A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
        """Ring matmul: (t, r, D) x (r, s, D) -> (t, s, D)."""
        t, r, D = A.shape
        r2, s, D2 = B.shape
        assert r == r2 and D == D2 == self.D, (A.shape, B.shape, self.D)
        K = self.K
        Bf = B.reshape(r, s * D)
        conv = jnp.zeros((t, s, K), dtype=jnp.uint32)

        def body(i, conv):
            Ai = lax.dynamic_index_in_dim(A, i, axis=2, keepdims=False)  # (t, r)
            tmp = self._chunk_dot(Ai, Bf).reshape(t, s, D)
            pos = self.CONVJ[i]
            return conv.at[..., pos].add(tmp)

        conv = lax.fori_loop(0, D, body, conv)
        conv = self._modq(conv)
        out = self._chunk_dot(conv.reshape(t * s, K), self.FOLDJ)
        return self._modq(out.reshape(t, s, D))

    def pow(self, a: jnp.ndarray, n: int) -> jnp.ndarray:
        """Elementwise a**n for a python-int exponent (unrolled square&multiply)."""
        result = jnp.broadcast_to(self.ones(a.shape[:-1]), a.shape)
        base = a
        while n:
            if n & 1:
                result = self.mul(result, base)
            base = self.mul(base, base) if n > 1 else base
            n >>= 1
        return result

    def inv(self, a: jnp.ndarray) -> jnp.ndarray:
        """Elementwise inverse of units (traceable: Fermat mod p + Hensel)."""
        x = self.pow(a, self.p**self.D - 2)
        two = self.scale(self.ones(a.shape[:-1]), 2)
        k = 1
        while k < self.e:
            ax = self.mul(a, x)
            x = self.mul(x, self.sub(two, ax))
            k *= 2
        return x

    def scale(self, a: jnp.ndarray, c: int) -> jnp.ndarray:
        """Multiply by an integer scalar."""
        return self._modq(a * jnp.uint32(c % self.q))

    def random(self, rng: np.random.Generator, shape: Tuple[int, ...]) -> jnp.ndarray:
        arr = rng.integers(0, self.q, size=tuple(shape) + (self.D,), dtype=np.uint64)
        return jnp.asarray(arr.astype(np.uint32))

    def random_jax(self, key: jax.Array, shape: Tuple[int, ...]) -> jnp.ndarray:
        """Uniform ring elements from a ``jax.random`` key (traceable).

        This is the masked-randomness seam used by the secure (T-private)
        schemes: the same key yields the same mask coefficients whether the
        encode runs master-side (``encode_*``) or at-worker
        (``encode_*_at``), so every execution backend produces bit-identical
        codewords from identical keys.
        """
        full = tuple(shape) + (self.D,)
        if self.p == 2:
            # q = 2^e divides 2^32: masking uniform 32-bit words stays uniform
            return self._modq(jax.random.bits(key, full, dtype=jnp.uint32))
        return jax.random.randint(key, full, 0, self.q, dtype=jnp.int32).astype(
            jnp.uint32
        )

    def random_units(self, rng: np.random.Generator, shape: Tuple[int, ...]) -> jnp.ndarray:
        arr = rng.integers(0, self.q, size=tuple(shape) + (self.D,), dtype=np.uint64)
        arr = arr.astype(np.uint32)
        # force constant coefficient to be a unit in Z_q => element is a unit
        c0 = arr[..., 0]
        c0 = c0 - (c0 % self.p) + 1
        arr[..., 0] = c0
        return jnp.asarray(arr)

    # -- embeddings between tower and base ------------------------------------

    def embed_base(self, a: jnp.ndarray, base: "Ring") -> jnp.ndarray:
        """Embed elements of the base ring (trailing dim base.D) into self.

        self must be a tower over ``base`` (degrees prefix match); the image
        occupies the low coefficients.
        """
        assert self.degrees[: len(base.degrees)] == base.degrees
        batch = a.shape[:-1]
        out = jnp.zeros(batch + (self.D,), dtype=self.dtype)
        return out.at[..., : base.D].set(a)

    def tower_coeffs(self, a: jnp.ndarray, base: "Ring") -> jnp.ndarray:
        """View (…, D) as (…, D//base.D, base.D): coefficients over the base."""
        assert self.degrees[: len(base.degrees)] == base.degrees
        t = self.D // base.D
        return a.reshape(a.shape[:-1] + (t, base.D))

    def from_tower_coeffs(self, c: jnp.ndarray) -> jnp.ndarray:
        return c.reshape(c.shape[:-2] + (self.D,))


@lru_cache(maxsize=None)
def make_ring(p: int, e: int, degrees: Tuple[int, ...] = ()) -> Ring:
    return Ring(p, e, degrees)
