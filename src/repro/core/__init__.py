"""Core: the paper's contribution — CDMM over Galois rings via RMFE."""
from .galois import Ring, make_ring, find_irreducible_gfp, is_irreducible_gfp
from .rmfe import BasicRMFE, ConcatRMFE, build_rmfe
from .ep_codes import EPCode, PlainCDMM, ep_cost_model, EPCosts
from .batch_rmfe import BatchEPRMFE
from .single_rmfe import EPRMFE_I, EPRMFE_II
from .gcsa import CSACode, GCSACode, gcsa_cost_model, gr_solve
from .secure import (
    SecureBatchEPRMFE,
    SecureEP,
    SecureEPCode,
    secure_recovery_threshold,
    smallest_secure_ext,
)
from .straggler import (
    MembershipEvents,
    WorkerTrace,
    sample_trace,
    select_workers,
    simulate_stragglers,
    straggler_latencies,
)

__all__ = [
    "Ring", "make_ring", "find_irreducible_gfp", "is_irreducible_gfp",
    "BasicRMFE", "ConcatRMFE", "build_rmfe",
    "EPCode", "PlainCDMM", "ep_cost_model", "EPCosts",
    "BatchEPRMFE", "EPRMFE_I", "EPRMFE_II",
    "CSACode", "GCSACode", "gcsa_cost_model", "gr_solve",
    "SecureEPCode", "SecureEP", "SecureBatchEPRMFE",
    "secure_recovery_threshold", "smallest_secure_ext",
    "select_workers", "simulate_stragglers", "straggler_latencies",
    "MembershipEvents", "WorkerTrace", "sample_trace",
]
