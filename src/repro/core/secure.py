"""T-private (secure) CDMM over Galois rings: EP codes with random masking.

The RMFE machinery of this repo comes from MPC [CCXY18]; this module closes
the loop and makes the codes themselves secret-sharing.  Following the
secure-MDS / GASP-style construction adapted to Galois rings, each encoding
polynomial carries ``T`` uniformly random mask coefficients placed ABOVE the
data terms:

    f(x) = sum_ij A_ij x^{(i-1)w + (j-1)}        + sum_{k<T} Z_k x^{uvw + k}
    g(x) = sum_kl B_kl x^{(w-k) + (l-1)uw}       + sum_{k<T} W_k x^{uvw + k}

with Z_k, W_k i.i.d. uniform over the codeword ring.  Worker i receives
(f(a_i), g(a_i)) for an exceptional point a_i.

Privacy (T-collusion, per operand).  For any subset S of <= T workers the
A-side shares are ``data_S + M_S z`` where ``M_S = [a_i^{uvw + k}]`` factors
as ``diag(a_i^{uvw}) @ Vandermonde_S``.  Digit-lift exceptional points are
units except the zero point — so this code evaluates at points 1..N (the
zero point is EXCLUDED; it would hand worker 0 an unmasked data block) —
and pairwise differences of exceptional points are units, hence
``det M_S = prod a_i^{uvw} * prod_{i<j} (a_j - a_i)`` is a unit and ``M_S``
is invertible over the ring.  Uniform masks therefore make the S-shares
exactly uniform, independent of the data: any <= T workers learn nothing
(tests/test_secure.py proves the distribution match exhaustively on a small
ring).  T+1 shares are NOT independent of the data — the recovery/privacy
trade the planner exposes as ``ProblemSpec.privacy_t``.

Correctness.  The mask degrees start at uvw, strictly above every read-out
exponent of C (max exp_c = uvw - 1), so all interference terms
(g·x^{uvw}Z, f·x^{uvw}W, x^{2uvw}ZW) live at degrees >= uvw and never
pollute the C blocks; deg h = 2uvw + 2T - 2 gives the recovery threshold

    R_secure = 2uvw + 2T - 1

(matching secure MatDot's 2(p+T)-1 at u=v=1).  Decoding is the same any-R
Lagrange interpolation as the non-secure EP code.

Randomness seam.  Masks are derived from a ``jax.random`` key
(``Ring.random_jax``): the A-side uses fold_in(key, 0), the B-side
fold_in(key, 1), so master-side ``encode_*`` and at-worker ``encode_*_at``
regenerate identical mask coefficients from the same key and every
execution backend (local / shard_map / elastic) decodes bit-identically.
"""
from __future__ import annotations

from math import ceil, log
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax, vmap

from .ep_codes import (
    EPCosts,
    ep_cost_model,
    secure_recovery_threshold,
    smallest_embedding_ext,
)
from .galois import Ring
from .polyops import as_u32, lagrange_coeff_matrix, s_vandermonde
from .rmfe import build_rmfe

__all__ = [
    "SecureEPCode",
    "SecureEP",
    "SecureBatchEPRMFE",
    "secure_recovery_threshold",
    "smallest_secure_ext",
]


def smallest_secure_ext(base: Ring, N: int) -> Ring:
    """Smallest extension of ``base`` whose exceptional set supports N
    *secure* evaluation points, i.e. >= N + 1 digit-lift points (the zero
    point is skipped — it is not a unit and would leak an unmasked share).

    Delegates to ``smallest_embedding_ext`` so the search stays in lockstep
    with its analytic mirror ``repro.cdmm.api._embed_ext_D``."""
    return smallest_embedding_ext(base, N + 1)


class SecureEPCode:
    """T-private EP code over ``ring`` with N workers and partition (u, v, w).

    Requires N + 1 <= p^D exceptional points (evaluation skips the zero
    point) and R = 2uvw + 2T - 1 <= N.  ``encode_a/encode_b`` take a
    ``jax.random`` key; the deterministic mask seam makes all backends
    reproducible from the key.  ``encode_a_with_masks`` exposes the mask
    coefficients directly for the exhaustive privacy tests.
    """

    def __init__(self, ring: Ring, N: int, u: int, v: int, w: int, T: int):
        if T < 1:
            raise ValueError(f"privacy requires T >= 1, got T={T}")
        self.ring = ring
        self.N, self.u, self.v, self.w, self.T = N, u, v, w, T
        uvw = u * v * w
        self.R = secure_recovery_threshold(u, v, w, T)
        if self.R > N:
            raise ValueError(
                f"secure recovery threshold {self.R} = 2uvw + 2T - 1 > N={N}"
            )
        if N + 1 > ring.p**ring.D:
            raise ValueError(
                f"T-private code needs N+1={N + 1} exceptional points (zero "
                f"point excluded) but |T(ring)|={ring.p}^{ring.D}; extend the ring"
            )
        # points 1..N: every one a unit, pairwise differences units
        pts = ring.exceptional_points(N + 1)[1:]
        self.points_np = pts
        self.points = jnp.asarray(pts)
        # data exponents (0-indexed) as in EPCode, masks at uvw .. uvw+T-1
        self.exp_f = [i * w + j for i in range(u) for j in range(w)]
        self.exp_g = [(w - 1 - k) + l * u * w for k in range(w) for l in range(v)]
        self.mask_exp = [uvw + k for k in range(T)]
        self.deg_h = 2 * uvw + 2 * T - 2
        assert self.deg_h + 1 == self.R
        V = s_vandermonde(ring, pts, self.R)  # (N, R, D) object
        self.Vf = jnp.asarray(as_u32(V[:, self.exp_f + self.mask_exp]))
        self.Vg = jnp.asarray(as_u32(V[:, self.exp_g + self.mask_exp]))
        self.exp_c = np.array(
            [[i * w + (w - 1) + l * u * w for l in range(v)] for i in range(u)]
        )  # (u, v) — all < uvw, below every interference term

    # -- partitioning (identical block layout to EPCode) --------------------

    def split_a(self, A: jnp.ndarray) -> jnp.ndarray:
        t, r, D = A.shape
        u, w = self.u, self.w
        assert t % u == 0 and r % w == 0, (A.shape, (u, w))
        blocks = A.reshape(u, t // u, w, r // w, D)
        return blocks.transpose(0, 2, 1, 3, 4).reshape(u * w, t // u, r // w, D)

    def split_b(self, B: jnp.ndarray) -> jnp.ndarray:
        r, s, D = B.shape
        w, v = self.w, self.v
        assert r % w == 0 and s % v == 0, (B.shape, (w, v))
        blocks = B.reshape(w, r // w, v, s // v, D)
        return blocks.transpose(0, 2, 1, 3, 4).reshape(w * v, r // w, s // v, D)

    # -- mask derivation (the RNG seam) --------------------------------------

    def _require_key(self, key) -> jax.Array:
        if key is None:
            raise ValueError(
                "secure encode requires a jax.random key (masks must be "
                "fresh randomness); pass key=... through coded_matmul"
            )
        return key

    def masks_a(self, key: jax.Array, tb: int, rb: int) -> jnp.ndarray:
        """(T, tb, rb, D) uniform mask blocks for the A-side polynomial."""
        return self.ring.random_jax(jax.random.fold_in(key, 0), (self.T, tb, rb))

    def masks_b(self, key: jax.Array, rb: int, sb: int) -> jnp.ndarray:
        return self.ring.random_jax(jax.random.fold_in(key, 1), (self.T, rb, sb))

    # -- encode --------------------------------------------------------------

    def encode_a_with_masks(self, A: jnp.ndarray, Z: jnp.ndarray) -> jnp.ndarray:
        """Encode with explicit mask blocks Z (T, tb, rb, D) -> (N, tb, rb, D).

        The privacy tests enumerate Z exhaustively through this entry point;
        ``encode_a`` derives Z from a key and delegates here.
        """
        blocks = self.split_a(A)
        K, tb, rb, D = blocks.shape
        assert Z.shape == (self.T, tb, rb, D), (Z.shape, (self.T, tb, rb, D))
        coeffs = jnp.concatenate([blocks, Z], axis=0)
        out = self.ring.matmul(self.Vf, coeffs.reshape(K + self.T, tb * rb, D))
        return out.reshape(self.N, tb, rb, D)

    def encode_b_with_masks(self, B: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
        blocks = self.split_b(B)
        K, rb, sb, D = blocks.shape
        assert W.shape == (self.T, rb, sb, D), (W.shape, (self.T, rb, sb, D))
        coeffs = jnp.concatenate([blocks, W], axis=0)
        out = self.ring.matmul(self.Vg, coeffs.reshape(K + self.T, rb * sb, D))
        return out.reshape(self.N, rb, sb, D)

    def encode_a(self, A: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
        key = self._require_key(key)
        t, r, _ = A.shape
        return self.encode_a_with_masks(
            A, self.masks_a(key, t // self.u, r // self.w)
        )

    def encode_b(self, B: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
        key = self._require_key(key)
        r, s, _ = B.shape
        return self.encode_b_with_masks(
            B, self.masks_b(key, r // self.w, s // self.v)
        )

    def encode_a_at(
        self, A: jnp.ndarray, i, key: Optional[jax.Array] = None
    ) -> jnp.ndarray:
        """Worker i's masked share only; regenerates the same masks from the
        key that ``encode_a`` uses, so the at-worker codeword is identical."""
        key = self._require_key(key)
        blocks = self.split_a(A)
        K, tb, rb, D = blocks.shape
        coeffs = jnp.concatenate([blocks, self.masks_a(key, tb, rb)], axis=0)
        vf = lax.dynamic_index_in_dim(self.Vf, i, axis=0, keepdims=False)
        out = self.ring.matmul(vf[None], coeffs.reshape(K + self.T, tb * rb, D))[0]
        return out.reshape(tb, rb, D)

    def encode_b_at(
        self, B: jnp.ndarray, i, key: Optional[jax.Array] = None
    ) -> jnp.ndarray:
        key = self._require_key(key)
        blocks = self.split_b(B)
        K, rb, sb, D = blocks.shape
        coeffs = jnp.concatenate([blocks, self.masks_b(key, rb, sb)], axis=0)
        vg = lax.dynamic_index_in_dim(self.Vg, i, axis=0, keepdims=False)
        out = self.ring.matmul(vg[None], coeffs.reshape(K + self.T, rb * sb, D))[0]
        return out.reshape(rb, sb, D)

    # -- worker / decode ------------------------------------------------------

    def worker_compute(self, FA: jnp.ndarray, GB: jnp.ndarray) -> jnp.ndarray:
        return vmap(self.ring.matmul)(FA, GB)

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        """Recover C from ANY R = 2uvw + 2T - 1 responses (idx traceable)."""
        ring = self.ring
        R, tb, sb, D = H.shape
        assert R == self.R, (R, self.R)
        pts = jnp.take(self.points, idx, axis=0)
        M = lagrange_coeff_matrix(ring, pts)  # (R, R, D)
        coeffs = ring.matmul(M, H.reshape(R, tb * sb, D)).reshape(R, tb, sb, D)
        cblocks = jnp.take(coeffs, jnp.asarray(self.exp_c.ravel()), axis=0)
        cblocks = cblocks.reshape(self.u, self.v, tb, sb, D)
        return cblocks.transpose(0, 2, 1, 3, 4).reshape(self.u * tb, self.v * sb, D)

    # -- end to end -----------------------------------------------------------

    def run(
        self,
        A: jnp.ndarray,
        B: jnp.ndarray,
        key: jax.Array,
        idx: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        FA, GB = self.encode_a(A, key), self.encode_b(B, key)
        H = self.worker_compute(FA, GB)
        if idx is None:
            idx = jnp.arange(self.R, dtype=jnp.int32)
        return self.decode(jnp.take(H, idx, axis=0), idx)

    def costs(self, t: int, r: int, s: int, base: Ring, batch: int = 1) -> EPCosts:
        return ep_cost_model(
            t, r, s, self.u, self.v, self.w, self.N,
            m_eff=self.ring.D / base.D, batch=batch, privacy_t=self.T,
        )


class SecureEP:
    """T-private single-product CDMM over a (possibly tiny) base ring.

    Lemma III.1 layout with masking: the base ring is embedded into the
    smallest extension with >= N + 1 exceptional points and a
    :class:`SecureEPCode` runs there.  Masks are uniform over the EXTENSION
    ring, so shares are uniform extension elements — embedding does not
    weaken the T-collusion privacy.
    """

    def __init__(self, base: Ring, N: int, u: int, v: int, w: int, T: int):
        self.base = base
        self.ext = smallest_secure_ext(base, N)
        self.code = SecureEPCode(self.ext, N, u, v, w, T)
        self.T = T

    @property
    def R(self) -> int:
        return self.code.R

    def embed(self, M: jnp.ndarray) -> jnp.ndarray:
        return self.ext.embed_base(M, self.base)

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        # products of embedded data stay in the embedded base ring; the
        # interference terms never reach the read-out exponents
        return self.code.decode(H, idx)[..., : self.base.D]

    def run(self, A, B, key, idx=None) -> jnp.ndarray:
        C = self.code.run(self.embed(A), self.embed(B), key, idx)
        return C[..., : self.base.D]

    def costs(self, t: int, r: int, s: int) -> EPCosts:
        return self.code.costs(t, r, s, self.base)


class SecureBatchEPRMFE:
    """T-private coded distributed BATCH matrix multiplication via RMFE.

    A batch of n products over GR(p^e, d) is packed positionwise by an
    (n, m)-RMFE into one product over the extension (paper Thm III.2) and
    computed by a :class:`SecureEPCode` there.  The RMFE extension is forced
    to carry >= N + 1 exceptional points; masks are uniform over the
    extension, so per-operand T-collusion privacy holds verbatim, while the
    read-out coefficients stay exactly the packed products (interference
    lives strictly above them) and psi recovers the batch.
    """

    def __init__(
        self, base: Ring, n: int, N: int, u: int, v: int, w: int, T: int
    ):
        self.base = base
        self.n = n
        self.T = T
        # the extension must support N + 1 exceptional points (zero skipped)
        min_m = ceil(log(max(N + 1, 2)) / (log(base.p) * base.D))
        self.rmfe = build_rmfe(base, n, min_m=min_m)
        self.ext = self.rmfe.ext
        if self.ext.p**self.ext.D < N + 1:
            raise ValueError(
                f"extension {self.ext} still has < {N + 1} exceptional points"
            )
        self.code = SecureEPCode(self.ext, N, u, v, w, T)

    @property
    def R(self) -> int:
        return self.code.R

    def pack(self, Ms: jnp.ndarray) -> jnp.ndarray:
        """(n, a, b, baseD) -> packed (a, b, extD) via phi positionwise."""
        n, a, b, D = Ms.shape
        assert n == self.rmfe.n, (n, self.rmfe.n)
        return self.rmfe.phi(jnp.moveaxis(Ms, 0, 2))

    def unpack(self, C: jnp.ndarray) -> jnp.ndarray:
        return jnp.moveaxis(self.rmfe.psi(C), 2, 0)

    def decode(self, H: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        return self.unpack(self.code.decode(H, idx))

    def run(self, As, Bs, key, idx=None) -> jnp.ndarray:
        C = self.code.run(self.pack(As), self.pack(Bs), key, idx)
        return self.unpack(C)

    def costs(self, t: int, r: int, s: int) -> EPCosts:
        return self.code.costs(t, r, s, self.base, batch=self.rmfe.n)
