"""Batch-EP_RMFE — the paper's general framework (Fig. 1 + Thm III.2).

A batch of n products {A_i B_i} over GR(p^e, d) is packed positionwise by an
(n, m)-RMFE into ONE product over the extension GR(p^e, dm), which is
computed by any CDMM (EP / Polynomial / MatDot) with recovery threshold
R = uvw + w - 1 — a factor ~1/n smaller than GCSA at matched costs.

The matmul identity that makes Fig. 1 work:  psi is linear and
psi(phi(a)phi(b)) = a*b, so for packed matrices  psi((AB)[i,l]) =
sum_j psi(A[i,j]B[j,l]) = sum_j a_{ij} * b_{jl} = (C_1[i,l], ..., C_n[i,l]).
"""
from __future__ import annotations

from math import ceil, log
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .ep_codes import EPCode, EPCosts, ep_cost_model
from .galois import Ring
from .rmfe import BasicRMFE, ConcatRMFE, build_rmfe

__all__ = ["BatchEPRMFE"]


class BatchEPRMFE:
    """Coded distributed *batch* matrix multiplication via RMFE.

    Args:
      base: the data ring GR(p^e, d) (e.g. Z_{2^32}).
      n: batch size (number of simultaneous products).
      N: number of worker nodes.
      u, v, w: EP partition parameters (w=1 => Polynomial, u=v=1 => MatDot).
    """

    def __init__(self, base: Ring, n: int, N: int, u: int, v: int, w: int):
        self.base = base
        self.n = n
        # the extension must support N exceptional points: p^(D_ext) >= N
        min_m = ceil(log(max(N, 2)) / (log(base.p) * base.D))
        self.rmfe = build_rmfe(base, n, min_m=min_m)
        self.ext = self.rmfe.ext
        if self.ext.p**self.ext.D < N:
            raise ValueError(
                f"extension {self.ext} still has < {N} exceptional points"
            )
        self.code = EPCode(self.ext, N, u, v, w)

    @property
    def R(self) -> int:
        return self.code.R

    # -- packing -------------------------------------------------------------

    def pack(self, Ms: jnp.ndarray) -> jnp.ndarray:
        """(n, a, b, baseD) -> packed (a, b, extD) via phi positionwise."""
        n, a, b, D = Ms.shape
        assert n == self.rmfe.n, (n, self.rmfe.n)
        vecs = jnp.moveaxis(Ms, 0, 2)  # (a, b, n, D)
        return self.rmfe.phi(vecs)  # (a, b, extD)

    def unpack(self, C: jnp.ndarray) -> jnp.ndarray:
        """(a, b, extD) -> (n, a, b, baseD) via psi positionwise."""
        vecs = self.rmfe.psi(C)  # (a, b, n, baseD)
        return jnp.moveaxis(vecs, 2, 0)

    # -- end to end ------------------------------------------------------------

    def run(
        self,
        As: jnp.ndarray,
        Bs: jnp.ndarray,
        idx: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """As: (n, t, r, baseD), Bs: (n, r, s, baseD) -> (n, t, s, baseD)."""
        A = self.pack(As)
        B = self.pack(Bs)
        C = self.code.run(A, B, idx)
        return self.unpack(C)

    # -- encode/worker/decode exposed for the distributed runtime ---------------

    def encode(self, As, Bs):
        A, B = self.pack(As), self.pack(Bs)
        return self.code.encode_a(A), self.code.encode_b(B)

    def worker_compute(self, FA, GB):
        return self.code.worker_compute(FA, GB)

    def decode(self, H, idx):
        return self.unpack(self.code.decode(H, idx))

    def costs(self, t: int, r: int, s: int) -> EPCosts:
        """Amortized per-product costs (Thm III.2), in base-ring elements."""
        return self.code.costs(t, r, s, self.base, batch=self.n)
