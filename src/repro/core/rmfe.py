"""Reverse Multiplication-Friendly Embeddings over Galois rings.

An (n, m)-RMFE over GR = GR(p^e, d) is a pair of GR-linear maps
``phi: GR^n -> GR_m`` and ``psi: GR_m -> GR^n`` with

    psi(phi(x) * phi(y)) = x * y   (elementwise product)

Construction (interpolation-based, [CCXY18]/[CRX21] adapted to digit-lift
exceptional points):

* pick n exceptional points a_1..a_n of GR (requires n <= p^d),
* phi(x) = the coefficient vector of the unique polynomial f_x of degree < n
  with f_x(a_i) = x_i, zero-padded to length m and read as an element of the
  tower GR_m = GR[y]/(g) (i.e. phi(x) = f_x(y)),
* psi(gamma) = evaluations of gamma's coefficient polynomial at a_1..a_n.

Because deg(f_x f_y) <= 2n-2 < m, the product phi(x)phi(y) never wraps mod
g, its tower coefficients are exactly the coefficients of f_x f_y, and
evaluating at a_i gives x_i y_i.  Any m >= 2n-1 works (the tower degree is
auto-bumped by Ring.extend to stay coprime with d; psi reads all m
coefficients so it remains a left inverse on products).

``ConcatRMFE`` composes (n1,m1) over GR(p^e, d*m2) with (n2,m2) over
GR(p^e, d) into an (n1*n2, m1*m2)-RMFE (paper Lemma II.5) — needed when the
base exceptional set is tiny (|T| = 2 for Z_{2^e}).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .galois import Ring
from .polyops import as_u32, s_lagrange_coeff_matrix, s_vandermonde

__all__ = ["BasicRMFE", "ConcatRMFE", "build_rmfe"]


class BasicRMFE:
    """(n, m)-RMFE over ``base`` with m = actual top extension degree."""

    def __init__(self, base: Ring, n: int, min_m: int = 0):
        if n > base.p**base.D:
            raise ValueError(
                f"n={n} exceeds exceptional set size {base.p}^{base.D}; "
                "use ConcatRMFE"
            )
        self.base = base
        self.n = n
        m_req = max(2 * n - 1, min_m, 2)
        self.ext = base.extend(m_req)
        self.m = self.ext.degrees[-1]
        pts = base.exceptional_points(n)
        self.points = pts
        # phi: value vector -> coefficients of interpolating poly (deg < n)
        M = s_lagrange_coeff_matrix(base, pts)  # (n, n, D) object
        self.M_phi = jnp.asarray(as_u32(M))
        # psi: tower coefficients -> evaluations at the n points
        V = s_vandermonde(base, pts, self.m)  # (n, m, D) object
        self.V_psi = jnp.asarray(as_u32(V))

    # phi ---------------------------------------------------------------

    def phi(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (..., n, baseD) -> (..., extD)."""
        base, ext = self.base, self.ext
        batch = x.shape[:-2]
        flat = x.reshape((-1, self.n, base.D))
        flat = jnp.moveaxis(flat, 0, 1)  # (n, B, D)
        coeffs = base.matmul(self.M_phi, flat)  # (n, B, D)
        B = coeffs.shape[1]
        tower = jnp.zeros((B, self.m, base.D), dtype=base.dtype)
        tower = tower.at[:, : self.n, :].set(jnp.moveaxis(coeffs, 0, 1))
        out = ext.from_tower_coeffs(tower)  # (B, extD)
        return out.reshape(batch + (ext.D,))

    # psi ---------------------------------------------------------------

    def psi(self, g: jnp.ndarray) -> jnp.ndarray:
        """g: (..., extD) -> (..., n, baseD)."""
        base, ext = self.base, self.ext
        batch = g.shape[:-1]
        tower = ext.tower_coeffs(g.reshape((-1, ext.D)), base)  # (B, m, D)
        tower = jnp.moveaxis(tower, 0, 1)  # (m, B, D)
        vals = base.matmul(self.V_psi, tower)  # (n, B, D)
        vals = jnp.moveaxis(vals, 0, 1)  # (B, n, D)
        return vals.reshape(batch + (self.n, base.D))


class ConcatRMFE:
    """(n1*n2, m1*m2)-RMFE via Lemma II.5 concatenation."""

    def __init__(self, base: Ring, n2: int, n1: int):
        self.inner = BasicRMFE(base, n2)
        self.outer = BasicRMFE(self.inner.ext, n1)
        self.base = base
        self.ext = self.outer.ext
        self.n = n1 * n2
        self.n1, self.n2 = n1, n2
        self.m = self.inner.m * self.outer.m

    def phi(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (..., n1*n2, baseD) -> (..., extD)."""
        batch = x.shape[:-2]
        xs = x.reshape(batch + (self.n1, self.n2, self.base.D))
        mid = self.inner.phi(xs)  # (..., n1, midD)
        return self.outer.phi(mid)  # (..., extD)

    def psi(self, g: jnp.ndarray) -> jnp.ndarray:
        mid = self.outer.psi(g)  # (..., n1, midD)
        xs = self.inner.psi(mid)  # (..., n1, n2, baseD)
        return xs.reshape(g.shape[:-1] + (self.n, self.base.D))


def build_rmfe(base: Ring, n: int, min_m: int = 0):
    """Choose a Basic or Concat RMFE automatically for batch size n."""
    if n <= base.p**base.D:
        return BasicRMFE(base, n, min_m=min_m)
    # factor n = n2 * n1 with n2 <= |T(base)|
    n2 = base.p**base.D
    n1 = -(-n // n2)
    return ConcatRMFE(base, n2, n1)
