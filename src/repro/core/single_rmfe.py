"""Single-DMM optimizations via RMFE batching — EP_RMFE-I and EP_RMFE-II
(paper §IV, Corollaries IV.1 / IV.2).

Type I  (MatDot-style preprocessing): A -> n column blocks, B -> n row
blocks, AB = sum_i A_i B_i; run Batch-EP_RMFE on the n block products and
sum.  Optimal encoding / upload / worker compute (x1/m vs plain EP).

Type II (Polynomial-style preprocessing): A -> n row blocks, B -> n column
blocks; all n^2 pairwise A_i B_j are needed.  Two RMFE levels:
  - level 1 packs the B blocks:      B_hat = phi1(B_1..B_n)        (inner)
  - level 2 packs the A blocks:      A_hat = phi2(A_1..A_n)        (outer)
  - A_i enters level 1 as a *constant* vector phi1(A_i,..,A_i) = embed(A_i),
    and B_hat enters level 2 as embed(B_hat) = phi2(B_hat,..,B_hat),
so ONE product over the top ring carries all n^2 cross products:
  psi1(psi2(A_hat * B_hat)[i]) = (A_i B_1, ..., A_i B_n).
Optimal decoding / download (x1/m vs plain EP), upload x sqrt(m).
"""
from __future__ import annotations

from math import ceil, log
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .ep_codes import EPCode, EPCosts, ep_cost_model
from .galois import Ring
from .rmfe import BasicRMFE

__all__ = ["EPRMFE_I", "EPRMFE_II"]


class EPRMFE_I:
    """Single DMM, MatDot-style batch preprocessing (Cor IV.1)."""

    def __init__(self, base: Ring, n: int, N: int, u: int, v: int, w: int):
        from .batch_rmfe import BatchEPRMFE

        self.base, self.n = base, n
        self.batch = BatchEPRMFE(base, n, N, u, v, w)
        self.ext = self.batch.ext
        self.code = self.batch.code

    @property
    def R(self) -> int:
        return self.batch.R

    def split_a(self, A: jnp.ndarray) -> jnp.ndarray:
        """(t, r, D) -> n column blocks (n, t, r/n, D)."""
        t, r, D = A.shape
        assert r % self.n == 0, f"n={self.n} must divide r={r}"
        return jnp.moveaxis(A.reshape(t, self.n, r // self.n, D), 1, 0)

    def split_b(self, B: jnp.ndarray) -> jnp.ndarray:
        """(r, s, D) -> n row blocks (n, r/n, s, D)."""
        r, s, D = B.shape
        assert r % self.n == 0, f"n={self.n} must divide r={r}"
        return B.reshape(self.n, r // self.n, s, D)

    def split(self, A: jnp.ndarray, B: jnp.ndarray):
        return self.split_a(A), self.split_b(B)

    def run(self, A, B, idx: Optional[jnp.ndarray] = None):
        As, Bs = self.split(A, B)
        Cs = self.batch.run(As, Bs, idx)  # (n, t, s, D)
        acc = Cs[0]
        for i in range(1, self.n):
            acc = self.base.add(acc, Cs[i])
        return acc

    def costs(self, t: int, r: int, s: int) -> EPCosts:
        # one EP run on (t, r/n, s) computes the ONE output product: the
        # r-dim shrink already carries the 1/n upload/encode/worker saving
        # (Cor IV.1), and download/decoding are not amortized at all
        return self.batch.code.costs(t, r // self.n, s, self.base, batch=1)


class EPRMFE_II:
    """Single DMM, Polynomial-style batch preprocessing, two-level RMFE
    (Cor IV.2).

    ``split_a=False`` reproduces the configuration the paper actually
    measured (§V: "we did not split matrix A ... and applied only phi_1"
    for small m): only B is column-split and packed; A is embedded.  This
    halves download/decoding at upload between plain-EP and type-I.
    """

    def __init__(
        self, base: Ring, n: int, N: int, u: int, v: int, w: int,
        split_a: bool = True,
    ):
        self.base, self.n = base, n
        self.split_a = split_a
        # level 1 over the base, level 2 over the mid ring
        min_m1 = ceil(log(max(N, 2)) / (log(base.p) * base.D)) if not split_a else 0
        self.rmfe1 = BasicRMFE(base, n, min_m=min_m1)
        self.mid = self.rmfe1.ext
        if split_a:
            min_m2 = ceil(log(max(N, 2)) / (log(base.p) * self.mid.D))
            self.rmfe2 = BasicRMFE(self.mid, n, min_m=min_m2)
            self.top = self.rmfe2.ext
        else:
            self.rmfe2 = None
            self.top = self.mid
        if self.top.p**self.top.D < N:
            raise ValueError("top extension too small for N workers")
        self.code = EPCode(self.top, N, u, v, w)

    @property
    def R(self) -> int:
        return self.code.R

    def pack_a(self, A: jnp.ndarray) -> jnp.ndarray:
        """A (t, r, baseD) -> (t/n, r, topD): row blocks through phi2∘embed.

        With split_a=False: A is embedded whole (paper §V configuration)."""
        t, r, D = A.shape
        n = self.n
        if not self.split_a:
            return self.top.embed_base(A, self.base)  # (t, r, topD)
        assert t % n == 0
        blocks = A.reshape(n, t // n, r, D)  # row blocks
        mid_blocks = self.mid.embed_base(blocks, self.base)  # phi1(const) = embed
        vecs = jnp.moveaxis(mid_blocks, 0, 2)  # (t/n, r, n, midD)
        return self.rmfe2.phi(vecs)  # (t/n, r, topD)

    def pack_b(self, B: jnp.ndarray) -> jnp.ndarray:
        """B (r, s, baseD) -> (r, s/n, topD): col blocks through embed∘phi1."""
        r, s, D = B.shape
        n = self.n
        assert s % n == 0
        blocks = B.reshape(r, n, s // n, D)
        vecs = jnp.moveaxis(blocks, 1, 2)  # (r, s/n, n, baseD)
        mid = self.rmfe1.phi(vecs)  # (r, s/n, midD)
        return self.top.embed_base(mid, self.mid)  # (r, s/n, topD)

    def unpack(self, C: jnp.ndarray) -> jnp.ndarray:
        """(t/n, s/n, topD) -> (t, s, baseD) assembling the n x n block grid."""
        tb, sb, _ = C.shape
        n = self.n
        if not self.split_a:
            outs = self.rmfe1.psi(C)  # (t, s/n, n_j, baseD)
            grid = outs.transpose(0, 2, 1, 3)  # (t, n_j, s/n, D)
            return grid.reshape(tb, n * sb, self.base.D)
        mids = self.rmfe2.psi(C)  # (t/n, s/n, n_i, midD)
        outs = self.rmfe1.psi(mids)  # (t/n, s/n, n_i, n_j, baseD)
        # C block (i, j) = A_i B_j at row block i, col block j
        grid = outs.transpose(2, 0, 3, 1, 4)  # (n_i, t/n, n_j, s/n, D)
        return grid.reshape(n * tb, n * sb, self.base.D)

    def run(self, A, B, idx: Optional[jnp.ndarray] = None):
        Ah, Bh = self.pack_a(A), self.pack_b(B)
        C = self.code.run(Ah, Bh, idx)
        return self.unpack(C)

    def costs(self, t: int, r: int, s: int) -> EPCosts:
        # one EP execution over the top ring: on (t/n, r, s/n) when A is
        # split, on (t, r, s/n) in the paper's split_a=False configuration
        ta = t // self.n if self.split_a else t
        return self.code.costs(ta, r, s // self.n, self.base, batch=1)
