"""One documented accessor for every ``REPRO_*`` environment knob.

Env-var reads used to be scattered: ``REPRO_CALIBRATION`` in
``cdmm/calibrate.py``, ``REPRO_DEBUG_SOLVE`` in ``core/gcsa.py``,
``REPRO_CONFORMANCE_INPROC`` in the conformance suite, the deprecated
``REPRO_POOL_WORKERS`` shim in ``dist/config.py``, and the tracing
switch nowhere at all.  This module is the single registry: every knob
has a name, an env var, a typed default and a one-line doc, and every
consumer goes through :func:`get` so ``python -m repro.settings`` (or
:func:`describe`) always prints the true, complete list.

The module imports nothing heavy (no jax, no numpy) so config-time code
— worker entrypoints, ``PoolConfig.from_env`` — can use it freely.

Booleans parse ``1/true/yes/on`` as True (case-insensitive); everything
else, including the empty string, is False.  A knob with
``legacy_env`` set falls back to the old variable and emits one
``DeprecationWarning`` per process via :func:`warn_deprecated_once`.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Dict, Mapping, Optional

__all__ = [
    "SETTINGS",
    "Setting",
    "describe",
    "get",
    "get_bool",
    "get_float",
    "get_int",
    "warn_deprecated_once",
]

# deprecation shims warn once per process per form, even under test
# harnesses that reset the warnings filters (``repro.dist.config``
# re-exports this set so legacy imports keep working)
_WARNED: set = set()

_TRUTHY = {"1", "true", "yes", "on"}


def warn_deprecated_once(key: str, message: str) -> None:
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


@dataclass(frozen=True)
class Setting:
    """One environment knob: where it comes from and what it defaults to."""

    name: str  # accessor name (settings.get(name))
    env: str  # environment variable
    kind: str  # "bool" | "int" | "float" | "str"
    default: object
    doc: str  # one line, printed by describe()
    legacy_env: Optional[str] = None  # deprecated fallback variable


SETTINGS: Dict[str, Setting] = {
    s.name: s
    for s in (
        Setting(
            "calibration", "REPRO_CALIBRATION", "str", None,
            "planner calibration source: a JSON path, or off/0/none for "
            "the analytic proxy (default: committed "
            "benchmarks/calibration.json)",
        ),
        Setting(
            "debug_solve", "REPRO_DEBUG_SOLVE", "bool", False,
            "run-time duplicate-live-set checks inside jitted decode "
            "paths via jax.debug.callback",
        ),
        Setting(
            "conformance_inproc", "REPRO_CONFORMANCE_INPROC", "bool", False,
            "run the conformance sweep fine-grained in-process instead of "
            "the subprocess-sharded quarantine variant",
        ),
        Setting(
            "trace", "REPRO_TRACE", "bool", False,
            "enable repro.obs request tracing (spans recorded to the "
            "process-local ring buffer; also via --trace flags / "
            "repro.obs.set_enabled)",
        ),
        Setting(
            "trace_buffer", "REPRO_TRACE_BUFFER", "int", 8192,
            "ring-buffer capacity (spans) of the process-local "
            "repro.obs tracer",
        ),
        Setting(
            "dist_workers", "REPRO_DIST_WORKERS", "int", None,
            "worker count for pools built from the environment "
            "(PoolConfig.from_env)", legacy_env="REPRO_POOL_WORKERS",
        ),
        Setting(
            "dist_transport", "REPRO_DIST_TRANSPORT", "str", None,
            "wire codec for pools built from the environment: auto, raw, "
            "pack, pack+zlib, pack+zstd",
        ),
        Setting(
            "dist_hostfile", "REPRO_DIST_HOSTFILE", "str", None,
            "hostfile (path or literal text) for pools built from the "
            "environment",
        ),
        Setting(
            "dist_master_addr", "REPRO_DIST_MASTER_ADDR", "str", None,
            "master endpoint (tcp:HOST:PORT or unix:/path) for pools "
            "built from the environment / rank-wired launches",
        ),
        Setting(
            "dist_stream_chunk", "REPRO_DIST_STREAM_CHUNK", "int", None,
            "share-streaming chunk size in bytes for pools built from "
            "the environment (0 disables pipelining)",
        ),
        Setting(
            "pool_log", "REPRO_POOL_LOG", "bool", False,
            "let spawned worker/agent stderr through instead of "
            "discarding it (pool debugging)",
        ),
        Setting(
            "obs_http_port", "REPRO_OBS_HTTP_PORT", "int", None,
            "serve the live telemetry plane (/metrics /healthz /stats "
            "/trace) on this port (0 = ephemeral; unset = no server)",
        ),
        Setting(
            "hedge_factor", "REPRO_HEDGE_FACTOR", "float", 0.0,
            "speculatively re-dispatch a share outstanding past "
            "p95(recent share round-trips) x this factor to a healthy "
            "worker; first valid reply wins (0 = never hedge)",
        ),
        Setting(
            "health_ewma", "REPRO_HEALTH_EWMA", "float", 0.2,
            "EWMA smoothing factor of the per-worker health signals "
            "(share round-trip + heartbeat jitter)",
        ),
        Setting(
            "obs_retention", "REPRO_OBS_RETENTION", "float", 300.0,
            "retention window in seconds of the time-series metrics "
            "behind windowed quantiles (hedge deadlines, /metrics "
            "window gauges)",
        ),
    )
}


def _parse(setting: Setting, raw: str):
    if setting.kind == "bool":
        return raw.strip().lower() in _TRUTHY
    if setting.kind == "int":
        return int(raw)
    if setting.kind == "float":
        return float(raw)
    return raw


def get(name: str, env: Mapping[str, str] = os.environ):
    """The effective value of setting ``name``: the env var parsed per its
    kind, the deprecated legacy variable (one warning) as fallback, else
    the documented default."""
    setting = SETTINGS[name]
    raw = env.get(setting.env)
    if raw is None and setting.legacy_env is not None:
        raw = env.get(setting.legacy_env)
        if raw is not None:
            warn_deprecated_once(
                setting.legacy_env,
                f"{setting.legacy_env} is deprecated; set {setting.env} "
                f"instead",
            )
    if raw is None:
        return setting.default
    return _parse(setting, raw)


def get_bool(name: str, env: Mapping[str, str] = os.environ) -> bool:
    return bool(get(name, env))


def get_int(name: str, env: Mapping[str, str] = os.environ) -> Optional[int]:
    val = get(name, env)
    return val if val is None else int(val)


def get_float(
    name: str, env: Mapping[str, str] = os.environ
) -> Optional[float]:
    val = get(name, env)
    return val if val is None else float(val)


def describe() -> str:
    """One line per knob: env var, default, doc (the README table's source
    of truth)."""
    lines = []
    for s in SETTINGS.values():
        default = "unset" if s.default is None else repr(s.default)
        legacy = f" (legacy: {s.legacy_env})" if s.legacy_env else ""
        lines.append(f"{s.env}{legacy} [{s.kind}, default {default}]: {s.doc}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(describe())
