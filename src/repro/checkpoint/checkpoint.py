"""Sharded checkpointing with async save and elastic (re-mesh) restore.

Format: <dir>/step_<k>/
    manifest.json            — step, flat key list, shapes/dtypes
    <i>.npz                  — chunked flat arrays (host-gathered)

Restore takes an OPTIONAL target mesh + sharding tree: arrays are loaded on
host and device_put with the new shardings — i.e. a checkpoint written on a
(16,16) mesh restores onto (2,16,16) or a degraded (15,16) mesh unchanged
(elastic scaling / failed-node replacement).  Data-pipeline state is just the
step integer (deterministic replay).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "//"


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
        return out
    return {_SEP.join(prefix): tree}


def _unflatten(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Dict, blocking: bool = True):
        """Host-gather and write; async when blocking=False."""
        flat = _flatten(tree)
        host = {}
        dtypes = {}
        for k, v in flat.items():
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                # npz can't serialize bf16 natively: store the raw bits
                a = a.view(np.uint16)
            host[k] = a

        def write():
            d = os.path.join(self.dir, f"step_{step:08d}")
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "0.npz"), **host)
            manifest = {
                "step": step,
                "keys": sorted(host),
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": dtypes,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, d)  # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            d = os.path.join(self.dir, f"step_{s:08d}")
            for root, dirs, files in os.walk(d, topdown=False):
                for fn in files:
                    os.remove(os.path.join(root, fn))
                os.rmdir(root)

    # -- restore ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        shardings: Optional[Dict] = None,
    ) -> Dict:
        """Load a checkpoint; optionally device_put with NEW shardings
        (elastic re-mesh)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "0.npz"))
        flat = {}
        for k in manifest["keys"]:
            a = data[k]
            if manifest["dtypes"].get(k) == "bfloat16":
                import ml_dtypes

                a = a.view(ml_dtypes.bfloat16)
            flat[k] = a
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            tree = _unflatten(
                {
                    k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                    for k, v in _flatten(tree).items()
                }
            )
        return tree
