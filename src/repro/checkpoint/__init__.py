"""Sharded checkpointing, async save, elastic restore."""
from .checkpoint import Checkpointer
