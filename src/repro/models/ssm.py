"""Mamba2 (SSD) language model and the Zamba2 hybrid (Mamba2 + shared
attention block every k layers)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.runtime.sharding import ParamSpec, shard

from .layers import (
    apply_mlp,
    apply_norm,
    apply_ssd,
    attention_block,
    attention_specs,
    mlp_specs,
    norm_specs,
    softcap,
    ssd_specs,
)
from .transformer import _maybe_remat, stack_specs

# ---------------------------------------------------------------------------
# Mamba2 LM
# ---------------------------------------------------------------------------


def mamba_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    unit = {"ln": norm_specs(cfg, d), "ssd": ssd_specs(cfg)}
    return {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "fsdp"), init="embed", scale=0.02),
        "blocks": stack_specs(unit, cfg.num_layers),
        "ln_f": norm_specs(cfg, d),
    }


def mamba_cache_specs(cfg: ModelConfig, batch: int) -> Dict:
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    conv_dim = di + 2 * N
    U = cfg.num_layers
    return {
        "conv": ParamSpec(
            (U, batch, cfg.ssm_conv - 1, conv_dim), (None, "batch", None, "ffn")
        ),
        "state": ParamSpec(
            (U, batch, H, N, cfg.ssm_head_dim),
            (None, "batch", "ssm_heads", "state", None),
            jnp.float32,
        ),
    }


def mamba_forward(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    x = shard(x, "batch", "seq", None)

    def unit(carry, xs):
        x, _aux = carry
        up, ucache = xs
        h, nc = apply_ssd(up["ssd"], apply_norm(up["ln"], x, cfg), cfg, cache=ucache)
        return (x + h, _aux), nc

    unit = _maybe_remat(unit, cfg)
    if cache is None:
        (x, _), _ = lax.scan(
            lambda c, up: (unit(c, (up, None))[0], None),
            (x, jnp.zeros((), jnp.float32)),
            params["blocks"],
        )
        new_cache = None
    else:
        (x, _), ncs = lax.scan(
            unit, (x, jnp.zeros((), jnp.float32)), (params["blocks"], cache)
        )
        new_cache = ncs
    x = apply_norm(params["ln_f"], x, cfg)
    logits = x @ params["embed"].T.astype(cfg.adtype)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return shard(logits, "batch", "seq", "vocab"), new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Zamba2 hybrid
# ---------------------------------------------------------------------------


def _shared_block_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    return {
        "ln_in": norm_specs(cfg, 2 * d),
        "proj_in": ParamSpec((2 * d, d), ("fsdp", None)),
        "ln_attn": norm_specs(cfg, d),
        "attn": attention_specs(cfg),
        "ln_mlp": norm_specs(cfg, d),
        "mlp": mlp_specs(cfg, d, cfg.d_ff),
    }


def zamba_units(cfg: ModelConfig) -> Tuple[int, int]:
    U = cfg.num_layers // cfg.shared_attn_every
    tail = cfg.num_layers % cfg.shared_attn_every
    return U, tail


def zamba_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    U, tail = zamba_units(cfg)
    munit = {
        f"m{i}": {"ln": norm_specs(cfg, d), "ssd": ssd_specs(cfg)}
        for i in range(cfg.shared_attn_every)
    }
    specs = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "fsdp"), init="embed", scale=0.02),
        "blocks": stack_specs(munit, U),
        "shared": _shared_block_specs(cfg),  # ONE set of attn params, reused U times
        "ln_f": norm_specs(cfg, d),
    }
    if tail:
        specs["tail"] = {
            f"t{i}": {"ln": norm_specs(cfg, d), "ssd": ssd_specs(cfg)}
            for i in range(tail)
        }
    return specs


def zamba_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    conv_dim = di + 2 * N
    U, tail = zamba_units(cfg)
    KV, hd = cfg.num_kv_heads, cfg.hd
    E = cfg.shared_attn_every
    c: Dict[str, Any] = {
        "conv": ParamSpec((U, E, batch, cfg.ssm_conv - 1, conv_dim), (None, None, "batch", None, "ffn")),
        "state": ParamSpec(
            (U, E, batch, H, N, cfg.ssm_head_dim),
            (None, None, "batch", "ssm_heads", "state", None), jnp.float32,
        ),
        # per-application KV cache for the shared attention block
        "shared_k": ParamSpec((U, batch, cache_len, KV, hd), (None, "batch", "cache_seq", "kv_heads", None)),
        "shared_v": ParamSpec((U, batch, cache_len, KV, hd), (None, "batch", "cache_seq", "kv_heads", None)),
    }
    if tail:
        c["tail_conv"] = ParamSpec((tail, batch, cfg.ssm_conv - 1, conv_dim), (None, "batch", None, "ffn"))
        c["tail_state"] = ParamSpec(
            (tail, batch, H, N, cfg.ssm_head_dim),
            (None, "batch", "ssm_heads", "state", None), jnp.float32,
        )
    return c


def zamba_forward(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    B, S = tokens.shape
    U, tail = zamba_units(cfg)
    E = cfg.shared_attn_every
    x0 = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    x0 = shard(x0, "batch", "seq", None)
    start = cache_len if cache_len is not None else jnp.zeros((), jnp.int32)
    positions = start + jnp.arange(S, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (B, S))
    shared = params["shared"]

    def shared_apply(x, kcache):
        """Shared attention block on concat(x, x0) (Zamba wiring)."""
        h = jnp.concatenate([x, x0], axis=-1)
        h = apply_norm(shared["ln_in"], h, cfg) @ shared["proj_in"]
        a, nc = attention_block(
            shared["attn"], apply_norm(shared["ln_attn"], h, cfg), positions, cfg,
            layer_type="global", cache=kcache,
        )
        h = h + a
        h = h + apply_mlp(shared["mlp"], apply_norm(shared["ln_mlp"], h, cfg), cfg)
        return x + h, nc

    def unit(carry, xs):
        x, _ = carry
        up, ucache = xs
        ncs = {} if ucache is not None else None
        for i in range(E):
            lc = None
            if ucache is not None:
                lc = {"conv": ucache["conv"][i], "state": ucache["state"][i]}
            h, nc = apply_ssd(up[f"m{i}"]["ssd"], apply_norm(up[f"m{i}"]["ln"], x, cfg), cfg, cache=lc)
            x = x + h
            if ncs is not None:
                ncs.setdefault("conv", []).append(nc["conv"])
                ncs.setdefault("state", []).append(nc["state"])
        kcache = None
        if ucache is not None:
            kcache = {"k": ucache["shared_k"], "v": ucache["shared_v"], "len": ucache["len"]}
        x, knc = shared_apply(x, kcache)
        out_cache = None
        if ncs is not None:
            out_cache = {
                "conv": jnp.stack(ncs["conv"]),
                "state": jnp.stack(ncs["state"]),
                "shared_k": knc["k"],
                "shared_v": knc["v"],
            }
        return (x, carry[1]), out_cache

    unit = _maybe_remat(unit, cfg)
    if cache is None:
        (x, _), _ = lax.scan(
            lambda c, up: (unit(c, (up, None))[0], None),
            (x0, jnp.zeros((), jnp.float32)),
            params["blocks"],
        )
        new_cache = None
    else:
        xs_cache = {
            "conv": cache["conv"],
            "state": cache["state"],
            "shared_k": cache["shared_k"],
            "shared_v": cache["shared_v"],
            "len": jnp.broadcast_to(cache["len"], (U,)),
        }
        (x, _), ncs = lax.scan(unit, (x0, jnp.zeros((), jnp.float32)), (params["blocks"], xs_cache))
        new_cache = dict(ncs)
        new_cache["len"] = cache["len"] + S
    # tail mamba layers (unscanned)
    if tail:
        new_tc, new_ts = [], []
        for i in range(tail):
            tp = params["tail"][f"t{i}"]
            lc = None
            if cache is not None:
                lc = {"conv": cache["tail_conv"][i], "state": cache["tail_state"][i]}
            h, nc = apply_ssd(tp["ssd"], apply_norm(tp["ln"], x, cfg), cfg, cache=lc)
            x = x + h
            if cache is not None:
                new_tc.append(nc["conv"])
                new_ts.append(nc["state"])
        if cache is not None:
            new_cache["tail_conv"] = jnp.stack(new_tc)
            new_cache["tail_state"] = jnp.stack(new_ts)
    x = apply_norm(params["ln_f"], x, cfg)
    logits = x @ params["embed"].T.astype(cfg.adtype)
    return shard(logits, "batch", "seq", "vocab"), new_cache, jnp.zeros((), jnp.float32)
