"""Uniform model API: every architecture exposes

    param_specs()                  -> ParamSpec tree
    loss_fn(params, batch)         -> (loss, metrics)        [train_step target]
    prefill_fn(params, batch)      -> logits                 [prefill cells]
    decode_fn(params, cache, batch)-> (logits, new_cache)    [decode cells]
    batch_specs(shape)             -> input ParamSpec tree (ShapeDtypeStruct-able)
    cache_decl(shape)              -> cache ParamSpec tree + scalar "len"

so the launcher / dry-run treat all 10 archs identically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.runtime.sharding import ParamSpec

from . import ssm as ssm_mod
from . import transformer as tf_mod

AUX_WEIGHT = 0.01


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked CE; labels < 0 are ignored.  logits (B,S,V) f32, labels (B,S)."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / denom, denom


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    param_specs: Dict
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    batch_specs: Callable[[ShapeConfig], Dict]
    cache_decl: Callable[[ShapeConfig], Dict]


# ---------------------------------------------------------------------------
# input declarations
# ---------------------------------------------------------------------------


def _lm_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    tok = ("batch", "seq")
    if shape.kind == "train":
        return {
            "tokens": ParamSpec((B, S), tok, jnp.int32),
            "labels": ParamSpec((B, S), tok, jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": ParamSpec((B, S), tok, jnp.int32)}
    # decode: one new token against a cache of length S
    return {"tokens": ParamSpec((B, 1), ("batch", None), jnp.int32)}


def _vlm_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    base = _lm_batch_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        B = shape.global_batch
        P = cfg.frontend_len
        S_text = shape.seq_len - P
        base["tokens"] = ParamSpec((B, S_text), ("batch", "seq"), jnp.int32)
        if "labels" in base:
            base["labels"] = ParamSpec((B, S_text), ("batch", "seq"), jnp.int32)
        base["patches"] = ParamSpec(
            (B, P, cfg.frontend_dim), ("batch", None, None), jnp.float32
        )
    return base


def _encdec_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    Ssrc = max(S // cfg.src_ratio, 16)
    base = _lm_batch_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        base["frames"] = ParamSpec(
            (B, Ssrc, cfg.frontend_dim), ("batch", "seq", None), jnp.float32
        )
    return base


# ---------------------------------------------------------------------------
# family builders
# ---------------------------------------------------------------------------


def _build_decoder_family(cfg: ModelConfig) -> ModelAPI:
    specs = tf_mod.decoder_specs(cfg)
    is_vlm = cfg.family == "vlm"
    is_encdec = cfg.family == "encdec"

    def loss_fn(params, batch):
        kw = {}
        if is_vlm:
            kw["patches"] = batch["patches"]
        if is_encdec:
            enc_out = tf_mod.encoder_forward(params, batch["frames"], cfg)
            B, Ssrc = enc_out.shape[:2]
            kw["enc_out"] = enc_out
            kw["src_positions"] = jnp.broadcast_to(
                jnp.arange(Ssrc, dtype=jnp.int32)[None], (B, Ssrc)
            )
        logits, _, aux = tf_mod.decoder_forward(params, batch["tokens"], cfg, **kw)
        if is_vlm:
            logits = logits[:, cfg.frontend_len :]
        loss, ntok = cross_entropy(logits, batch["labels"])
        total = loss + AUX_WEIGHT * aux
        return total, {"ce": loss, "aux": aux, "ntok": ntok}

    def prefill_fn(params, batch):
        kw = {}
        if is_vlm:
            kw["patches"] = batch["patches"]
        if is_encdec:
            enc_out = tf_mod.encoder_forward(params, batch["frames"], cfg)
            B, Ssrc = enc_out.shape[:2]
            kw["enc_out"] = enc_out
            kw["src_positions"] = jnp.broadcast_to(
                jnp.arange(Ssrc, dtype=jnp.int32)[None], (B, Ssrc)
            )
        logits, _, _ = tf_mod.decoder_forward(params, batch["tokens"], cfg, **kw)
        return logits[:, -1:]

    def decode_fn(params, cache, batch):
        logits, new_cache, _ = tf_mod.decoder_forward(
            params, batch["tokens"], cfg, cache=cache, cache_len=cache["len"]
        )
        return logits, new_cache

    def cache_decl(shape: ShapeConfig):
        B = shape.global_batch
        Ssrc = max(shape.seq_len // cfg.src_ratio, 16) if is_encdec else 0
        decl = tf_mod.cache_specs(cfg, B, shape.seq_len, src_len=Ssrc)
        decl["len"] = ParamSpec((), (), jnp.int32, "zeros")
        return decl

    bspecs = (
        _vlm_batch_specs if is_vlm else _encdec_batch_specs if is_encdec else _lm_batch_specs
    )
    return ModelAPI(
        cfg, specs, loss_fn, prefill_fn, decode_fn,
        lambda s: bspecs(cfg, s), cache_decl,
    )


def _build_mamba(cfg: ModelConfig) -> ModelAPI:
    specs = ssm_mod.mamba_specs(cfg)

    def loss_fn(params, batch):
        logits, _, _ = ssm_mod.mamba_forward(params, batch["tokens"], cfg)
        loss, ntok = cross_entropy(logits, batch["labels"])
        return loss, {"ce": loss, "ntok": ntok}

    def prefill_fn(params, batch):
        logits, _, _ = ssm_mod.mamba_forward(params, batch["tokens"], cfg)
        return logits[:, -1:]

    def decode_fn(params, cache, batch):
        logits, new_cache, _ = ssm_mod.mamba_forward(
            params, batch["tokens"], cfg, cache=cache
        )
        return logits, new_cache

    def cache_decl(shape: ShapeConfig):
        return ssm_mod.mamba_cache_specs(cfg, shape.global_batch)

    return ModelAPI(
        cfg, specs, loss_fn, prefill_fn, decode_fn,
        lambda s: _lm_batch_specs(cfg, s), cache_decl,
    )


def _build_zamba(cfg: ModelConfig) -> ModelAPI:
    specs = ssm_mod.zamba_specs(cfg)

    def loss_fn(params, batch):
        logits, _, _ = ssm_mod.zamba_forward(params, batch["tokens"], cfg)
        loss, ntok = cross_entropy(logits, batch["labels"])
        return loss, {"ce": loss, "ntok": ntok}

    def prefill_fn(params, batch):
        logits, _, _ = ssm_mod.zamba_forward(params, batch["tokens"], cfg)
        return logits[:, -1:]

    def decode_fn(params, cache, batch):
        logits, new_cache, _ = ssm_mod.zamba_forward(
            params, batch["tokens"], cfg, cache=cache, cache_len=cache["len"]
        )
        return logits, new_cache

    def cache_decl(shape: ShapeConfig):
        decl = ssm_mod.zamba_cache_specs(cfg, shape.global_batch, shape.seq_len)
        decl["len"] = ParamSpec((), (), jnp.int32, "zeros")
        return decl

    return ModelAPI(
        cfg, specs, loss_fn, prefill_fn, decode_fn,
        lambda s: _lm_batch_specs(cfg, s), cache_decl,
    )


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "ssm":
        return _build_mamba(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    return _build_decoder_family(cfg)
