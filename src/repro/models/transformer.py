"""Decoder-only transformer (dense + MoE + VLM prefix) and encoder-decoder.

Compile-time discipline: layers are grouped into the config's repeating
``layer_pattern`` unit and scanned (stacked params), so a 95-layer model
lowers as one scan — essential for the 512-device dry-run compiles.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.runtime.sharding import ParamSpec, shard

from .layers import (
    apply_mlp,
    apply_moe,
    apply_norm,
    attention_block,
    attention_specs,
    mlp_specs,
    moe_specs,
    norm_specs,
    softcap,
)

# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------


def stack_specs(tree, n: int):
    """Prepend a scanned-units dim to every ParamSpec leaf."""
    if isinstance(tree, dict):
        return {k: stack_specs(v, n) for k, v in tree.items()}
    ps: ParamSpec = tree
    return ParamSpec((n,) + ps.shape, (None,) + ps.logical, ps.dtype, ps.init, ps.scale)


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def num_units(cfg: ModelConfig) -> int:
    pat = len(cfg.layer_pattern)
    layers = cfg.num_layers - cfg.first_k_dense
    assert layers % pat == 0, (cfg.name, layers, pat)
    return layers // pat


def _sub_block_specs(cfg: ModelConfig, moe: bool) -> Dict:
    d = cfg.d_model
    p = {
        "ln_attn": norm_specs(cfg, d),
        "attn": attention_specs(cfg),
        "ln_mlp": norm_specs(cfg, d),
    }
    if moe:
        p["moe"] = moe_specs(cfg)
    else:
        p["mlp"] = mlp_specs(cfg, d, cfg.d_ff)
    return p


def decoder_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    moe = cfg.family == "moe"
    unit = {
        f"l{i}": _sub_block_specs(cfg, moe) for i in range(len(cfg.layer_pattern))
    }
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "fsdp"), init="embed", scale=0.02),
        "blocks": stack_specs(unit, num_units(cfg)),
        "ln_f": norm_specs(cfg, d),
    }
    if cfg.first_k_dense:
        specs["prefix"] = {
            f"p{i}": _sub_block_specs(cfg, moe=False) for i in range(cfg.first_k_dense)
        }
    if cfg.frontend == "patch":
        specs["frontend_proj"] = ParamSpec((cfg.frontend_dim, d), ("frontend", "fsdp"))
    if cfg.family == "encdec":
        enc_unit = {"l0": _sub_block_specs(cfg, moe=False)}
        specs["encoder"] = {
            "blocks": stack_specs(enc_unit, cfg.encoder_layers),
            "ln_f": norm_specs(cfg, d),
            "frontend_proj": ParamSpec((cfg.frontend_dim, d), ("frontend", "fsdp")),
        }
        for i in range(len(cfg.layer_pattern)):
            unit[f"l{i}"]["ln_xattn"] = norm_specs(cfg, d)
            unit[f"l{i}"]["xattn"] = attention_specs(cfg)
        specs["blocks"] = stack_specs(unit, num_units(cfg))
    return specs


# ---------------------------------------------------------------------------
# cache declaration
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int, src_len: int = 0) -> Dict:
    KV, hd = cfg.num_kv_heads, cfg.hd
    U = num_units(cfg)
    L = len(cfg.layer_pattern)
    kv = lambda n: {
        "k": ParamSpec((n, batch, cache_len, KV, hd), (None, "batch", "cache_seq", "kv_heads", None)),
        "v": ParamSpec((n, batch, cache_len, KV, hd), (None, "batch", "cache_seq", "kv_heads", None)),
    }
    c: Dict[str, Any] = {f"l{i}": kv(U) for i in range(L)}
    if cfg.first_k_dense:
        c["prefix"] = {
            f"p{i}": {
                "k": ParamSpec((batch, cache_len, KV, hd), ("batch", "cache_seq", "kv_heads", None)),
                "v": ParamSpec((batch, cache_len, KV, hd), ("batch", "cache_seq", "kv_heads", None)),
            }
            for i in range(cfg.first_k_dense)
        }
    if cfg.family == "encdec":
        # cross-attention K/V computed once from the encoder output
        c["xkv"] = {
            f"l{i}": {
                "k": ParamSpec((U, batch, src_len, KV, hd), (None, "batch", "cache_seq", "kv_heads", None)),
                "v": ParamSpec((U, batch, src_len, KV, hd), (None, "batch", "cache_seq", "kv_heads", None)),
            }
            for i in range(L)
        }
    return c


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _one_layer(
    lp: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    layer_type: str,
    lcache: Optional[Dict],
    xattn_kv=None,
):
    """pre-LN attention + (moe|mlp); returns (x, new_cache, aux)."""
    h, new_cache = attention_block(
        lp["attn"], apply_norm(lp["ln_attn"], x, cfg), positions, cfg,
        layer_type=layer_type, cache=lcache,
    )
    x = x + h
    if xattn_kv is not None:
        hx, _ = attention_block(
            lp["xattn"], apply_norm(lp["ln_xattn"], x, cfg), positions, cfg,
            layer_type="global", causal=False, xattn_kv=xattn_kv,
        )
        x = x + hx
    aux = jnp.zeros((), jnp.float32)
    h2in = apply_norm(lp["ln_mlp"], x, cfg)
    if "moe" in lp:
        h2, aux = apply_moe(lp["moe"], h2in, cfg)
    else:
        h2 = apply_mlp(lp["mlp"], h2in, cfg)
    return x + h2, new_cache, aux


def _unit_fn(cfg: ModelConfig, positions, encdec_xkv_from=None):
    """Builds the scanned unit function: carry=(x, aux), xs=(params, cache)."""
    L = len(cfg.layer_pattern)

    def unit(carry, xs):
        x, aux = carry
        up, ucache = xs
        new_cache = {} if ucache is not None else None
        for i, lt in enumerate(cfg.layer_pattern):
            lc = ucache[f"l{i}"] if ucache is not None else None
            if lc is not None and "len" not in lc:
                lc = dict(lc, len=ucache["len"])
            xkv = None
            if encdec_xkv_from is not None:
                xk = up[f"l{i}"].get("xattn") is not None
                if xk:
                    xkv = encdec_xkv_from(up[f"l{i}"], i, ucache)
            x, nc, a = _one_layer(up[f"l{i}"], x, positions, cfg, lt, lc, xkv)
            aux = aux + a
            if new_cache is not None:
                new_cache[f"l{i}"] = {"k": nc["k"], "v": nc["v"]}
        return (x, aux), new_cache

    return unit


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def decoder_forward(
    params: Dict,
    tokens: jnp.ndarray,  # (B, S) int32
    cfg: ModelConfig,
    *,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
    patches: Optional[jnp.ndarray] = None,  # (B, P, frontend_dim) for VLM
    enc_out: Optional[jnp.ndarray] = None,  # (B, Ssrc, d) for enc-dec
    src_positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (logits, new_cache, aux_loss)."""
    B, S = tokens.shape
    d = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    start = cache_len if cache_len is not None else jnp.zeros((), jnp.int32)
    if patches is not None:
        pe = (patches.astype(cfg.adtype) @ params["frontend_proj"]).astype(cfg.adtype)
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
    x = shard(x, "batch", "residual_seq", None)
    positions = start + jnp.arange(S, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (B, S))
    aux = jnp.zeros((), jnp.float32)

    # unscanned prefix layers (e.g. kimi first dense layer)
    new_prefix_cache = {}
    if cfg.first_k_dense:
        for i in range(cfg.first_k_dense):
            lp = params["prefix"][f"p{i}"]
            lc = None
            if cache is not None:
                lc = dict(cache["prefix"][f"p{i}"], len=cache["len"])
            x, nc, a = _one_layer(lp, x, positions, cfg, "global", lc)
            aux += a
            if cache is not None:
                new_prefix_cache[f"p{i}"] = {"k": nc["k"], "v": nc["v"]}

    # scanned units
    U = num_units(cfg)
    L = len(cfg.layer_pattern)
    xkv_fn = None
    if cfg.family == "encdec":
        if enc_out is not None:
            def xkv_fn(lp, i, ucache):  # compute cross K/V from encoder output
                KV, hd = cfg.num_kv_heads, cfg.hd
                k = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, KV, hd)
                v = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, KV, hd)
                return (k, v, src_positions)
        else:
            def xkv_fn(lp, i, ucache):  # decode: cached cross K/V
                xc = ucache["xkv"][f"l{i}"]
                kpos = jnp.broadcast_to(
                    jnp.arange(xc["k"].shape[1], dtype=jnp.int32)[None], (B, xc["k"].shape[1])
                )
                return (xc["k"], xc["v"], kpos)

    unit = _unit_fn(cfg, positions, xkv_fn)
    unit = _maybe_remat(unit, cfg)

    if cache is None:
        xs_cache = None
        (x, aux), _ = lax.scan(
            lambda c, up: (unit(c, (up, None))[0], None), (x, aux), params["blocks"]
        )
        new_cache = None
    else:
        ucaches = {
            f"l{i}": {"k": cache[f"l{i}"]["k"], "v": cache[f"l{i}"]["v"]}
            for i in range(L)
        }
        if cfg.family == "encdec":
            ucaches["xkv"] = cache["xkv"]
        ucaches["len"] = jnp.broadcast_to(cache["len"], (U,))
        (x, aux), scanned_cache = lax.scan(unit, (x, aux), (params["blocks"], ucaches))
        new_cache = dict(scanned_cache)
        if cfg.family == "encdec":
            new_cache["xkv"] = cache["xkv"]
        if cfg.first_k_dense:
            new_cache["prefix"] = new_prefix_cache
        new_cache["len"] = cache["len"] + S

    x = apply_norm(params["ln_f"], x, cfg)
    x = shard(x, "batch", "residual_seq", None)
    logits = x @ params["embed"].T.astype(cfg.adtype)  # tied head
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return shard(logits, "batch", "residual_seq", "vocab"), new_cache, aux


def encoder_forward(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Bidirectional encoder over stub frame embeddings (B, Ssrc, fdim)."""
    enc = params["encoder"]
    x = (frames.astype(cfg.adtype) @ enc["frontend_proj"]).astype(cfg.adtype)
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def unit(carry, up):
        x, aux = carry
        lp = up["l0"]
        h, _ = attention_block(
            lp["attn"], apply_norm(lp["ln_attn"], x, cfg), positions, cfg,
            layer_type="global", causal=False,
        )
        x = x + h
        x = x + apply_mlp(lp["mlp"], apply_norm(lp["ln_mlp"], x, cfg), cfg)
        return (x, aux), None

    unit = _maybe_remat(unit, cfg)
    (x, _), _ = lax.scan(unit, (x, jnp.zeros((), jnp.float32)), enc["blocks"])
    return apply_norm(enc["ln_f"], x, cfg)


def build_xattn_cache(params: Dict, enc_out: jnp.ndarray, cfg: ModelConfig) -> Dict:
    """Precompute cross-attention K/V for decode (one pass over units)."""
    B = enc_out.shape[0]
    KV, hd = cfg.num_kv_heads, cfg.hd
    out = {}
    for i in range(len(cfg.layer_pattern)):
        wk = params["blocks"][f"l{i}"]["xattn"]["wk"]  # (U, d, KV*hd)
        wv = params["blocks"][f"l{i}"]["xattn"]["wv"]
        k = jnp.einsum("bsd,udk->ubsk", enc_out, wk).reshape(
            wk.shape[0], B, -1, KV, hd
        )
        v = jnp.einsum("bsd,udk->ubsk", enc_out, wv).reshape(
            wv.shape[0], B, -1, KV, hd
        )
        out[f"l{i}"] = {"k": k, "v": v}
    return out
