"""Shared neural layers: norms, RoPE, chunked flash-style attention (train /
prefill / decode), dense MLPs, MoE with scatter-based dispatch, Mamba2 SSD.

All layers are pure functions over ParamSpec-declared pytrees; activations
carry logical sharding annotations via runtime.sharding.shard().
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.runtime.sharding import ParamSpec, shard

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_specs(cfg: ModelConfig, d: int) -> Dict:
    if cfg.norm == "layernorm":
        return {
            "scale": ParamSpec((d,), (None,), jnp.float32, "ones"),
            "bias": ParamSpec((d,), (None,), jnp.float32, "zeros"),
        }
    return {"scale": ParamSpec((d,), (None,), jnp.float32, "ones")}


def apply_norm(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd), positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = {
        "wq": ParamSpec((d, H * hd), ("fsdp", "qkv")),
        "wk": ParamSpec((d, KV * hd), ("fsdp", "qkv")),
        "wv": ParamSpec((d, KV * hd), ("fsdp", "qkv")),
        "wo": ParamSpec((H * hd, d), ("qkv", "fsdp")),
    }
    if cfg.qk_norm:
        p["qnorm"] = {"scale": ParamSpec((hd,), (None,), jnp.float32, "ones")}
        p["knorm"] = {"scale": ParamSpec((hd,), (None,), jnp.float32, "ones")}
    return p


def _qk_normalize(p, q, k, cfg):
    if not cfg.qk_norm:
        return q, k
    def rn(scale, x):
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)
    return rn(p["qnorm"]["scale"], q), rn(p["knorm"]["scale"], k)


def _chunk_mask(qpos, kpos, layer_type: str, window: int, causal: bool):
    """(Sq, Sk) boolean mask given absolute positions."""
    diff = qpos[:, None] - kpos[None, :]
    m = kpos[None, :] < 2**29  # padded / unwritten cache slots are invalid
    if causal:
        m &= diff >= 0
    if layer_type == "local":
        m &= diff < window
    return m


def multihead_attention(
    x_q: jnp.ndarray,       # (B, Sq, H, hd) post-rope
    k: jnp.ndarray,         # (B, Sk, KV, hd)
    v: jnp.ndarray,         # (B, Sk, KV, hd)
    qpos: jnp.ndarray,      # (B, Sq)
    kpos: jnp.ndarray,      # (B, Sk)
    *,
    layer_type: str = "global",
    window: int = 0,
    causal: bool = True,
    attn_softcap: float = 0.0,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax (flash-style) chunked attention over key blocks.

    Memory never materialises (Sq, Sk) scores — peak is (B,H,Sq,kv_chunk).
    GQA is handled by reshaping q into (KV, group) without repeating K/V.
    """
    B, Sq, H, hd = x_q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    q = x_q.reshape(B, Sq, KV, G, hd) * (hd ** -0.5)

    if Sq <= 8:
        # decode: direct split-K attention.  The chunk SCAN below is
        # sequential, which forces GSPMD to all-gather a seq-sharded KV
        # cache (2 x full-cache per layer — perf iter Z1); the direct
        # einsum + sharded softmax lowers to tiny partial-max/sum
        # collectives instead.
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
        s = softcap(s, attn_softcap)
        mask = jax.vmap(
            lambda qp, kp: _chunk_mask(qp, kp, layer_type, window, causal)
        )(qpos, kpos)  # (B, Sq, Sk)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        pexp = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m))
        l = jnp.sum(pexp, axis=-1, keepdims=True)
        out = jnp.einsum("bkgqs,bskh->bkgqh", (pexp / jnp.maximum(l, 1e-30)).astype(v.dtype), v)
        return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)

    nchunks = -(-Sk // kv_chunk)
    pad = nchunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=2**30)
    kc = k.reshape(B, nchunks, kv_chunk, KV, hd)
    vc = v.reshape(B, nchunks, kv_chunk, KV, hd)
    pc = kpos.reshape(B, nchunks, kv_chunk)

    def step(carry, blk):
        m_run, l_run, acc = carry
        kb, vb, pb = blk  # (B, C, KV, hd), (B, C, KV, hd), (B, C)
        s = jnp.einsum("bqkgh,bckh->bkgqc", q, kb).astype(jnp.float32)
        s = softcap(s, attn_softcap)
        mask = jax.vmap(
            lambda qp, kp: _chunk_mask(qp, kp, layer_type, window, causal)
        )(qpos, pb)  # (B, Sq, C)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        # fully-masked chunks: exp(NEG_INF - NEG_INF) would be 1 — force 0
        pexp = jnp.where(
            s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new[..., None])
        )
        l_new = l_run * alpha + jnp.sum(pexp, axis=-1)
        upd = jnp.einsum("bkgqc,bckh->bkgqh", pexp.astype(vb.dtype), vb)
        acc = acc * alpha[..., None].astype(acc.dtype) + upd
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), v.dtype)
    (m, l, acc), _ = lax.scan(
        step,
        (m0, l0, a0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.moveaxis(pc, 1, 0),
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)  # (B,Sq,KV,G,hd)->(B,Sq,H*hd)
    return out


def attention_block(
    p: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    layer_type: str = "global",
    causal: bool = True,
    cache: Optional[Dict] = None,
    xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full attention sublayer: projections + rope + (cached) attention.

    cache: {"k": (B,T,KV,hd), "v": ..., "len": ()} for decode; updated copy
    returned.  xattn_kv: precomputed (k, v, kpos) for cross-attention.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if xattn_kv is None:
        k = (x @ p["wk"]).reshape(B, S, KV, hd)
        v = (x @ p["wv"]).reshape(B, S, KV, hd)
        q, k = _qk_normalize(p, q, k, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kpos = positions
    else:
        k, v, kpos = xattn_kv
        q, _ = _qk_normalize(p, q, q, cfg) if cfg.qk_norm else (q, None)
    # explicit attention layouts — the head/seq mode is chosen per arch by
    # launch.steps.rules_for (q_seq/kv_seq stay None in pure head-TP mode)
    q = shard(q, "batch", "q_seq", "heads", None)
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)

    new_cache = None
    if cache is not None and xattn_kv is None:
        T = cache["k"].shape[1]
        start = cache["len"]
        ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
        new_cache = {"k": ck, "v": cv, "len": start + S}
        k, v = ck, cv
        kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        # mask out unwritten slots via "future" positions
        kpos = jnp.where(kpos < start + S, kpos, 2**30)

    out = multihead_attention(
        q, k, v, positions, kpos,
        layer_type=layer_type,
        window=cfg.window_size,
        causal=causal,
        attn_softcap=cfg.attn_softcap,
    )
    out = shard(out, "batch", "q_seq", "heads", None)
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return shard(out, "batch", "residual_seq", None), new_cache


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d: int, ff: int) -> Dict:
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, ff), ("fsdp", "ffn")),
            "w_in": ParamSpec((d, ff), ("fsdp", "ffn")),
            "w_out": ParamSpec((ff, d), ("ffn", "fsdp")),
        }
    return {
        "w_in": ParamSpec((d, ff), ("fsdp", "ffn")),
        "w_out": ParamSpec((ff, d), ("ffn", "fsdp")),
    }


def apply_mlp(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = jax.nn.gelu(x @ p["w_in"])
    h = shard(h, "batch", "seq", "ffn")
    return shard(h @ p["w_out"], "batch", "residual_seq", None)


# ---------------------------------------------------------------------------
# MoE (scatter dispatch into (E, C, d) bins + batched expert GEMMs)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig) -> Dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    p = {
        "router": ParamSpec((d, E), ("fsdp", None), jnp.float32),
        "w_gate": ParamSpec((E, d, ff), ("experts", "fsdp", "expert_ffn")),
        "w_in": ParamSpec((E, d, ff), ("experts", "fsdp", "expert_ffn")),
        "w_out": ParamSpec((E, ff, d), ("experts", "expert_ffn", "fsdp")),
    }
    if cfg.shared_experts:
        p["shared"] = mlp_specs(cfg, d, cfg.expert_d_ff * cfg.shared_experts)
    return p


def apply_moe(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k token-choice MoE.  Returns (out, aux_loss).

    Under a mesh with a 'model' axis this routes through the shard_map
    implementation (`_apply_moe_shardmap`): GSPMD partitions the scatter-
    based dispatch catastrophically (it all-reduces the full (E, C, d) bins
    per layer — 1 TB+/layer on kimi-k2; see EXPERIMENTS.md §Perf iter K1),
    whereas the explicit formulation keeps routing local and needs ONE psum.
    """
    from repro.runtime.sharding import current_mesh, _CTX

    mesh = current_mesh()
    if mesh is not None and "model" in mesh.shape:
        rules = _CTX.rules
        tp = mesh.shape["model"]
        if rules.get("experts") == "model" and cfg.num_experts % tp == 0:
            batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            bsz = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
            if batch_axes and x.shape[0] % bsz == 0:
                # seq-sharded residual -> expert-parallel all-to-all island
                # (perf iter K4); else replicated-token island (decode)
                if rules.get("residual_seq") == "model" and x.shape[1] % tp == 0:
                    return _apply_moe_ep_a2a(p, x, cfg, mesh, batch_axes)
                return _apply_moe_shardmap(p, x, cfg, mesh, batch_axes)
    return _apply_moe_dense(p, x, cfg)


def _apply_moe_ep_a2a(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, mesh, batch_axes
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE with explicit all-to-all dispatch (GShard-style).

    Tokens are sharded over BOTH batch axes and the model axis (seq); each
    shard routes its local tokens, scatters them into per-expert send
    buffers, exchanges with the expert owners by all_to_all, runs the expert
    GEMMs, and reverses the exchange.  Per layer collective cost is exactly
    2 x T_local*k*cf*d (fwd) — no replicated bins, no full-activation psum.
    """
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_tok
    tp = mesh.shape["model"]
    El = E // tp
    nmat_glu = cfg.mlp_act in ("swiglu", "geglu")
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu

    def body(xl, router, wg, wi, wo, *shared):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, d)
        logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        me = lax.pmean(jnp.mean(probs, axis=0), batch_axes + ("model",))
        ce = lax.pmean(
            jnp.mean(
                jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1),
                axis=0,
            ),
            batch_axes + ("model",),
        )
        aux = jnp.sum(me * ce) * E

        flat_e = expert_ids.reshape(T * K).astype(jnp.int32)
        pos = _local_positions(flat_e, E)
        Cs = max(1, int(T * K * cfg.capacity_factor / E))  # per-source capacity
        ok = pos < Cs
        dst = jnp.where(ok, flat_e * Cs + pos, E * Cs)
        src = jnp.repeat(xt, K, axis=0)
        send = jnp.zeros((E * Cs + 1, d), xt.dtype).at[dst].add(src)
        send = send[: E * Cs].reshape(tp, El * Cs, d)
        recv = lax.all_to_all(send, "model", split_axis=0, concat_axis=0)
        bins = recv.reshape(tp, El, Cs, d).transpose(1, 0, 2, 3).reshape(
            El, tp * Cs, d
        )
        if nmat_glu:
            h = act(jnp.einsum("ecd,edf->ecf", bins, wg)) * jnp.einsum(
                "ecd,edf->ecf", bins, wi
            )
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", bins, wi))
        outb = jnp.einsum("ecf,efd->ecd", h, wo)  # (El, tp*Cs, d)
        back = outb.reshape(El, tp, Cs, d).transpose(1, 0, 2, 3).reshape(
            tp, El * Cs, d
        )
        ret = lax.all_to_all(back, "model", split_axis=0, concat_axis=0)
        ret = ret.reshape(E * Cs, d)
        ret = jnp.concatenate([ret, jnp.zeros((1, d), ret.dtype)])
        gathered = jnp.take(ret, dst, axis=0)
        weighted = gathered.reshape(T, K, d) * gate_vals[..., None].astype(
            gathered.dtype
        )
        out = jnp.sum(weighted, axis=1)  # (T, d) — already complete locally
        if shared:
            # tokens are seq-sharded here, so the (small) shared-expert
            # weights are REPLICATED over model: every rank serves its own
            # tokens completely — a psum of partial-f products would mix
            # different ranks' tokens (bug caught by the parity test)
            sg, si, so = shared
            if nmat_glu:
                hs = act(xt @ sg) * (xt @ si)
            else:
                hs = jax.nn.gelu(xt @ si)
            out = out + hs @ so
        return out.reshape(Bl, Sl, d), aux

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], "model", None)
    espec = P("model", None, None)
    if cfg.shared_experts:
        sp = p["shared"]
        shared = (
            sp["w_gate"] if "w_gate" in sp else sp["w_in"],
            sp["w_in"],
            sp["w_out"],
        )
        sspec = (P(None, None), P(None, None), P(None, None))  # replicated
    else:
        shared = ()
        sspec = ()
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(bspec, P(None, None), espec, espec, espec) + sspec,
        out_specs=(bspec, P()),
        check=False,
    )(x, p["router"].astype(jnp.float32), p["w_gate"], p["w_in"], p["w_out"], *shared)
    return out, aux


def _local_positions(flat_e: jnp.ndarray, E: int) -> jnp.ndarray:
    """Rank of each routing decision within its expert (sort-based, local).

    Avoids the (T*K, E) one-hot cumsum tensor entirely."""
    TK = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = jnp.take(flat_e, order)
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(TK, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(rank)
    return pos


def _apply_moe_shardmap(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, mesh, batch_axes
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit MoE: tokens sharded over batch axes and REPLICATED over
    'model'; each model rank routes the local tokens to its own expert slab;
    a single psum over 'model' combines expert (and shared-FFN) partials."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_tok
    tp = mesh.shape["model"]
    El = E // tp
    nmat_glu = cfg.mlp_act in ("swiglu", "geglu")
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu

    def body(xl, router, wg, wi, wo, shared):
        m = lax.axis_index("model")
        Bl = xl.shape[0]
        T = Bl * S
        xt = xl.reshape(T, d)
        logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        # load-balance aux (global over batch axes; replicated over model)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(
            jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1), axis=0
        )
        if batch_axes:
            me = lax.pmean(me, batch_axes)
            ce = lax.pmean(ce, batch_axes)
        aux = jnp.sum(me * ce) * E

        flat_e = expert_ids.reshape(T * K).astype(jnp.int32)
        pos = _local_positions(flat_e, E)
        C = max(1, int(T * K * cfg.capacity_factor / E))
        local_e = flat_e - m * El
        ok = (local_e >= 0) & (local_e < El) & (pos < C)
        dst = jnp.where(ok, jnp.clip(local_e, 0, El - 1) * C + pos, El * C)
        src = jnp.repeat(xt, K, axis=0)  # (T*K, d)
        bins = jnp.zeros((El * C + 1, d), xt.dtype).at[dst].add(src)
        bins = bins[: El * C].reshape(El, C, d)
        if nmat_glu:
            h = act(jnp.einsum("ecd,edf->ecf", bins, wg)) * jnp.einsum(
                "ecd,edf->ecf", bins, wi
            )
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", bins, wi))
        out_bins = jnp.einsum("ecf,efd->ecd", h, wo).reshape(El * C, d)
        out_bins = jnp.concatenate([out_bins, jnp.zeros((1, d), out_bins.dtype)])
        gathered = jnp.take(out_bins, dst, axis=0)  # masked rows hit the 0-row
        weighted = gathered.reshape(T, K, d) * gate_vals[..., None].astype(
            gathered.dtype
        )
        partial = jnp.sum(weighted, axis=1)  # (T, d)
        if shared is not None:
            sg, si, so = shared
            if nmat_glu:
                hs = act(xt @ sg) * (xt @ si)
            else:
                hs = jax.nn.gelu(xt @ si)
            partial = partial + hs @ so
        out = lax.psum(partial.astype(xl.dtype), "model")  # bf16 payload
        return out.reshape(Bl, S, d), aux

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None, None)
    espec = P("model", None, None)
    if cfg.shared_experts:
        sp = p["shared"]
        shared = (
            sp["w_gate"] if "w_gate" in sp else sp["w_in"],
            sp["w_in"],
            sp["w_out"],
        )
        sspec = (P(None, "model"), P(None, "model"), P("model", None))
    else:
        shared = ()
        sspec = ()
    out, aux = shard_map(
        lambda xl, router, wg, wi, wo, *sh: body(xl, router, wg, wi, wo, sh or None),
        mesh=mesh,
        in_specs=(bspec, P(None, None), espec, espec, espec) + sspec,
        out_specs=(bspec, P()),
        check=False,
    )(x, p["router"].astype(jnp.float32), p["w_gate"], p["w_in"], p["w_out"], *shared)
    return out, aux


def _apply_moe_dense(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference dense-dispatch MoE (single device / no mesh)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_tok
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = jnp.sum(me * ce) * E

    C = max(1, int(T * K * cfg.capacity_factor / E))
    flat_e = expert_ids.reshape(T * K)
    # position of each (token, k) within its expert bin
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*K,)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)  # overflow row
    # scatter tokens into bins (E, C+1, d); +1 row swallows dropped tokens
    src = jnp.repeat(xt, K, axis=0)  # (T*K, d)
    bins = jnp.zeros((E, C + 1, d), xt.dtype)
    bins = bins.at[flat_e, safe_pos].add(src)
    bins = shard(bins, "experts", None, None)
    # expert FFNs (batched GEMMs over E)
    if cfg.mlp_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", bins, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", bins, p["w_in"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", bins, p["w_in"]))
    h = shard(h, "experts", None, None)
    out_bins = jnp.einsum("ecf,efd->ecd", h, p["w_out"])  # (E, C+1, d)
    # gather back
    gathered = out_bins[flat_e, safe_pos]  # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered.reshape(T, K, d) * gate_vals[..., None].astype(gathered.dtype)
    out = jnp.sum(weighted, axis=1).reshape(B, S, d)
    if cfg.shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg)
    return shard(out, "batch", "seq", None), aux


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    N = cfg.ssm_state
    H = di // cfg.ssm_head_dim
    conv_dim = di + 2 * N
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * N + H), ("fsdp", "ffn")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), ("conv", None)),
        "A_log": ParamSpec((H,), (None,), jnp.float32, "zeros"),
        "D": ParamSpec((H,), (None,), jnp.float32, "ones"),
        "dt_bias": ParamSpec((H,), (None,), jnp.float32, "zeros"),
        "out_norm": {"scale": ParamSpec((di,), (None,), jnp.float32, "ones")},
        "out_proj": ParamSpec((di, d), ("ffn", "fsdp")),
    }


def _ssd_scan(x, dt, A, Bm, Cm, chunk: int, state0=None):
    """Chunked state-space dual scan.

    x: (B, L, H, P); dt: (B, L, H); A: (H,) (negative decay rates);
    Bm, Cm: (B, L, N).  Returns (y: (B, L, H, P), final_state (B,H,N,P)).
    """
    Bsz, L, H, Pd = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert nc * chunk == L, (L, chunk)
    xr = x.reshape(Bsz, nc, chunk, H, Pd)
    dtr = dt.reshape(Bsz, nc, chunk, H)
    Br = Bm.reshape(Bsz, nc, chunk, N)
    Cr = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtr * A[None, None, None, :]  # (B, nc, c, H) negative values
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log decay

    def step(state, blk):
        xb, dtb, Bb, Cb, dAb, cumb = blk  # (B,c,H,P),(B,c,H),(B,c,N),(B,c,N),(B,c,H),(B,c,H)
        # intra-chunk: y[i] = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
        Lmat = cumb[:, :, None, :] - cumb[:, None, :, :]  # (B, i, j, H)
        causal = jnp.tril(jnp.ones((Lmat.shape[1], Lmat.shape[2]), bool))
        # mask in log space BEFORE exp: avoids inf (and nan grads) above diag
        decay = jnp.exp(jnp.where(causal[None, :, :, None], Lmat, NEG_INF))
        cb = jnp.einsum("bin,bjn->bij", Cb, Bb)  # (B, i, j)
        w = cb[..., None] * decay * dtb[:, None, :, :]  # (B, i, j, H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w.astype(xb.dtype), xb)
        # inter-chunk: y_i += C_i . state * exp(cum_i)
        y_inter = jnp.einsum(
            "bin,bhnp->bihp", Cb, state.astype(Cb.dtype)
        ) * jnp.exp(cumb)[..., None].astype(xb.dtype)
        # state update: S' = S * exp(sum dA) + sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
        tail = jnp.exp(cumb[:, -1:, :] - cumb) * dtb  # (B, c, H)
        dBx = jnp.einsum("bjh,bjn,bjhp->bhnp", tail.astype(xb.dtype), Bb, xb)
        state = state * jnp.exp(cumb[:, -1])[:, :, None, None].astype(state.dtype) + dBx
        return state, y_intra + y_inter

    if state0 is None:
        state0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    state_f, ys = lax.scan(
        step,
        state0,
        (
            jnp.moveaxis(xr, 1, 0),
            jnp.moveaxis(dtr, 1, 0),
            jnp.moveaxis(Br, 1, 0),
            jnp.moveaxis(Cr, 1, 0),
            jnp.moveaxis(dA, 1, 0),
            jnp.moveaxis(cum, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, H, Pd), state_f


def apply_ssd(
    p: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba2 block.  cache = {"conv": (B, K-1, convdim), "state": (B,H,N,P)}."""
    B, S, d = x.shape
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    Pd = cfg.ssm_head_dim
    proj = x @ p["in_proj"]  # (B, S, 2di + 2N + H)
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative

    # causal depthwise conv over xbc
    Kc = cfg.ssm_conv
    new_cache = None
    if cache is None:
        pad = jnp.pad(xbc, ((0, 0), (Kc - 1, 0), (0, 0)))
        conv = sum(
            pad[:, i : i + S] * p["conv_w"][i][None, None].astype(x.dtype)
            for i in range(Kc)
        )
    else:
        hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K-1+S, cd)
        conv = sum(
            hist[:, i : i + S] * p["conv_w"][i][None, None].astype(x.dtype)
            for i in range(Kc)
        )
        new_conv = hist[:, -(Kc - 1):]
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [di, di + N], axis=-1)
    xh = xs.reshape(B, S, H, Pd)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)

    if cache is None:
        L = xh.shape[1]
        chunk = min(cfg.ssm_chunk, L)
        while L % chunk:
            chunk //= 2
        y, _ = _ssd_scan(xh, dt, A, Bm, Cm, max(chunk, 1))
    elif S > 1:
        # prefill continuing from a cached state (SSM "cache" = final state)
        L = xh.shape[1]
        chunk = min(cfg.ssm_chunk, L)
        while L % chunk:
            chunk //= 2
        y, state = _ssd_scan(xh, dt, A, Bm, Cm, max(chunk, 1), state0=cache["state"])
        new_cache = {"conv": new_conv, "state": state}
    else:
        # single-step recurrence (S == 1 decode)
        state = cache["state"]  # (B, H, N, P) float32
        dA1 = jnp.exp(dt[:, 0] * A[None, :])  # (B, H)
        dBx = jnp.einsum(
            "bh,bn,bhp->bhnp", dt[:, 0], Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        state = state * dA1[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), state)[
            :, None
        ].astype(x.dtype)
        new_cache = {"conv": new_conv, "state": state}
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, di)
    # gated RMS norm then out projection
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["out_norm"]["scale"]
    out = yf.astype(x.dtype) @ p["out_proj"]
    return shard(out, "batch", "seq", None), new_cache
