"""LM model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM architectures."""
from .registry import ModelAPI, build_model, cross_entropy
