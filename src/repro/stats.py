"""One stats schema for every runtime surface.

``ServeStats``, ``SchedulerStats`` and the pool master's cumulative
counters historically disagreed on key names and units; this module pins
the shared snapshot contract they all emit:

- **counters** are plain ints under their own name (``submitted``,
  ``completed``, ``redispatched`` ...);
- **latency distributions** are milliseconds and follow the
  ``<name>_ms_hist`` / ``<name>_ms_p50`` / ``<name>_ms_p99`` /
  ``<name>_ms_sum`` family — the histogram is a dict of
  cumulative-style bucket labels (``"<=0.5"`` ... ``"inf"``) to counts,
  the quantiles are the upper bound of the bucket the quantile falls in
  (``None`` when empty), and the sum is the total observed milliseconds
  (what Prometheus histogram ``_sum`` samples carry);
- **bytes** are ``bytes_in`` / ``bytes_out`` for what actually crossed
  the wire and ``raw_bytes_in`` / ``raw_bytes_out`` for the pre-codec
  payload sizes, so ``raw/wire`` is the observed compression ratio.

Every key is **component-prefixed**: ``<component>_<metric>`` with
``component`` one of ``serve`` / ``scheduler`` / ``pool``
(``serve_completed``, ``pool_bytes_out``, ``scheduler_request_ms_p99``),
so merged reports from several components never collide.
:func:`namespaced` applies the prefix to a raw snapshot, and
:class:`StatsSnapshot` resolves the historical unprefixed names
(``snap["completed"]``) with a one-time ``DeprecationWarning`` so
``--stats-every`` consumers keep working across the rename.

:class:`Histogram` produces the triple; :func:`merge_snapshots` combines
snapshots from several components (e.g. the serving engine + the pool
master) into one report, summing counters and bucket counts and
recomputing quantiles from the merged histograms.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.settings import warn_deprecated_once

__all__ = [
    "BUCKETS_MS",
    "Histogram",
    "StatsSnapshot",
    "merge_snapshots",
    "namespaced",
    "quantile_from_hist",
]

# shared latency bucket bounds (ms); inf catches the long tail
BUCKETS_MS: Tuple[float, ...] = (
    0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0,
    float("inf"),
)


def _label(bound: float) -> str:
    if bound == float("inf"):
        return "inf"
    return f"<={bound:g}"


def _bound(label: str) -> float:
    if label == "inf":
        return float("inf")
    return float(label[2:])


class Histogram:
    """Fixed-bucket latency histogram emitting the shared ``*_ms`` triple.

    Thread-safe: ``observe`` may race with ``snapshot`` from reporting
    threads.
    """

    def __init__(self, bounds: Sequence[float] = BUCKETS_MS):
        self.bounds = tuple(bounds)
        self._counts = [0] * len(self.bounds)
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value_ms: float) -> None:
        for k, bound in enumerate(self.bounds):
            if value_ms <= bound:
                with self._lock:
                    self._counts[k] += 1
                    self._sum += value_ms
                return

    @property
    def total(self) -> int:
        with self._lock:
            return sum(self._counts)

    def quantile(self, q: float) -> Optional[float]:
        with self._lock:
            counts = list(self._counts)
        return quantile_from_hist(
            dict(zip(map(_label, self.bounds), counts)), q
        )

    def snapshot(self, name: str) -> Dict[str, object]:
        """``{f"{name}_hist": {...}, f"{name}_p50": ..., f"{name}_p99": ...,
        f"{name}_sum": ...}`` — ``name`` should end in ``_ms`` per the
        schema; the sum is what Prometheus histograms need next to the
        bucket counts."""
        with self._lock:
            counts = list(self._counts)
            total = self._sum
        hist = dict(zip(map(_label, self.bounds), counts))
        return {
            f"{name}_hist": hist,
            f"{name}_p50": quantile_from_hist(hist, 0.50),
            f"{name}_p99": quantile_from_hist(hist, 0.99),
            f"{name}_sum": round(total, 3),
        }


def quantile_from_hist(hist: Dict[str, int], q: float) -> Optional[float]:
    """Upper bucket bound holding the q-quantile of a ``*_ms_hist`` dict
    (None when the histogram is empty).  A quantile landing in the open
    ``inf`` bucket clamps to the largest finite bound so snapshots stay
    JSON-clean."""
    items = sorted(hist.items(), key=lambda kv: _bound(kv[0]))
    total = sum(c for _, c in items)
    if total == 0:
        return None
    finite = [_bound(lbl) for lbl, _ in items if _bound(lbl) != float("inf")]
    cap = finite[-1] if finite else float("inf")
    target = q * total
    seen = 0
    for label, count in items:
        seen += count
        if seen >= target:
            return min(_bound(label), cap)
    return cap  # pragma: no cover - fp slack


class StatsSnapshot(dict):
    """A schema-conforming snapshot that still answers legacy key names.

    Keys are stored component-prefixed (``serve_completed``).  Indexing
    with a historical unprefixed name (``snap["completed"]``) resolves
    through the alias table built at construction and emits one
    ``DeprecationWarning`` per process per alias; iteration and ``dict()``
    only ever expose the canonical names.
    """

    def __init__(self, data: Dict[str, object],
                 aliases: Optional[Dict[str, str]] = None):
        super().__init__(data)
        self._aliases = dict(aliases or {})

    def __missing__(self, key):
        target = self._aliases.get(key)
        if target is None or target not in self:
            raise KeyError(key)
        warn_deprecated_once(
            f"stats:{key}",
            f"stats key {key!r} is deprecated; read {target!r} instead",
        )
        return self[target]

    def __contains__(self, key) -> bool:
        if super().__contains__(key):
            return True
        target = self._aliases.get(key)
        return target is not None and super().__contains__(target)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default


def namespaced(
    component: str,
    snap: Dict[str, object],
    extra_aliases: Optional[Dict[str, str]] = None,
) -> StatsSnapshot:
    """Prefix every key of ``snap`` with ``<component>_`` and wrap it so
    the unprefixed names still resolve (with a deprecation warning).

    Idempotent per key: a key already starting with the prefix is kept
    as-is, so callers that pre-prefixed by hand don't double up.
    """
    prefix = f"{component}_"
    data: Dict[str, object] = {}
    aliases: Dict[str, str] = {}
    for key, val in snap.items():
        if key.startswith(prefix):
            data[key] = val
        else:
            data[prefix + key] = val
            aliases[key] = prefix + key
    if extra_aliases:
        aliases.update(extra_aliases)
    return StatsSnapshot(data, aliases)


def merge_snapshots(*snaps: Dict[str, object]) -> Dict[str, object]:
    """Merge schema-conforming snapshots into one combined report.

    Counters (ints/floats) sum; ``*_hist`` dicts sum per bucket;
    ``*_p50``/``*_p99`` are recomputed from the merged histograms (never
    summed — quantiles don't add).  A precomputed quantile whose matching
    ``*_hist`` appears in no snapshot keeps its first occurrence — there is
    nothing to recompute from, and dropping it would silently thin the
    schema.  Keys that appear in only one snapshot pass through;
    non-numeric values (labels, lists) keep the first occurrence.
    """
    merged: Dict[str, object] = {}
    hists: Dict[str, Dict[str, int]] = {}
    quantiles: Dict[str, object] = {}
    for snap in snaps:
        for key, val in snap.items():
            if key.endswith("_hist") and isinstance(val, dict):
                acc = hists.setdefault(key, {})
                for label, count in val.items():
                    acc[label] = acc.get(label, 0) + int(count)
            elif key.endswith("_p50") or key.endswith("_p99"):
                # recomputed below when the merged hist exists; kept as a
                # passthrough (first occurrence) when it doesn't
                quantiles.setdefault(key, val)
            elif isinstance(val, bool):
                merged[key] = merged.get(key, False) or val
            elif isinstance(val, (int, float)):
                merged[key] = merged.get(key, 0) + val
            elif key not in merged:
                merged[key] = val
    for key, hist in hists.items():
        base = key[: -len("_hist")]
        merged[key] = hist
        merged[f"{base}_p50"] = quantile_from_hist(hist, 0.50)
        merged[f"{base}_p99"] = quantile_from_hist(hist, 0.99)
    for key, val in quantiles.items():
        if f"{key[:-len('_p50')]}_hist" not in hists:  # _p99 same length
            merged[key] = val
    aliases: Dict[str, str] = {}
    for snap in snaps:
        if isinstance(snap, StatsSnapshot):
            aliases.update(snap._aliases)
    if aliases:
        return StatsSnapshot(merged, aliases)
    return merged
