"""Step builders shared by train.py / serve.py / dryrun.py.

A "cell" = (architecture, input shape, mesh).  This module turns a cell into
a jit-able step function plus the ShapeDtypeStruct stand-ins (with
NamedShardings) for every input — the dry-run lowers exactly what the real
launcher runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import ModelAPI, build_model
from repro.optim import OptConfig, opt_state_specs, opt_init, opt_update
from repro.runtime.sharding import (
    ParamSpec,
    axis_rules,
    shape_structs,
    sharding_tree,
)


def rules_for(cfg: ModelConfig, shape: ShapeConfig, tp: int = 16) -> Dict[str, Any]:
    """Per-cell logical->physical overrides on top of DEFAULT_RULES.

    Attention layout mode (perf iteration 1, see EXPERIMENTS.md §Perf):
      * heads and kv heads divisible by tp  -> pure head-TP (fastest)
      * heads divisible, kv not             -> head-TP with replicated KV
      * heads not divisible                 -> sequence-parallel attention:
        Q sharded over seq/model, K/V gathered (kills the partial-scores
        all-reduce that dominated the baseline)
    """
    rules: Dict[str, Any] = {"fsdp": cfg.fsdp_axes if len(cfg.fsdp_axes) > 1 else cfg.fsdp_axes[0]}
    if shape.kind in ("train", "prefill") and shape.seq_len % tp == 0:
        # Megatron-SP residual layout (perf iter K4).  First attempt (K2) was
        # refuted — the replicated-token MoE island forced a gather per MoE
        # layer; with the all-to-all EP island the SP layout wins everywhere:
        # scan-boundary activations shrink 16x and bwd psums become
        # reduce-scatters.
        rules["residual_seq"] = "model"
    heads_ok = cfg.num_heads and cfg.num_heads % tp == 0
    kv_ok = cfg.num_kv_heads and cfg.num_kv_heads % tp == 0
    if cfg.num_heads:
        kv_dim = cfg.num_kv_heads * cfg.hd
        small_kv = kv_dim * 2 <= cfg.d_model // 2  # GQA: K/V much smaller than x
        if not heads_ok or (shape.kind != "decode" and small_kv):
            # context-parallel attention (perf iter K5): Q stays seq-sharded,
            # K/V are gathered — with GQA the gathered K/V is far smaller
            # than the 4x full-activation SP<->TP transitions of head-TP
            rules["heads"] = None
            rules["kv_heads"] = None
            if shape.kind != "decode":
                rules["q_seq"] = "model"
                rules["residual_seq"] = "model"
                # NOTE (perf iter 3, REFUTED): dropping ffn/qkv tensor
                # sharding in favour of pure SP doubles collective traffic —
                # per-layer FSDP weight gathers (105 GB) exceed the Megatron
                # seq<->tensor transitions (45 GB).  Keep TP for FFN/QKV.
        elif not kv_ok:
            rules["kv_heads"] = None  # replicate K/V heads (small for GQA)
    if shape.kind == "decode":
        # split-K decode: KV-cache sequence sharded over model (and data for
        # the 500k single-request cell, where batch can't shard)
        rules["cache_seq"] = ("data", "model") if shape.global_batch == 1 else "model"
        # decode latency = weight reads: keep weights RESIDENT (model-sharded
        # only) whenever they fit, instead of ZeRO-gathering every step
        # (perf iter Z2).  Only the 1T MoE genuinely needs fsdp for serving.
        from repro.launch.costmodel import _param_counts

        pbytes = _param_counts(cfg)["total"] * 2.0
        if pbytes / tp <= 12 * 2**30:
            rules["fsdp"] = None
    if shape.kind in ("train", "prefill") and shape.seq_len >= 262144:
        rules["seq"] = "data"  # context parallelism for very long sequences
    return rules


def opt_config_for(cfg: ModelConfig, total_steps: int = 10_000) -> OptConfig:
    return OptConfig(
        name=cfg.optimizer,
        state_dtype=cfg.opt_state_dtype,
        total_steps=total_steps,
    )


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    api: ModelAPI
    step_fn: Any            # the python callable to jit
    arg_specs: Tuple        # ParamSpec trees, one per argument
    donate: Tuple[int, ...]


def build_cell(cfg: ModelConfig, shape: ShapeConfig) -> Cell:
    api = build_model(cfg)
    if shape.kind == "train":
        ocfg = opt_config_for(cfg)
        A = max(1, cfg.grad_accum)

        def train_step(params, opt_state, batch):
            if A == 1:
                (loss, _), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(
                    params, batch
                )
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch
                )

                def micro(carry, b):
                    gacc, lacc = carry
                    (l, _), g = jax.value_and_grad(api.loss_fn, has_aux=True)(
                        params, b
                    )
                    gacc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), gacc, g)
                    return (gacc, lacc + l), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
                (gsum, lsum), _ = jax.lax.scan(
                    micro, (g0, jnp.zeros((), jnp.float32)), mb
                )
                grads = jax.tree.map(lambda g: g / A, gsum)
                loss = lsum / A
            new_params, new_state, om = opt_update(ocfg, grads, opt_state, params)
            return new_params, new_state, dict(loss=loss, **om)

        ostate = opt_state_specs(ocfg, api.param_specs)
        return Cell(
            cfg, shape, api, train_step,
            (api.param_specs, ostate, api.batch_specs(shape)),
            donate=(0, 1),
        )
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return api.prefill_fn(params, batch)

        return Cell(cfg, shape, api, prefill_step,
                    (api.param_specs, api.batch_specs(shape)), donate=())
    # decode
    def serve_step(params, cache, batch):
        return api.decode_fn(params, cache, batch)

    return Cell(
        cfg, shape, api, serve_step,
        (api.param_specs, api.cache_decl(shape), api.batch_specs(shape)),
        donate=(1,),
    )


def cell_structs(cell: Cell, mesh: Optional[Mesh]):
    """ShapeDtypeStructs (with shardings) for every step argument."""
    rules = rules_for(cell.cfg, cell.shape)
    return tuple(shape_structs(t, mesh, {**_merged(rules)}) for t in cell.arg_specs)


def _merged(rules):
    from repro.runtime.sharding import DEFAULT_RULES

    out = dict(DEFAULT_RULES)
    out.update(rules)
    return out


def lower_cell(cell: Cell, mesh: Mesh):
    """jit + lower the cell on the mesh (no execution, no allocation)."""
    rules = rules_for(cell.cfg, cell.shape)
    structs = cell_structs(cell, mesh)
    fn = jax.jit(cell.step_fn, donate_argnums=cell.donate)
    with mesh:
        with axis_rules(mesh, rules):
            lowered = fn.lower(*structs)
    return lowered
