"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the "pod"
axis carries data parallelism (and optionally ZeRO / pipeline stages) over
the slower inter-pod links.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh over however many host devices exist (tests/examples)."""
    import numpy as np

    n = int(np.prod(shape))
    devs = jax.devices()
    assert len(devs) >= n, (len(devs), shape)
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(shape), axes)
