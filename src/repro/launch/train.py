"""Training driver: real steps on whatever devices exist.

Production behaviors exercised here (and tested in tests/test_train_loop.py):
  * jit-compiled train step with logical-axis shardings
  * deterministic data replay keyed only by the step counter
  * periodic (async) checkpointing; --resume restores params/opt/step and
    continues bit-identically
  * elastic restore onto a different mesh than the writer's
  * optional int8 gradient compression with error feedback (--compress-grads)

Usage (CPU example run; the full configs need the dry-run meshes):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
      --steps 20 --ckpt-dir /tmp/ck --ckpt-every 10
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, SHAPES, ShapeConfig, smoke_shape
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import OptConfig, compress_tree, init_ef, opt_init, opt_update
from repro.runtime.sharding import axis_rules, materialize
from repro.launch.steps import opt_config_for, rules_for


def make_train_state(api, ocfg: OptConfig, seed: int = 0):
    params = materialize(api.param_specs, jax.random.PRNGKey(seed))
    opt_state = opt_init(ocfg, params)
    return {"params": params, "opt": opt_state, "step": np.int64(0)}


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 20,
    shape: Optional[ShapeConfig] = None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    resume: bool = False,
    compress_grads: bool = False,
    mesh=None,
    log_every: int = 10,
    seed: int = 0,
    data_source: str = "markov",
    lr: float = 3e-4,
) -> Dict[str, Any]:
    cfg = ARCHS[arch].smoke() if smoke else ARCHS[arch]
    shape = shape or (smoke_shape("train") if smoke else SHAPES["train_4k"])
    api = build_model(cfg)
    ocfg = opt_config_for(cfg, total_steps=max(steps, 10))
    ocfg = dataclasses.replace(ocfg, lr=lr, warmup_steps=min(20, max(steps // 10, 1)))
    pipe = TokenPipeline(DataConfig(seed=seed + 1, source=data_source), cfg, shape)

    def step_fn(state, batch, ef):
        (loss, metrics), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(
            state["params"], batch
        )
        if compress_grads:
            grads, ef = compress_tree(grads, ef)
        params, opt_state, om = opt_update(ocfg, grads, state["opt"], state["params"])
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        return new_state, dict(loss=loss, **om), ef

    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    ck = Checkpointer(ckpt_dir) if ckpt_dir else None
    state = None
    start_step = 0
    if resume and ck and ck.latest_step() is not None:
        restored = ck.restore()
        state = {
            "params": restored["params"],
            "opt": restored["opt"],
            "step": jnp.asarray(restored["meta"]["step"], jnp.int32),
        }
        start_step = int(restored["meta"]["step"])
        print(f"[train] resumed from step {start_step}")
    if state is None:
        state = make_train_state(api, ocfg, seed)
        state["step"] = jnp.asarray(0, jnp.int32)

    ef = None
    if compress_grads:
        ef = jax.tree.map(
            lambda ps: jnp.zeros(ps.shape, jnp.float32), api.param_specs,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
        )

    losses = []
    ctx = axis_rules(mesh, rules_for(cfg, shape)) if mesh else axis_rules(None)
    with ctx:
        t0 = time.time()
        for s in range(start_step, steps):
            raw = pipe.with_frontend(pipe.batch_at(s), s)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            state, metrics, ef = jit_step(state, batch, ef)
            losses.append(float(metrics["loss"]))
            if log_every and (s + 1) % log_every == 0:
                dt = (time.time() - t0) / max(s + 1 - start_step, 1)
                print(
                    f"[train] step {s+1} loss={losses[-1]:.4f} "
                    f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['gnorm']):.2f} "
                    f"({dt*1e3:.0f} ms/step)"
                )
            if ck and ckpt_every and (s + 1) % ckpt_every == 0:
                ck.save(
                    s + 1,
                    {
                        "params": state["params"],
                        "opt": state["opt"],
                        "meta": {"step": np.asarray(s + 1)},
                    },
                    blocking=False,
                )
        if ck:
            ck.wait()
    return {"losses": losses, "state": state, "config": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    out = train(
        args.arch,
        smoke=args.smoke,
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        compress_grads=args.compress_grads,
    )
    print(f"final loss: {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
