"""Aggregate dry-run artifacts into the §Roofline table.

    PYTHONPATH=src python -m repro.launch.roofline            # markdown table
    PYTHONPATH=src python -m repro.launch.roofline --pick     # hillclimb picks
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"),
)


def load(mesh: str = "single"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        r = json.load(open(f))
        recs.append(r)
    return recs


def fmt_row(r) -> str:
    if r["status"] == "skipped":
        return (
            f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — |"
        )
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | ERROR: {r['error'][:50]} |"
    t = r["roofline"]
    mem = r.get("bytes_per_device", 0) / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.4f} | "
        f"{t['t_memory_s']:.4f} | {t['t_collective_s']:.4f} | "
        f"{t['dominant']} | {t['roofline_fraction']:.3f} | "
        f"{t.get('useful_ratio', 0):.2f} | {mem:.1f} |"
    )


def table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "roofline frac | 6ND/HLO | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        rows.append(fmt_row(r))
    return "\n".join(rows)


def picks():
    """Choose the three hillclimb cells per the assignment rubric."""
    recs = [r for r in load("single") if r["status"] == "ok"]
    by_frac = sorted(recs, key=lambda r: r["roofline"]["roofline_fraction"])
    worst = by_frac[0]
    coll = sorted(
        recs,
        key=lambda r: -(
            r["roofline"]["t_collective_s"]
            / max(sum((r["roofline"]["t_compute_s"], r["roofline"]["t_memory_s"],
                       r["roofline"]["t_collective_s"])), 1e-30)
        ),
    )[0]
    # most representative of the paper: the MoE giant (batch of per-expert
    # GEMMs == the paper's batch-matmul setting + coded serving target)
    rep = next(r for r in recs if r["arch"] == "kimi-k2-1t-a32b" and r["shape"] == "train_4k")
    return worst, coll, rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--pick", action="store_true")
    args = ap.parse_args()
    if args.pick:
        w, c, r = picks()
        for label, rec in [("worst-fraction", w), ("most-collective", c), ("paper-representative", r)]:
            t = rec["roofline"]
            print(
                f"{label}: {rec['arch']} x {rec['shape']} "
                f"(frac={t['roofline_fraction']:.3f}, dom={t['dominant']}, "
                f"t=({t['t_compute_s']:.3f},{t['t_memory_s']:.3f},{t['t_collective_s']:.3f}))"
            )
        return
    print(table(args.mesh))


if __name__ == "__main__":
    main()
