import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — bytes/device: proves (or disproves) HBM fit
  * compiled.cost_analysis()    — per-device FLOPs / bytes for §Roofline
  * collective schedule + payload bytes parsed from the compiled HLO

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and are
consumed by launch/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, SHAPES, cells  # noqa: E402
from repro.launch.costmodel import analytic_costs  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes, roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell, lower_cell  # noqa: E402
from repro.runtime.sharding import param_bytes, param_count  # noqa: E402

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"),
)


def _mem_dict(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        print(f"[skip] {path} exists")
        return json.load(open(path))
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "status": "ok",
    }
    try:
        cell = build_cell(cfg, shape)
        rec["param_count"] = param_count(cell.api.param_specs)
        rec["param_bytes"] = param_bytes(cell.api.param_specs)
        lowered = lower_cell(cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = dict(compiled.cost_analysis() or {})
        mem = _mem_dict(compiled)
        text = compiled.as_text()
        coll = collective_bytes(text)
        ac = analytic_costs(cfg, shape)
        terms = roofline_terms(cost, text, chips, analytic=ac)
        rec.update(
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            cost={k: float(v) for k, v in cost.items() if np.isscalar(v)},
            memory=mem,
            collectives=coll,
            roofline=terms,
        )
        per_dev = (
            mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        )
        rec["bytes_per_device"] = per_dev
        rec["fits_16gb"] = bool(per_dev <= 16 * 2**30) if per_dev else None
        print(
            f"[ok] {arch} {shape_name} {mesh_kind}: "
            f"compile={t_compile:.1f}s flops/chip={terms['flops_per_chip']:.3g} "
            f"coll={terms['collective_bytes_per_chip']:.3g}B "
            f"dom={terms['dominant']} frac={terms['roofline_fraction']:.3f} "
            f"mem/dev={per_dev/2**30:.2f}GiB"
        )
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} {shape_name} {mesh_kind}: {rec['error']}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run needs 512 host devices; do not import jax before this module"
    )
    todo = []
    for arch, shape_name, skipped in cells(include_skipped=True):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape_name != args.shape:
            continue
        if skipped:
            # record the documented skip (long_500k on quadratic-attention archs)
            os.makedirs(ART_DIR, exist_ok=True)
            for mesh_kind in ("single", "multi"):
                path = os.path.join(ART_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
                if not os.path.exists(path):
                    json.dump(
                        {
                            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                            "status": "skipped",
                            "reason": "long_500k needs sub-quadratic attention "
                            "(DESIGN.md §4)",
                        },
                        open(path, "w"), indent=1,
                    )
            continue
        for mesh_kind in ("single", "multi"):
            if args.mesh and mesh_kind != args.mesh:
                continue
            todo.append((arch, shape_name, mesh_kind))

    print(f"dry-run: {len(todo)} cells")
    n_ok = n_fail = 0
    for arch, shape_name, mesh_kind in todo:
        rec = run_cell(arch, shape_name, mesh_kind, force=args.force)
        if rec.get("status") == "ok":
            n_ok += 1
        elif rec.get("status") == "error":
            n_fail += 1
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
