"""Serving driver: prefill + decode loop with KV/state caches, optional
EP_RMFE-coded quantized FFN execution with straggler injection.

The coded path (--coded) swaps a designated matmul onto the CDMM plane:
int8-quantized, lifted to Z_{2^32}, EP_RMFE-I encoded across N simulated
workers, decoded from the first R responders — bit-identical outputs under
worker failures (tests/test_serving.py asserts equality vs uncoded int8).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm import CodedQuantMatmul, ProblemSpec, coded_matmul, plan
from repro.configs import ARCHS, ShapeConfig
from repro.core import make_ring, sample_trace
from repro.models import build_model
from repro.runtime.sharding import materialize


def greedy_generate(
    arch: str,
    *,
    smoke: bool = True,
    prompt_len: int = 8,
    gen_len: int = 8,
    batch: int = 2,
    seed: int = 0,
) -> Dict[str, Any]:
    cfg = ARCHS[arch].smoke() if smoke else ARCHS[arch]
    api = build_model(cfg)
    params = materialize(api.param_specs, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    cache_shape = ShapeConfig("serve", prompt_len + gen_len + 8, batch, "decode")
    cache = jax.tree.map(
        lambda ps: jnp.zeros(ps.shape, ps.dtype),
        api.cache_decl(cache_shape),
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
    )
    decode = jax.jit(api.decode_fn, donate_argnums=(1,))

    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    # prefill token-by-token through the decode path (exercises cache writes)
    out_tokens = []
    logits = None
    for t in range(prompt_len):
        logits, cache = decode(params, cache, {"tokens": tokens[:, t : t + 1]})
    for t in range(gen_len):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(nxt))
        logits, cache = decode(params, cache, {"tokens": nxt})
    gen = np.concatenate(out_tokens, axis=1)
    return {"generated": gen, "config": cfg}


def coded_matmul_demo(
    N: int = 8, fail: int = 3, size: int = 64, seed: int = 0,
    backend: str = "local", privacy_t: int = 0, pool_workers: int = 4,
):
    """The paper's serving integration in one function: the planner picks a
    scheme for the problem spec, and the quantized coded matmul survives
    ``fail`` dead workers out of N bit-identically.

    ``backend`` selects the execution path for the planned integer scheme:
    ``"local"`` (sync, vmapped), ``"elastic"`` (event-driven master that
    decodes at the R-th response under a randomized join/slowdown trace —
    the straggler-tolerant serving mode), or ``"pool"`` (a real
    multi-process worker pool: ``pool_workers`` worker OS processes are
    spawned, serve the request over sockets, and are shut down on exit —
    ``repro.dist``'s production-shaped runtime).

    ``privacy_t > 0`` serves T-privately: the planner is restricted to the
    secure scheme families, encodes carry masked randomness from a fresh
    jax.random key, and any ``privacy_t`` colluding workers learn nothing
    about the operands.  (The int8-quantized plane stays insecure — secure
    serving routes the raw ring matmul.)
    """
    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=N, straggler_budget=fail,
        privacy_t=privacy_t,
    )
    # the quantized serving plane runs EP_RMFE-I; under a privacy budget the
    # planner instead searches the secure families (it never silently
    # downgrades privacy to an insecure scheme)
    objective = "time_to_R" if backend == "elastic" else "latency"
    p = plan(spec, objective=objective,
             schemes=["ep_rmfe1"] if privacy_t == 0 else None)
    chosen = p.best
    rng = np.random.default_rng(seed)
    mask = np.ones(N, dtype=bool)
    dead = rng.choice(N, size=fail, replace=False)
    mask[dead] = False

    exact = True
    if privacy_t == 0:
        cm = CodedQuantMatmul(N=N, axis_name=None, n=chosen.n, u=chosen.u,
                              v=chosen.v, w=chosen.w)
        x = rng.standard_normal((size, size)).astype(np.float32)
        w = rng.standard_normal((size, size)).astype(np.float32)
        y = cm(jnp.asarray(x), jnp.asarray(w), mask=jnp.asarray(mask))
        y_full = cm(jnp.asarray(x), jnp.asarray(w), mask=None)
        exact = bool(np.array_equal(np.asarray(y), np.asarray(y_full)))

    # the same planned scheme through the pluggable backend plane: the
    # elastic path races a randomized straggler trace and must still match
    # the sync path bit for bit (integer-exact any-R decode; secure schemes
    # decode bit-identically from the same key on every backend)
    scheme = p.instantiate()
    key = jax.random.PRNGKey(seed) if privacy_t > 0 else None
    A = scheme.base.random(rng, (size, size))
    B = scheme.base.random(rng, (size, size))
    exec_backend = backend
    pool = None
    if backend == "elastic":
        trace = sample_trace(
            jax.random.PRNGKey(seed), N, slowdown_prob=0.3
        ).restrict(mask)
        from repro.cdmm import ElasticBackend

        exec_backend = ElasticBackend(trace=trace)
    elif backend == "pool":
        from repro.dist import LocalPool, PoolBackend, PoolConfig

        pool = LocalPool(config=PoolConfig(workers=pool_workers))
        exec_backend = PoolBackend(pool)
    try:
        C = coded_matmul(
            A, B, scheme, backend=exec_backend,
            mask=None if backend == "elastic" else jnp.asarray(mask),
            key=key,
        )
        C_sync = coded_matmul(A, B, scheme, backend="local", key=key)
    finally:
        if pool is not None:
            pool.close()  # clean shutdown: reap every worker process
    backend_exact = bool(np.array_equal(np.asarray(C), np.asarray(C_sync)))
    return {
        "scheme": chosen.scheme,
        "backend": backend,
        "privacy_t": privacy_t,
        "partition": (chosen.u, chosen.v, chosen.w, chosen.n),
        "R": chosen.costs.R,
        "dead_workers": sorted(int(d) for d in dead),
        "bit_identical": exact and backend_exact,
    }


def batch_serving_demo(
    requests: int = 32, size: int = 64, pool_workers: int = 6,
    wait_ms: float = 50.0, target_batch: int = 8, privacy_t: int = 0,
    stats_every: float = 0.0, seed: int = 0, trace: bool = False,
    trace_out: str = "", obs_http_port: int = None,
) -> Dict[str, Any]:
    """Continuous-batching serving in one function: ``requests`` concurrent
    same-shape matmuls through :class:`repro.serve.ServeScheduler` over a
    pool the scheduler launches itself from a :class:`PoolConfig`,
    coalesced into RMFE batch codewords wherever the planner's
    ``"amortized"`` objective says one batch job beats per-request
    dispatch.  ``stats_every > 0`` prints a MERGED stats snapshot every
    that many seconds while requests are in flight: the engine's
    ``ServeStats`` (``serve_``-prefixed: fill, wait quantiles) and the
    pool master's transport accounting (``pool_``-prefixed: bytes on wire
    vs pre-codec raw, time-to-R quantiles) in one shared-schema dict.
    ``trace=True`` records per-request span timelines (:mod:`repro.obs`)
    and returns the last request's merged timeline; ``trace_out`` also
    writes it as Chrome ``trace_event`` JSON for about://tracing.
    ``obs_http_port`` (0 = ephemeral) serves the live telemetry plane
    (``/metrics`` ``/healthz`` ``/stats`` ``/trace/<rid>``) while
    requests run — point ``python -m repro.obs.top`` at it.
    """
    import json

    from repro import obs
    from repro.dist import PoolConfig
    from repro.serve import CoalescePolicy, ServeScheduler
    from repro.stats import merge_snapshots

    if trace:
        obs.set_enabled(True)
    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=pool_workers,
        straggler_budget=1, privacy_t=privacy_t,
    )
    rng = np.random.default_rng(seed)
    pairs = [
        (Z32.random(rng, (size, size)), Z32.random(rng, (size, size)))
        for _ in range(requests)
    ]
    policy = CoalescePolicy(
        target_batch_n=target_batch, max_wait_ms=wait_ms
    )

    def merged_stats(sched):
        # both snapshots arrive pre-prefixed (serve_* / pool_*)
        return merge_snapshots(sched.stats.snapshot(), sched.master.stats())

    timeline = None
    pool_cfg = PoolConfig(workers=pool_workers)
    if obs_http_port is not None:
        pool_cfg = pool_cfg.with_(obs_http_port=obs_http_port)
    with ServeScheduler(
        config=pool_cfg, policy=policy,
        max_queue=requests, seed=seed,
    ) as sched:
        if obs_http_port is not None:
            from repro.obs import http as obs_http

            srv = obs_http.server()
            if srv is not None:
                print(f"obs admin plane: {srv.url}/metrics  {srv.url}/stats"
                      f"  (python -m repro.obs.top --url {srv.url})")
        futs = [sched.submit(A, B, spec=spec) for A, B in pairs]
        if stats_every > 0:
            while any(not f.done() for f in futs):
                time.sleep(stats_every)
                snap = merged_stats(sched)
                print(json.dumps({
                    k: snap[k] for k in (
                        "serve_submitted", "serve_completed",
                        "serve_batches", "serve_mean_fill",
                        "serve_wait_ms_p50", "serve_wait_ms_p99",
                        "pool_completed", "pool_bytes_out",
                        "pool_raw_bytes_out", "pool_time_to_R_ms_p50",
                    )
                }))
        results = [np.asarray(f.result(timeout=600)) for f in futs]
        snap = merged_stats(sched)
        if trace:
            timeline = sched.trace(futs[-1])
    ok = all(
        np.array_equal(C, np.asarray(Z32.matmul(A, B)))
        for C, (A, B) in zip(results, pairs)
    )
    out: Dict[str, Any] = {"bit_identical": ok, "stats": snap}
    if timeline is not None:
        out["timeline"] = timeline
        if trace_out:
            with open(trace_out, "w") as f:
                f.write(obs.to_chrome_trace(timeline, indent=1))
            print(f"wrote Chrome trace_event timeline to {trace_out} "
                  f"(load in about://tracing or ui.perfetto.dev)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--coded", action="store_true")
    ap.add_argument(
        "--coded-backend", default="local",
        choices=["local", "elastic", "pool"],
        help="execution backend for the coded matmul plane (elastic = "
        "event-driven any-R decode, races past stragglers; pool = real "
        "multi-process worker pool over sockets, repro.dist)",
    )
    ap.add_argument(
        "--pool-workers", type=int, default=4, metavar="N",
        help="worker OS processes to spawn for --coded-backend pool "
        "(shut down cleanly on exit)",
    )
    ap.add_argument(
        "--privacy-t", type=int, default=0, metavar="T",
        help="serve the coded matmul plane T-privately: any T colluding "
        "workers' shares are statistically independent of the operands "
        "(restricts the planner to the secure scheme families and raises "
        "the recovery threshold to 2uvw + 2T - 1)",
    )
    ap.add_argument(
        "--serve", type=int, default=0, metavar="REQUESTS",
        help="continuous-batching demo: serve this many concurrent "
        "same-shape coded matmuls through repro.serve, coalescing them "
        "into RMFE batch codewords where the amortized objective says a "
        "batch job beats per-request dispatch (0 = off)",
    )
    ap.add_argument(
        "--serve-batch", type=int, default=8, metavar="N",
        help="--serve policy: max batch arity the amortized planner "
        "scans when deciding how many requests to coalesce",
    )
    ap.add_argument(
        "--serve-wait-ms", type=float, default=50.0, metavar="MS",
        help="--serve policy: max time a request waits for batch peers "
        "before a partial batch is padded and dispatched",
    )
    ap.add_argument(
        "--stats-every", type=float, default=0.0, metavar="SECONDS",
        help="print the serving engine's stats snapshot (fill, wait "
        "histogram quantiles, amortized us/request) this often while "
        "--serve requests are in flight (0 = only the final snapshot)",
    )
    ap.add_argument(
        "--trace", action="store_true",
        help="record per-request span timelines (repro.obs) for --serve: "
        "admission -> coalesce -> encode -> wire -> per-worker compute -> "
        "any-R decode; prints a span summary of the last request",
    )
    ap.add_argument(
        "--trace-out", default="", metavar="PATH",
        help="with --trace: also write the last request's timeline as "
        "Chrome trace_event JSON (open in about://tracing / perfetto)",
    )
    ap.add_argument(
        "--obs-http", type=int, default=None, metavar="PORT",
        help="with --serve: expose the live telemetry plane (/metrics "
        "/healthz /stats /trace/<rid>) on this port while requests run "
        "(0 = ephemeral; also via REPRO_OBS_HTTP_PORT)",
    )
    args = ap.parse_args()
    t0 = time.time()
    out = greedy_generate(args.arch, smoke=args.smoke, gen_len=args.gen_len)
    print(f"generated tokens ({time.time()-t0:.1f}s):\n{out['generated']}")
    if args.serve > 0:
        import json

        demo = batch_serving_demo(
            requests=args.serve, pool_workers=args.pool_workers,
            wait_ms=args.serve_wait_ms, target_batch=args.serve_batch,
            privacy_t=args.privacy_t, stats_every=args.stats_every,
            trace=args.trace, trace_out=args.trace_out,
            obs_http_port=args.obs_http,
        )
        s = demo["stats"]
        print(
            f"batch serving [{args.serve} requests, {args.pool_workers} "
            f"workers]: {s['serve_batches']} batch jobs, mean fill "
            f"{s['serve_mean_fill']:.2f}, bit-identical={demo['bit_identical']}"
        )
        timeline = demo.get("timeline")
        if timeline is not None:
            print(f"last request timeline [{timeline.trace_id}] "
                  f"({timeline.wall_s * 1e3:.1f} ms wall):")
            for sp in timeline.spans:
                rel = (sp.t_start - timeline.t_start) * 1e3
                wid = sp.tags.get("wid")
                lane = f" wid={wid}" if wid is not None else ""
                print(f"  +{rel:8.2f}ms {sp.component:9s} {sp.name:13s} "
                      f"{sp.duration_s * 1e3:8.2f}ms{lane}")
        print(json.dumps(s, indent=2, default=str))
    if args.coded:
        demo = coded_matmul_demo(backend=args.coded_backend,
                                 privacy_t=args.privacy_t,
                                 pool_workers=args.pool_workers)
        private = (f" T={demo['privacy_t']}-private"
                   if demo["privacy_t"] else " int8")
        print(
            f"coded{private} matmul [{demo['scheme']} via {demo['backend']} "
            f"(u,v,w,n)={demo['partition']} "
            f"R={demo['R']}] with dead workers {demo['dead_workers']}: "
            f"bit-identical={demo['bit_identical']}"
        )


if __name__ == "__main__":
    main()
