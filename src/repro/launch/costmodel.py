"""Analytic FLOPs / HBM-bytes model per (arch x shape) — the roofline's
compute and memory terms.

Why analytic: XLA's HloCostAnalysis counts a `while` body ONCE, not
x trip-count, so compiled.cost_analysis() under-reports any scanned-layer
model by ~num_units (verified on gemma2-2b: raw 2.05e13 flops/chip vs
analytic 9.1e13 — ratio == the 13-unit scan).  We therefore derive the
compute/memory terms from explicit formulas over the architecture configs
(every matmul in the model is enumerated below) and keep the raw XLA numbers
in the artifact for reference.  Collective bytes DO come from the compiled
HLO — with while-trip multipliers (hlo_analysis.collective_bytes_tripaware).

Conventions:
  fwd FLOPs — 2*m*n*k per matmul, global (whole step, all chips).
  train = 4x layer fwd (fwd + 2x bwd + 1x remat recompute) + 3x logits.
  bytes — HBM traffic estimate: weight reads per use, activation
  boundaries, optimizer state read/write, KV/state cache traffic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class CellCost:
    fwd_flops: float
    total_flops: float          # per step, global
    hbm_bytes: float            # per step, global
    model_flops: float          # 6*N_active*D (train) / 2*N_active*D (fwd)
    param_count: float
    active_param_count: float
    notes: str = ""


def _attn_ctx(S: int, layer_type: str, window: int, kind: str) -> float:
    """Average attended length per query."""
    if kind == "decode":
        return float(S)  # one query against the whole cache
    full = S / 2.0  # causal average
    if layer_type == "local":
        return float(min(window, full))
    return full


def _attn_flops(cfg: ModelConfig, T: float, S: int, kind: str, layer_type: str) -> float:
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_model
    proj = 2 * T * d * (H * hd + 2 * KV * hd) + 2 * T * (H * hd) * d
    ctx = _attn_ctx(S, layer_type, cfg.window_size, kind)
    core = 2 * 2 * T * H * hd * ctx
    return proj + core


def _mlp_flops(cfg: ModelConfig, T: float, ff: int) -> float:
    nmat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    return 2 * T * cfg.d_model * ff * nmat


def _moe_flops(cfg: ModelConfig, T: float) -> float:
    d, E, k, eff = cfg.d_model, cfg.num_experts, cfg.experts_per_tok, cfg.expert_d_ff
    router = 2 * T * d * E
    rows = T * k * cfg.capacity_factor
    nmat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    experts = 2 * rows * d * eff * nmat
    shared = _mlp_flops(cfg, T, eff * cfg.shared_experts) if cfg.shared_experts else 0
    return router + experts + shared


def _ssd_flops(cfg: ModelConfig, T: float, kind: str) -> float:
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    proj = 2 * T * d * (2 * di + 2 * N + H) + 2 * T * di * d
    conv = 2 * T * (di + 2 * N) * cfg.ssm_conv
    Q = cfg.ssm_chunk if kind != "decode" else 1
    core = T * (2 * Q * N + 2 * Q * di + 4 * N * di)
    return proj + conv + core


def _param_counts(cfg: ModelConfig) -> Dict[str, float]:
    d, V = cfg.d_model, cfg.vocab_size
    embed = V * d  # tied head
    per_attn = d * (cfg.num_heads * cfg.hd + 2 * cfg.num_kv_heads * cfg.hd) + (
        cfg.num_heads * cfg.hd
    ) * d
    nmat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    per_mlp = nmat * d * cfg.d_ff
    per_moe = (
        d * cfg.num_experts
        + nmat * cfg.num_experts * d * cfg.expert_d_ff
        + (nmat * d * cfg.expert_d_ff * cfg.shared_experts)
    )
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim if di else 0
    per_ssd = (
        d * (2 * di + 2 * N + H) + di * d + cfg.ssm_conv * (di + 2 * N) if di else 0
    )
    total = embed
    active = embed
    L = cfg.num_layers
    if cfg.family in ("dense", "vlm", "encdec"):
        total += L * (per_attn + per_mlp)
        active = total
        if cfg.family == "encdec":
            total += cfg.encoder_layers * (per_attn + per_mlp) + L * per_attn  # xattn
            total += cfg.frontend_dim * d
            active = total
        if cfg.family == "vlm":
            total += cfg.frontend_dim * d
            active = total
    elif cfg.family == "moe":
        dense_layers = cfg.first_k_dense
        moe_layers = L - dense_layers
        total += L * per_attn + dense_layers * per_mlp + moe_layers * per_moe
        active_moe = (
            d * cfg.num_experts
            + nmat * cfg.experts_per_tok * d * cfg.expert_d_ff
            + nmat * d * cfg.expert_d_ff * cfg.shared_experts
        )
        active = embed + L * per_attn + dense_layers * per_mlp + moe_layers * active_moe
    elif cfg.family == "ssm":
        total += L * per_ssd
        active = total
    elif cfg.family == "hybrid":
        shared = (2 * d) * d + per_attn + per_mlp
        total += L * per_ssd + shared
        # shared block params are REUSED every application: active compute uses
        # them (num_layers // every) times but memory holds them once
        active = total
    return {"total": total, "active": active}


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    Sq = 1 if kind == "decode" else S
    T = float(B * Sq)
    d, V = cfg.d_model, cfg.vocab_size
    L = cfg.num_layers

    fwd = 0.0
    logits = 2 * T * d * V
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        if cfg.family == "vlm" and kind != "decode":
            T = float(B * (Sq))  # patch tokens already inside seq_len budget
        pat = cfg.layer_pattern
        for li in range(L):
            lt = pat[li % len(pat)]
            fwd += _attn_flops(cfg, T, S, kind, lt)
            if cfg.family == "moe" and li >= cfg.first_k_dense:
                fwd += _moe_flops(cfg, T)
            else:
                fwd += _mlp_flops(cfg, T, cfg.d_ff if cfg.d_ff else cfg.expert_d_ff)
        if cfg.family == "encdec":
            Tsrc = float(B * max(S // cfg.src_ratio, 16)) if kind != "decode" else 0.0
            Ssrc = max(S // cfg.src_ratio, 16)
            H, hd = cfg.num_heads, cfg.hd
            for _ in range(cfg.encoder_layers):
                if Tsrc:
                    # bidirectional: every query attends the full source
                    proj = 2 * Tsrc * d * (H * hd + 2 * cfg.num_kv_heads * hd) + 2 * Tsrc * H * hd * d
                    fwd += proj + 2 * 2 * Tsrc * H * hd * Ssrc
                    fwd += _mlp_flops(cfg, Tsrc, cfg.d_ff)
            # cross attention in every decoder layer
            xctx = Ssrc
            fwd += L * (2 * T * d * (cfg.num_heads * cfg.hd) + 2 * 2 * T * cfg.num_heads * cfg.hd * xctx)
    elif cfg.family == "ssm":
        fwd += L * _ssd_flops(cfg, T, kind)
    elif cfg.family == "hybrid":
        fwd += L * _ssd_flops(cfg, T, kind)
        napp = L // cfg.shared_attn_every
        shared = (
            2 * T * (2 * d) * d
            + _attn_flops(cfg, T, S, kind, "global")
            + _mlp_flops(cfg, T, cfg.d_ff)
        )
        fwd += napp * shared
    fwd += logits

    if kind == "train":
        total = 4.0 * (fwd - logits) + 3.0 * logits
    else:
        total = fwd

    # ---- bytes ----
    pc = _param_counts(cfg)
    pbytes = pc["total"] * 2.0  # bf16
    act_io = 2.0  # bf16
    if kind == "train":
        opt_bytes = pc["total"] * (8.0 if cfg.optimizer == "adamw" else 0.1)
        # params: fwd + recompute + bwd reads, grad write+read, param write
        traffic = pbytes * 5.0 + opt_bytes * 2.0
        # activation boundaries: ~10 tensor r/w of (T, d) per layer
        traffic += L * T * d * act_io * 10.0
        traffic += T * V * 4.0 * 2.0  # logits fwd+bwd
    elif kind == "prefill":
        traffic = pbytes + L * T * d * act_io * 6.0 + T * V * 4.0
    else:  # decode: weight-read bound + cache read
        traffic = pbytes + T * V * 4.0
        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            cache = L * B * S * cfg.num_kv_heads * cfg.hd * 2 * 2.0
            traffic += cache
        if cfg.family in ("ssm", "hybrid"):
            di, N = cfg.ssm_d_inner, cfg.ssm_state
            H = di // cfg.ssm_head_dim
            traffic += L * B * H * N * cfg.ssm_head_dim * 4.0 * 2.0
            if cfg.family == "hybrid":
                napp = L // cfg.shared_attn_every
                traffic += napp * B * S * cfg.num_kv_heads * cfg.hd * 2 * 2.0

    tokens = T
    mf = (6.0 if kind == "train" else 2.0) * pc["active"] * tokens
    return CellCost(
        fwd_flops=fwd,
        total_flops=total,
        hbm_bytes=traffic,
        model_flops=mf,
        param_count=pc["total"],
        active_param_count=pc["active"],
    )
