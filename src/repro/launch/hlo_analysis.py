"""Extract roofline terms from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak)          [cost_analysis]
memory term     = HLO_bytes / (chips * hbm_bw)        [cost_analysis]
collective term = collective_bytes / (chips * link_bw)[parsed from HLO text]

cost_analysis of the SPMD-partitioned module is per-device, so the flops /
bytes it reports are already divided by the device count; we therefore use
per-chip peaks directly.  collective_bytes sums the RESULT buffer sizes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute in the per-device program (documented approximation:
result size ~ payload per hop).
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e-ish constants from the assignment
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALLEE = re.compile(
    r"(?:body|condition|to_apply|called_computations=\{|branch_computations=\{)"
    r"[=]?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)"
)


def _split_computations(hlo_text: str):
    """name -> list of instruction lines (handles the flat HLO text format)."""
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if s.endswith("{") and ("(" in s or s.startswith("ENTRY")):
            m = _COMP_HEAD.match(s)
            if m and not s.startswith(("while", "fusion")):
                cur = m.group(1)
                comps[cur] = []
                if raw.startswith("ENTRY") or s.startswith("ENTRY"):
                    entry = cur
                continue
        if s == "}" or s.startswith("} "):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps, entry


def _trip_count(cond_lines) -> int:
    """Trip count of a canonical lax.scan/fori condition: compare(i, C), LT."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    for ln in cond_lines:
        if "compare(" in ln and "direction=LT" in ln:
            return max(consts) if consts else 1
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Trip-count-aware collective payload accounting.

    XLA prints a while body once; its collectives execute trip-count times.
    We walk the computation graph from ENTRY, multiplying by parsed loop
    bounds (canonical lax.scan conditions), so collectives inside scanned
    layers are charged correctly.
    """
    comps, entry = _split_computations(hlo_text)
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    if entry is None:
        return out

    import functools

    call_re = re.compile(
        r"(?:body=%?([\w\.\-]+)|condition=%?([\w\.\-]+)|to_apply=%?([\w\.\-]+)"
        r"|calls=%?([\w\.\-]+))"
    )

    def local_and_edges(name):
        local = {k: 0 for k in _COLLECTIVES}
        nloc = 0
        edges = []  # (callee, multiplier_is_loop_body, cond_name)
        for ln in comps.get(name, []):
            if "=" in ln:
                rhs = ln.split("=", 1)[1]
                for kind in _COLLECTIVES:
                    if re.search(rf"\b{kind}(-start)?\(", rhs) and "-done" not in rhs.split("(")[0]:
                        local[kind] += _shape_bytes(rhs.split(f" {kind}")[0])
                        nloc += 1
                        break
            body = re.search(r"body=%?([\w\.\-]+)", ln)
            cond = re.search(r"condition=%?([\w\.\-]+)", ln)
            if body:
                edges.append((body.group(1), cond.group(1) if cond else None))
            for pat in (r"to_apply=%?([\w\.\-]+)", r"calls=%?([\w\.\-]+)"):
                m = re.search(pat, ln)
                if m and not body:
                    edges.append((m.group(1), None))
            bm = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if bm:
                for b in bm.group(1).split(","):
                    edges.append((b.strip().lstrip("%"), None))
        return local, nloc, edges

    seen_stack = set()

    @functools.lru_cache(maxsize=None)
    def total(name):
        if name in seen_stack or name not in comps:
            return {k: 0 for k in _COLLECTIVES}, 0
        seen_stack.add(name)
        local, nloc, edges = local_and_edges(name)
        agg = dict(local)
        n = nloc
        for callee, cond in edges:
            sub, subn = total(callee)
            mult = _trip_count(comps.get(cond, [])) if cond else 1
            for k in _COLLECTIVES:
                agg[k] += sub[k] * mult
            n += subn * mult
        seen_stack.discard(name)
        return agg, n

    agg, n = total(entry)
    out.update(agg)
    out["count"] = n
    return out


def roofline_terms(
    cost: Dict, hlo_text: str, chips: int, analytic=None
) -> Dict[str, float]:
    """Three roofline terms in seconds.

    compute/memory come from the analytic model when provided (XLA's
    cost_analysis counts while bodies once — see costmodel.py); the raw XLA
    numbers are reported alongside.  Collectives come from the compiled HLO
    with while-trip multipliers.
    """
    flops_raw = float(cost.get("flops", 0.0) or 0.0)
    bytes_raw = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll = collective_bytes(hlo_text)
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))
    if analytic is not None:
        flops = analytic.total_flops / chips
        mem_bytes = analytic.hbm_bytes / chips
    else:
        flops, mem_bytes = flops_raw, bytes_raw
    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_collective = cbytes / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    rec = {
        "hlo_flops_per_chip_raw": flops_raw,
        "hlo_bytes_per_chip_raw": bytes_raw,
        "flops_per_chip": flops,
        "bytes_per_chip": mem_bytes,
        "collective_bytes_per_chip": cbytes,
        "collective_ops": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "roofline_fraction": (
            t_compute / max(t_compute, t_memory, t_collective, 1e-30)
        ),
    }
    if analytic is not None:
        rec["model_flops"] = analytic.model_flops
        rec["useful_ratio"] = analytic.model_flops / max(analytic.total_flops, 1e-30)
        rec["param_count"] = analytic.param_count
        rec["active_param_count"] = analytic.active_param_count
    return rec


def model_flops(cfg, shape, param_count: int, active_param_count: int) -> float:
    """6*N*D for train, 2*N*D for forward-only, per the assignment."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n = active_param_count
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * tokens
