"""Secure (T-private) CDMM: privacy-threshold / overhead sweep.

Analytic rows: for each collusion tolerance T the best-latency secure plan's
recovery threshold and communication, against the T=0 insecure baseline —
the "privacy tax" R = 2uvw + 2T - 1 and the mask-encode overhead.

Measured rows: wall-clock of one T=1-private coded matmul vs the insecure
baseline scheme on the same spec (LocalSimBackend; both integer-exact).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.cdmm import ProblemSpec, coded_matmul, plan
from repro.core import make_ring

from .common import emit, timeit


def run(full: bool = False):
    size = 128 if full else 64
    N = 16
    Z32 = make_ring(2, 32, ())

    base_plan = plan(
        ProblemSpec(size, size, size, n=1, ring=Z32, N=N), "latency"
    )
    b = base_plan.best.costs
    for T in (1, 2, 3):
        spec = ProblemSpec(size, size, size, n=1, ring=Z32, N=N, privacy_t=T)
        c = plan(spec, "latency").best.costs
        emit(
            f"secure_T{T}_N{N}", 0.0,
            R=c.R, R_insecure=b.R,
            upload=int(c.upload), download=int(c.download),
            download_overhead=round(c.download / b.download, 2),
            encode_overhead=round(c.encode_ops / b.encode_ops, 2),
        )

    # batched: the secure RMFE family amortizes the privacy tax over n
    for n in (2, 4):
        spec = ProblemSpec(size, size, size, n=n, ring=Z32, N=N, privacy_t=1)
        c = plan(spec, "download").best.costs
        emit(
            f"secure_batch_n{n}_T1_N{N}", 0.0,
            R=c.R, download=int(c.download), upload=int(c.upload),
        )

    # measured head-to-head at T=1 (same spec, same backend, fixed key)
    rng = np.random.default_rng(0)
    A = Z32.random(rng, (size, size))
    B = Z32.random(rng, (size, size))
    key = jax.random.PRNGKey(0)
    sec = plan(
        ProblemSpec(size, size, size, n=1, ring=Z32, N=N, privacy_t=1),
        "latency",
    ).instantiate()
    ins = base_plan.instantiate()
    us_ins = timeit(lambda: coded_matmul(A, B, ins))
    us_sec = timeit(lambda: coded_matmul(A, B, sec, key=key))
    emit(
        f"secure_matmul_T1_N{N}", us_sec,
        R=sec.R, scheme=sec.name,
        overhead_vs_insecure=round(us_sec / max(us_ins, 1e-9), 2),
    )
    emit(f"insecure_matmul_T0_N{N}", us_ins, R=ins.R, scheme=ins.name)
