"""Beyond-paper: time-to-completion under a straggler latency model.

The roofline argument for CDMM: with heavy-tailed worker latencies, an
uncoded N-shard matmul waits for the SLOWEST worker; EP-coded with threshold
R waits for the R-th fastest.  We sample the latency model of
core.straggler and report expected completion-time ratios, plus the measured
decode overhead that buys the tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm.api import EPSchemeAdapter
from repro.core import make_ring, straggler_latencies

from .common import emit, timeit


def run(full: bool = False):
    key = jax.random.PRNGKey(0)
    trials = 200 if not full else 2000
    for N, R in [(8, 4), (16, 9), (64, 36)]:
        tN, tR = [], []
        for i in range(trials):
            lat = np.sort(np.asarray(straggler_latencies(jax.random.fold_in(key, i), N)))
            tN.append(lat[-1])
            tR.append(lat[R - 1])
        emit(
            f"straggler_N{N}_R{R}", 0.0,
            uncoded_ms=round(float(np.mean(tN)), 2),
            coded_ms=round(float(np.mean(tR)), 2),
            speedup=round(float(np.mean(tN) / np.mean(tR)), 2),
        )
    # decode cost that buys the tolerance (N=8 paper regime, 256^2 blocks)
    ring = make_ring(2, 32, (3,))
    sch = EPSchemeAdapter(ring, N=8, u=2, v=2, w=1)
    rng = np.random.default_rng(0)
    A = ring.random(rng, (256, 256))
    B = ring.random(rng, (256, 256))
    FA, GB = sch.encode_a(A), sch.encode_b(B)
    H = sch.worker_compute(FA, GB)
    idx = jnp.arange(sch.R, dtype=jnp.int32)
    dec = jax.jit(lambda h: sch.decode(h, idx))
    emit("straggler_decode_cost_256", timeit(dec, H[: sch.R]))
