"""Beyond-paper: time-to-completion under a straggler latency model.

The roofline argument for CDMM: with heavy-tailed worker latencies, an
uncoded N-shard matmul waits for the SLOWEST worker; EP-coded with threshold
R waits for the R-th fastest.  We sample the latency model of
core.straggler and report expected completion-time ratios, plus the measured
decode overhead that buys the tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm import ElasticBackend, LocalSimBackend
from repro.cdmm.api import EPSchemeAdapter
from repro.core import make_ring, sample_trace, straggler_latencies

from .common import emit, timeit


def run(full: bool = False):
    key = jax.random.PRNGKey(0)
    trials = 200 if not full else 2000
    for N, R in [(8, 4), (16, 9), (64, 36)]:
        tN, tR = [], []
        for i in range(trials):
            lat = np.sort(np.asarray(straggler_latencies(jax.random.fold_in(key, i), N)))
            tN.append(lat[-1])
            tR.append(lat[R - 1])
        emit(
            f"straggler_N{N}_R{R}", 0.0,
            uncoded_ms=round(float(np.mean(tN)), 2),
            coded_ms=round(float(np.mean(tR)), 2),
            speedup=round(float(np.mean(tN) / np.mean(tR)), 2),
        )
    # decode cost that buys the tolerance (N=8 paper regime, 256^2 blocks)
    ring = make_ring(2, 32, (3,))
    sch = EPSchemeAdapter(ring, N=8, u=2, v=2, w=1)
    rng = np.random.default_rng(0)
    A = ring.random(rng, (256, 256))
    B = ring.random(rng, (256, 256))
    FA, GB = sch.encode_a(A), sch.encode_b(B)
    H = sch.worker_compute(FA, GB)
    idx = jnp.arange(sch.R, dtype=jnp.int32)
    dec = jax.jit(lambda h: sch.decode(h, idx))
    emit("straggler_decode_cost_256", timeit(dec, H[: sch.R]))

    # sync vs elastic head-to-head: same scheme (`sch`, with its jit/decode
    # caches already warm), same traces.  The sync backends barrier on all N
    # responses (virtual t_N); the elastic master decodes at the R-th
    # arrival (virtual t_R) — with simulated worker delays the *measured*
    # elastic wall-clock tracks t_R, not t_N.
    rngA = np.random.default_rng(1)
    A8 = sch.base.random(rngA, (64, 64))
    B8 = sch.base.random(rngA, (64, 64))
    sync = LocalSimBackend()
    runs = 5 if not full else 20
    traces = [
        sample_trace(
            jax.random.fold_in(key, 10_000 + i), 8,
            slowdown_prob=0.25, slowdown_factor=20.0,
        )
        for i in range(runs)
    ]
    # warmup pass compiles the shared worker closures and every subset
    # decoder; measured pass then shows master wall-clock, not XLA tracing
    for warm in (True, False):
        t_R_virt, t_N_virt, wall_elastic, wall_sync = [], [], [], []
        for tr in traces:
            with ElasticBackend(
                trace=tr, simulate_ms_scale=0.0 if warm else 1.0
            ) as eb:
                C_e, st = eb.run(sch, A8, B8)
            if warm:
                jax.block_until_ready(sync(sch, A8, B8, mask=jnp.asarray(tr.mask())))
                continue
            assert np.array_equal(np.asarray(C_e),
                                  np.asarray(sch.base.matmul(A8, B8)))
            t_R_virt.append(st.time_to_R_ms)
            t_N_virt.append(st.time_to_all_ms)
            wall_elastic.append(st.wall_ms)
            wall_sync.append(np.max(tr.response_ms()))  # the barrier's wait
    emit(
        "straggler_elastic_vs_sync_N8_R4",
        float(np.mean(wall_elastic)) * 1e3,
        virt_t_R_ms=round(float(np.mean(t_R_virt)), 2),
        virt_t_N_ms=round(float(np.mean(t_N_virt)), 2),
        sync_barrier_ms=round(float(np.mean(wall_sync)), 2),
        elastic_wall_ms=round(float(np.mean(wall_elastic)), 2),
        elastic_tracks_R=bool(
            np.mean(wall_elastic) < 0.8 * np.mean(wall_sync)
        ),
    )
