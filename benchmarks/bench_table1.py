"""Paper Table 1: Batch-EP_RMFE vs GCSA over a Galois ring.

Recovery threshold + per-product amortized costs from the analytic models,
plus a MEASURED head-to-head of the executable instances:
  Batch-EP_RMFE(n, N, u=v=w=1 MatDot-style or EP) vs CSA (= GCSA at
  u=v=w=1, kappa=n) on the same batch, and — now that the general
  construction executes — gcsa_general vs Batch-EP_RMFE at a MATCHED
  non-trivial partition (u, v, w) = (2, 2, 1), where the observed
  recovery-threshold gap must reproduce the paper's 1/n factor
  (``gap_measured`` vs ``gap_analytic`` in the emitted rows).

All executable schemes run through the unified CdmmScheme surface; the
planner's view of the same trade-off is emitted as ``table1_plan_*`` rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm import ProblemSpec, plan
from repro.cdmm.api import BatchRMFEAdapter, CSAAdapter, GCSAGeneralAdapter
from repro.core import gcsa_cost_model, make_ring

from .common import emit, timeit


def run(full: bool = False):
    # ----- analytic Table 1 (per-product amortized, base-ring elements) -----
    t = r = s = 512
    N = 64
    base = make_ring(2, 32, ())
    for n in [2, 4, 8]:
        for kappa in sorted({1, n}):
            u, v, w = 2, 2, 2
            m_eff = max(int(np.ceil(np.log2(N))), 2)
            g = gcsa_cost_model(t, r, s, u, v, w, n, kappa, N, m_eff)
            emit(
                f"table1_gcsa_n{n}_k{kappa}", 0.0,
                R=g.R, upload=int(g.upload), download=int(g.download),
                worker_ops=int(g.worker_ops),
            )
        sch = BatchRMFEAdapter(base, n, N, u=2, v=2, w=2)
        c = sch.costs(ProblemSpec(t=t, r=r, s=s, n=n, ring=base, N=N))
        emit(
            f"table1_rmfe_n{n}", 0.0,
            R=c.R, upload=int(c.upload), download=int(c.download),
            worker_ops=int(c.worker_ops),
            threshold_ratio=round(g.R / c.R, 2),
        )
        # the planner reproduces the Table-1 ranking from the same models:
        # download compared at the matched (u,v,w)=(1,1,1), kappa=n point
        # (GCSA's best-communication configuration — comparing against the
        # download-optimal RMFE point would pit it against trivial R=1
        # replication), best scheme reported under the upload objective
        spec = ProblemSpec(t=t, r=r, s=s, n=n, ring=base, N=N)
        p = plan(spec, objective="download")
        gc = p.by_scheme("gcsa")
        bm = next(
            (c for c in p.candidates
             if c.scheme == "batch_ep_rmfe" and (c.u, c.v, c.w) == (1, 1, 1)),
            None,
        )
        pu = plan(spec, objective="upload")
        emit(
            f"table1_plan_n{n}", 0.0,
            best_by_upload=pu.best.scheme, best_R=pu.best.costs.R,
            download_ratio_gcsa_matched=(
                round(gc.costs.download / bm.costs.download, 2)
                if gc and bm else None
            ),
        )

    # ----- measured: CSA vs Batch-EP_RMFE, same batch of L=n=3 products -----
    size = 96 if not full else 256
    base16 = make_ring(2, 16, ())
    L, Ncsa = 3, 8
    # CSA needs L + N = 11 exceptional points: adapter embeds Z_{2^16} into
    # GR(2^16, 4) (|T| = 16), the same ring the seed benchmark used
    csa = CSAAdapter(base16, n=L, N=Ncsa)
    rng = np.random.default_rng(0)
    schemes = {
        f"csa_L{L}_N{Ncsa}": csa,
        f"batchrmfe_L{L}_N{Ncsa}": BatchRMFEAdapter(base16, L, Ncsa, u=1, v=1, w=1),
    }
    for name, sch in schemes.items():
        As = base16.random(rng, (sch.batch, size, size))
        Bs = base16.random(rng, (sch.batch, size, size))
        enc = jax.jit(lambda a, b, sch=sch: (sch.encode_a(a), sch.encode_b(b)))
        FA, GB = enc(As, Bs)
        H = sch.worker_compute(FA, GB)
        idx = jnp.arange(sch.R, dtype=jnp.int32)
        dec = jax.jit(lambda h, sch=sch, idx=idx: sch.decode(h, idx))
        emit(f"{name}_encode", timeit(enc, As, Bs), R=sch.R)
        emit(
            f"{name}_worker",
            timeit(jax.jit(lambda a, b, sch=sch: sch.worker_compute(a, b)),
                   FA[:1], GB[:1]),
        )
        emit(f"{name}_decode", timeit(dec, H[: sch.R]), R=sch.R)

    # ----- measured: general GCSA vs Batch-EP_RMFE at matched partition -----
    # the paper's headline 1/n threshold gap, observed on executing codes:
    # same batch n=2, same N=8, same inner partition (2, 2, 1) —
    # R_gcsa = uvw * n + w - 1 = 8 responses vs R_rmfe = uvw + w - 1 = 4
    n2, Ng, (u, v, w) = 2, 8, (2, 2, 1)
    pair = {
        "gcsa_general": GCSAGeneralAdapter(base16, n2, Ng, u, v, w, kappa=1),
        "batchrmfe_matched": BatchRMFEAdapter(base16, n2, Ng, u, v, w),
    }
    Rs = {}
    for name, sch in pair.items():
        As = base16.random(rng, (sch.batch, size, size))
        Bs = base16.random(rng, (sch.batch, size, size))
        enc = jax.jit(lambda a, b, sch=sch: (sch.encode_a(a), sch.encode_b(b)))
        FA, GB = enc(As, Bs)
        H = sch.worker_compute(FA, GB)
        idx = jnp.arange(sch.R, dtype=jnp.int32)
        dec = jax.jit(lambda h, sch=sch, idx=idx: sch.decode(h, idx))
        Rs[name] = sch.R
        emit(f"table1_{name}_n{n2}_encode", timeit(enc, As, Bs), R=sch.R)
        emit(
            f"table1_{name}_n{n2}_worker",
            timeit(jax.jit(lambda a, b, sch=sch: sch.worker_compute(a, b)),
                   FA[:1], GB[:1]),
        )
        emit(f"table1_{name}_n{n2}_decode", timeit(dec, H[: sch.R]), R=sch.R)
    ga = gcsa_cost_model(size, size, size, u, v, w, n2, 1, Ng, 1.0)
    ba = Rs["batchrmfe_matched"]
    emit(
        f"table1_gap_n{n2}", 0.0,
        gap_measured=round(Rs["gcsa_general"] / ba, 2),
        gap_analytic=round(ga.R / (u * v * w + w - 1), 2),
        R_gcsa=Rs["gcsa_general"], R_rmfe=ba,
    )
