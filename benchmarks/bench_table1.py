"""Paper Table 1: Batch-EP_RMFE vs GCSA over a Galois ring.

Recovery threshold + per-product amortized costs from the analytic models,
plus a MEASURED head-to-head of the executable instances:
  Batch-EP_RMFE(n, N, u=v=w=1 MatDot-style or EP) vs CSA (= GCSA at
  u=v=w=1, kappa=n) on the same batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BatchEPRMFE, CSACode, gcsa_cost_model, make_ring

from .common import emit, timeit


def run(full: bool = False):
    # ----- analytic Table 1 (per-product amortized, base-ring elements) -----
    t = r = s = 512
    N = 64
    for n in [2, 4, 8]:
        for kappa in sorted({1, n}):
            u, v, w = 2, 2, 2
            m_eff = max(int(np.ceil(np.log2(N))), 2)
            g = gcsa_cost_model(t, r, s, u, v, w, n, kappa, N, m_eff)
            emit(
                f"table1_gcsa_n{n}_k{kappa}", 0.0,
                R=g.R, upload=int(g.upload), download=int(g.download),
                worker_ops=int(g.worker_ops),
            )
        base = make_ring(2, 32, ())
        sch = BatchEPRMFE(base, n=n, N=N, u=2, v=2, w=2)
        c = sch.costs(t, r, s)
        emit(
            f"table1_rmfe_n{n}", 0.0,
            R=c.R, upload=int(c.upload), download=int(c.download),
            worker_ops=int(c.worker_ops),
            threshold_ratio=round(g.R / c.R, 2),
        )

    # ----- measured: CSA vs Batch-EP_RMFE, same batch of L=n=3 products -----
    size = 96 if not full else 256
    ring16 = make_ring(2, 16, (4,))  # |T|=16 >= L+N
    L, Ncsa = 3, 8
    csa = CSACode(ring16, L=L, N=Ncsa)
    rng = np.random.default_rng(0)
    As = ring16.random(rng, (L, size, size))
    Bs = ring16.random(rng, (L, size, size))
    enc = jax.jit(lambda a, b: (csa.encode_a(a), csa.encode_b(b)))
    FA, GB = enc(As, Bs)
    H = csa.worker_compute(FA, GB)
    idx = jnp.arange(csa.R, dtype=jnp.int32)
    dec = jax.jit(lambda h: csa.decode(h, idx))
    emit(f"csa_L{L}_N{Ncsa}_encode", timeit(enc, As, Bs), R=csa.R)
    emit(
        f"csa_L{L}_N{Ncsa}_worker",
        timeit(jax.jit(lambda a, b: ring16.matmul(a, b)), FA[0], GB[0]),
    )
    emit(f"csa_L{L}_N{Ncsa}_decode", timeit(dec, H[: csa.R]), R=csa.R)

    base16 = make_ring(2, 16, ())
    sch = BatchEPRMFE(base16, n=L, N=Ncsa, u=1, v=1, w=1)  # R = 1!
    As2 = base16.random(rng, (sch.rmfe.n, size, size))
    Bs2 = base16.random(rng, (sch.rmfe.n, size, size))
    enc2 = jax.jit(lambda a, b: sch.encode(a, b))
    FA2, GB2 = enc2(As2, Bs2)
    H2 = sch.worker_compute(FA2, GB2)
    idx2 = jnp.arange(sch.R, dtype=jnp.int32)
    dec2 = jax.jit(lambda h: sch.decode(h, idx2))
    emit(f"batchrmfe_L{L}_N{Ncsa}_encode", timeit(enc2, As2, Bs2), R=sch.R)
    emit(
        f"batchrmfe_L{L}_N{Ncsa}_worker",
        timeit(jax.jit(lambda a, b: sch.ext.matmul(a, b)), FA2[0], GB2[0]),
    )
    emit(f"batchrmfe_L{L}_N{Ncsa}_decode", timeit(dec2, H2[: sch.R]), R=sch.R)
