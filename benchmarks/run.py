"""Benchmark entrypoint: one section per paper table/figure.

  figs2-5   bench_single_cdmm  — EP vs EP_RMFE-I/II, N=8/16 (measured; stage
                                 rows carry cost features for calibrate.py)
  table1    bench_table1       — GCSA vs Batch-EP_RMFE (analytic + measured CSA)
  kernels   bench_kernels      — gr_matmul ref wall-clock + kernel schedule
                                 + measured tuned-vs-static block configs
  straggler bench_straggler    — time-to-completion under straggler model
  secure    bench_secure       — T-private threshold/overhead sweep (privacy tax)
  serving   bench_serving      — requests/s batched (repro.serve coalescing)
                                 vs unbatched over a real worker pool
  wire      bench_wire         — bytes-on-wire raw vs packed/compressed share
                                 transport + time-to-R on a live pool

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses larger sizes.
``--json PATH`` additionally writes the rows as machine-readable JSON
(consumed by tools/check_bench.py for regression gating in CI).
"""
import argparse
import os


def main() -> None:
    # benchmark rows must measure STABLE configurations: without this,
    # plan() auto-loads benchmarks/calibration.json and the scheme a row
    # times would shift whenever the calibration is refit (circularly —
    # the calibration is fitted from these very rows), breaking row
    # identity for the regression gate and the rolling history
    os.environ.setdefault("REPRO_CALIBRATION", "off")
    # the per-backend calibration rows (bench_single_cdmm.bench_backends)
    # need an 8-device host mesh for their shard_map stage programs; CI
    # sets this workflow-wide, so defaulting it here keeps local
    # regenerations of benchmarks/calibration.json equivalent (must happen
    # before jax initializes its backends)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )
    sections = ("figs", "table1", "kernels", "straggler", "secure",
                "serving", "wire")
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only", default=None, metavar="SECTION[,SECTION...]",
        help=f"comma-separated subset of {sections} (default: all)",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write emitted rows as JSON to PATH",
    )
    args = ap.parse_args()

    only = set(sections if args.only is None else args.only.split(","))
    unknown = only - set(sections)
    if unknown:
        ap.error(f"unknown section(s) {sorted(unknown)}; choose from {sections}")

    from . import (
        bench_kernels,
        bench_secure,
        bench_serving,
        bench_single_cdmm,
        bench_straggler,
        bench_table1,
        bench_wire,
    )
    from .common import header, write_json

    header()
    if "kernels" in only:
        bench_kernels.verify()
        bench_kernels.run(args.full)
    if "table1" in only:
        bench_table1.run(args.full)
    if "straggler" in only:
        bench_straggler.run(args.full)
    if "secure" in only:
        bench_secure.run(args.full)
    if "serving" in only:
        bench_serving.run(args.full)
    if "wire" in only:
        bench_wire.run(args.full)
    if "figs" in only:
        bench_single_cdmm.run(args.full)
    if args.json:
        write_json(args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
