"""Benchmark entrypoint: one section per paper table/figure.

  figs2-5   bench_single_cdmm  — EP vs EP_RMFE-I/II, N=8/16 (measured)
  table1    bench_table1       — GCSA vs Batch-EP_RMFE (analytic + measured CSA)
  kernels   bench_kernels      — gr_matmul ref wall-clock + kernel schedule
  straggler bench_straggler    — time-to-completion under straggler model

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses larger sizes.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only", default=None,
        choices=[None, "figs", "table1", "kernels", "straggler"],
    )
    args = ap.parse_args()

    from . import bench_kernels, bench_single_cdmm, bench_straggler, bench_table1
    from .common import header

    header()
    if args.only in (None, "kernels"):
        bench_kernels.verify()
        bench_kernels.run(args.full)
    if args.only in (None, "table1"):
        bench_table1.run(args.full)
    if args.only in (None, "straggler"):
        bench_straggler.run(args.full)
    if args.only in (None, "figs"):
        bench_single_cdmm.run(args.full)


if __name__ == "__main__":
    main()
