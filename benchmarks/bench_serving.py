"""Serving throughput: coalesced continuous batching vs per-request dispatch.

The acceptance measurement of ``repro.serve``: the same stream of
concurrent same-shape requests over the same worker pool, served two ways —

  serving_unbatched  PoolScheduler, one single-CDMM job per request
  serving_batched    ServeScheduler, amortized-planned RMFE batch coalescing

Rows carry requests/s, per-request latency p50/p99 (submit-to-result,
futures timed individually) and the engine's mean batch fill.  The row's
``us`` is wall-clock per request across the whole stream — the regression
gate therefore tracks serving throughput history directly.  A third row,
``serving_traced``, re-runs the batched mode with ``repro.obs`` span
tracing enabled and reports its overhead against the untraced row (the
acceptance bound is <5%).

Two hedging rows measure the speculative re-dispatch plane:

  serving_hedged     the batched stream with ``hedge_factor=2`` and NO
                     stragglers — its ``overhead_pct`` against the
                     unhedged batched row is the <5% acceptance bound
                     (a healthy pool must never trip the hedge path);
  serving_straggler  a zero-slack scheme (R == N, every share needed) with
                     one worker's compute parked: time-to-R with hedging
                     off vs on, same request, scores reset so round-robin
                     re-offers the straggler a share each race.  The row's
                     ``us`` is the hedged time; ``unhedged_ms`` and
                     ``speedup`` carry the margin, and both decodes are
                     asserted bit-identical to the local sync backend.

Warmup matters more here than in the jit benches: the any-R ``decode_op``
compiles per live *subset* (up to C(N, R) distinct decoders), so the first
stream of each mode is a compile storm.  Each mode runs ``WARM_STREAMS``
full streams to reach the steady state the row claims to measure, then
takes the median of ``iters`` measured streams.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .common import emit

WARM_STREAMS = 2  # first stream compiles decode subsets; second settles


def _stream(submit, pairs) -> Dict:
    """Submit every pair at once, record submit->result latency per
    request and the stream's total wall-clock."""
    t0 = time.perf_counter()
    futs = [submit(A, B) for A, B in pairs]
    done_at: List[float] = []
    for f in futs:
        f.result(timeout=600)
        done_at.append(time.perf_counter() - t0)
    # result() is collected in submit order, so each request's true
    # completion is bounded by when its future resolved; with every future
    # resolved well before the loop reaches it, done_at converges to the
    # resolution times (the loop only blocks on stragglers)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "lat_s": done_at}


def run(full: bool = False) -> None:
    from repro.cdmm import ProblemSpec
    from repro.core import make_ring
    from repro.dist import LocalPool, PoolScheduler
    from repro.serve import CoalescePolicy, ServeScheduler

    workers = 6
    requests = 32 if full else 16
    size = 128 if full else 64
    iters = 3
    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=1,
    )
    rng = np.random.default_rng(0)
    pairs = [
        (Z32.random(rng, (size, size)), Z32.random(rng, (size, size)))
        for _ in range(requests)
    ]

    with LocalPool(workers=workers) as pool:
        # -- unbatched baseline: PoolScheduler, one job per request -------
        with PoolScheduler(
            pool.master, max_queue=requests, max_inflight=4,
        ) as sched:
            for _ in range(WARM_STREAMS):
                _stream(lambda A, B: sched.submit(A, B, spec=spec), pairs)
            runs = [
                _stream(lambda A, B: sched.submit(A, B, spec=spec), pairs)
                for _ in range(iters)
            ]
        r = sorted(runs, key=lambda x: x["wall_s"])[len(runs) // 2]
        lat = np.asarray(r["lat_s"]) * 1e3
        emit(
            f"serving_unbatched_{requests}x{size}",
            r["wall_s"] * 1e6 / requests,
            rps=round(requests / r["wall_s"], 2),
            p50_ms=round(float(np.percentile(lat, 50)), 1),
            p99_ms=round(float(np.percentile(lat, 99)), 1),
            mean_fill=1.0,
            workers=workers,
        )

        # -- coalesced: ServeScheduler, amortized RMFE batching -----------
        with ServeScheduler(
            pool.master,
            CoalescePolicy(target_batch_n=8, max_wait_ms=50.0),
            max_queue=requests, max_inflight=4, seed=0,
        ) as sched:
            for _ in range(WARM_STREAMS):
                _stream(lambda A, B: sched.submit(A, B, spec=spec), pairs)
            runs = [
                _stream(lambda A, B: sched.submit(A, B, spec=spec), pairs)
                for _ in range(iters)
            ]
            snap = sched.stats.snapshot()
        r = sorted(runs, key=lambda x: x["wall_s"])[len(runs) // 2]
        lat = np.asarray(r["lat_s"]) * 1e3
        batched_wall = r["wall_s"]
        emit(
            f"serving_batched_{requests}x{size}",
            r["wall_s"] * 1e6 / requests,
            rps=round(requests / r["wall_s"], 2),
            p50_ms=round(float(np.percentile(lat, 50)), 1),
            p99_ms=round(float(np.percentile(lat, 99)), 1),
            mean_fill=round(snap["serve_mean_fill"], 2),
            workers=workers,
        )

        # -- traced: same batched mode under repro.obs span recording -----
        from repro import obs

        obs.set_enabled(True)
        try:
            with ServeScheduler(
                pool.master,
                CoalescePolicy(target_batch_n=8, max_wait_ms=50.0),
                max_queue=requests, max_inflight=4, seed=0,
            ) as sched:
                _stream(lambda A, B: sched.submit(A, B, spec=spec), pairs)
                runs = [
                    _stream(
                        lambda A, B: sched.submit(A, B, spec=spec), pairs
                    )
                    for _ in range(iters)
                ]
        finally:
            obs.set_enabled(None)
            obs.tracer().clear()
        r = sorted(runs, key=lambda x: x["wall_s"])[len(runs) // 2]
        emit(
            f"serving_traced_{requests}x{size}",
            r["wall_s"] * 1e6 / requests,
            rps=round(requests / r["wall_s"], 2),
            overhead_pct=round(
                (r["wall_s"] / batched_wall - 1.0) * 100.0, 2
            ),
            workers=workers,
        )

        # -- hedged, no stragglers: the overhead acceptance row -----------
        # a healthy pool must not pay for the hedge plane: the sweep runs
        # every poll but the p95-derived deadline should never fire
        pool.master.hedge_factor = 2.0
        try:
            with ServeScheduler(
                pool.master,
                CoalescePolicy(target_batch_n=8, max_wait_ms=50.0),
                max_queue=requests, max_inflight=4, seed=0,
            ) as sched:
                _stream(lambda A, B: sched.submit(A, B, spec=spec), pairs)
                runs = [
                    _stream(
                        lambda A, B: sched.submit(A, B, spec=spec), pairs
                    )
                    for _ in range(iters)
                ]
        finally:
            pool.master.hedge_factor = 0.0
        hedged_total = int(pool.master.stats()["pool_hedged"])
        r = sorted(runs, key=lambda x: x["wall_s"])[len(runs) // 2]
        emit(
            f"serving_hedged_{requests}x{size}",
            r["wall_s"] * 1e6 / requests,
            rps=round(requests / r["wall_s"], 2),
            overhead_pct=round(
                (r["wall_s"] / batched_wall - 1.0) * 100.0, 2
            ),
            hedged=hedged_total,
            workers=workers,
        )

        # -- straggler race: hedged vs unhedged time-to-R -----------------
        _straggler_race(pool, workers=workers, full=full)


def _straggler_race(pool, workers: int, full: bool) -> None:
    """One parked worker on a zero-slack (R == N) scheme: without hedging
    the request waits out the injected delay; with hedging the overdue
    share re-ships to a spare worker at ~p95 x factor.  Emits the hedged
    time with the unhedged margin, after asserting both decodes equal the
    local sync backend bit for bit."""
    from repro.cdmm import ProblemSpec, coded_matmul, plan
    from repro.core import make_ring

    size = 48  # divisible by workers=6: zero-slack partitions exist
    delay_ms = 400.0
    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=0,
    )
    p = plan(spec, objective="threshold")
    # zero slack: the candidate with the LARGEST R (== N) — every share
    # is needed, so one parked worker stalls the whole decode
    rank = max(
        range(len(p.candidates)), key=lambda i: p.candidates[i].costs.R
    )
    scheme = p.instantiate(rank)
    assert scheme.R == scheme.N == workers, (scheme.R, scheme.N)
    rng = np.random.default_rng(0)
    A = Z32.random(rng, (size, size))
    B = Z32.random(rng, (size, size))
    oracle = np.asarray(coded_matmul(A, B, scheme, backend="local"))

    master = pool.master
    master.hedge_factor = 0.0
    # warm: jit every worker's ring matmul for this scheme's shard shape
    for _ in range(3):
        master.execute(scheme, A, B)
    # those rounds carry jit-compile round-trips (seconds) that would make
    # the p95-derived hedge deadline dwarf the injected delay; purge them,
    # then re-seed the window with steady-state rounds (6 shares each; the
    # deadline needs >= 8 samples before it arms)
    master.health.clear_window()
    for _ in range(2):
        master.execute(scheme, A, B)

    victim = master.live_workers()[0]
    master.task_delay_ms[victim] = delay_ms
    try:
        # hedged race FIRST: the victim's slow reply lands after the
        # request closes, so it never pollutes the share-ms window the
        # hedge deadline quantile reads
        master.health.reset_scores()  # cold: round-robin is blind again
        master.hedge_factor = 2.0
        C_hedged, st_hedged = master.execute(scheme, A, B)
        master.hedge_factor = 0.0

        master.health.reset_scores()
        C_plain, st_plain = master.execute(scheme, A, B)
    finally:
        master.hedge_factor = 0.0
        master.task_delay_ms.pop(victim, None)

    assert np.array_equal(np.asarray(C_hedged), oracle), "hedged != oracle"
    assert np.array_equal(np.asarray(C_plain), oracle), "unhedged != oracle"
    emit(
        f"serving_straggler_{size}x{size}",
        st_hedged.time_to_R_ms * 1e3,
        unhedged_ms=round(st_plain.time_to_R_ms, 1),
        hedged_ms=round(st_hedged.time_to_R_ms, 1),
        speedup=round(
            st_plain.time_to_R_ms / max(st_hedged.time_to_R_ms, 1e-9), 2
        ),
        hedged=st_hedged.hedged,
        delay_ms=delay_ms,
        workers=workers,
        bit_identical=True,
    )
