"""Paper Figs 2-5: plain EP vs EP_RMFE-I vs EP_RMFE-II over Z_{2^32}.

Measures master encode/decode time, per-worker compute time (wall clock,
XLA-CPU uint32 matmuls) and counts upload/download volume (bytes), for the
paper's two regimes:
  * N=8  workers -> GR(2^32, 3), u=v=2, w=1, R=4
  * N=16 workers -> GR(2^32, 4), u=v=w=2, R=9
n = 2 for both optimized variants, exactly as in §V (type II uses the
paper's measured configuration: B packed via phi1, A embedded).

All three schemes run through the unified CdmmScheme surface
(encode_a/encode_b/worker_compute/decode + costs(spec)) — the volumes come
straight from the shared analytic cost model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cdmm.api import (
    EPRMFE1Adapter,
    EPRMFE2Adapter,
    PlainCDMMAdapter,
    ProblemSpec,
)
from repro.core import make_ring

from .common import emit, timeit

WORD = 4  # bytes per Z_{2^32} element


def bench_one(N: int, uvw, sizes, iters: int = 3):
    u, v, w = uvw
    base = make_ring(2, 32, ())
    schemes = {
        "ep_plain": PlainCDMMAdapter(base, N, u, v, w),
        "ep_rmfe1": EPRMFE1Adapter(base, 2, N, u, v, w),
        "ep_rmfe2": EPRMFE2Adapter(base, 2, N, u, v, w),  # §V: split_a=False
    }
    rng = np.random.default_rng(0)

    for size in sizes:
        t = r = s = size
        A = base.random(rng, (t, r))
        B = base.random(rng, (r, s))
        spec = ProblemSpec(t=t, r=r, s=s, n=1, ring=base, N=N)
        for name, sch in schemes.items():
            m = sch.ring.D
            idx = jnp.arange(sch.R, dtype=jnp.int32)
            enc = jax.jit(lambda a, b, sch=sch: (sch.encode_a(a), sch.encode_b(b)))
            FA, GB = enc(A, B)
            worker = jax.jit(
                lambda fa, gb, sch=sch: sch.worker_compute(fa, gb)
            )
            H = sch.worker_compute(FA, GB)
            dec = jax.jit(lambda h, sch=sch, idx=idx: sch.decode(h, idx))
            e_us = timeit(enc, A, B, iters=iters)
            w_us = timeit(worker, FA[:1], GB[:1], iters=iters)
            d_us = timeit(dec, H[: sch.R], iters=iters)
            # master<->worker transfer proxy: host round-trip of the share
            # stack (memcpy bandwidth on this box) — the communication term
            # the calibration fit grounds its upload/download coefficient on
            comm = jax.jit(lambda fa: fa + jnp.uint32(0))
            c_us = timeit(lambda fa: np.asarray(comm(fa)), FA, iters=iters)
            c = sch.costs(spec)
            # every stage row carries its cost-model features + backend tag
            # so repro.cdmm.calibrate can fit wall-time coefficients from
            # the emitted JSON (backend="local": stages are the same jitted
            # calls the LocalSim/ShardMap masters run)
            emit(f"{name}_N{N}_s{size}_encode", e_us,
                 upload_B=int(c.upload * WORD), m=m,
                 encode_ops=c.encode_ops, backend="local")
            emit(f"{name}_N{N}_s{size}_worker", w_us, m=m,
                 worker_ops=c.worker_ops, backend="local")
            emit(f"{name}_N{N}_s{size}_decode", d_us,
                 download_B=int(c.download * WORD),
                 decode_ops=c.decode_ops, backend="local")
            emit(f"{name}_N{N}_s{size}_comm", c_us,
                 comm_elems=c.upload + c.download, backend="local")


def run(full: bool = False):
    sizes = [128, 256, 512] if not full else [256, 512, 1024, 2048]
    bench_one(8, (2, 2, 1), sizes)
    bench_one(16, (2, 2, 2), sizes)
