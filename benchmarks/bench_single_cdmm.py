"""Paper Figs 2-5: plain EP vs EP_RMFE-I vs EP_RMFE-II over Z_{2^32}.

Measures master encode/decode time, per-worker compute time (wall clock,
XLA-CPU uint32 matmuls) and counts upload/download volume (bytes), for the
paper's two regimes:
  * N=8  workers -> GR(2^32, 3), u=v=2, w=1, R=4
  * N=16 workers -> GR(2^32, 4), u=v=w=2, R=9
n = 2 for both optimized variants, exactly as in §V (type II uses the
paper's measured configuration: B packed via phi1, A embedded).

Paper's claims to validate (§V-B/C):
  I : encode ~ 1/2 EP, upload  1/2, worker 1/2, decode/download ~ EP.
  II: decode ~ 1/2 EP, download 1/2, worker 1/2, upload between EP and I.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EPRMFE_I, EPRMFE_II, PlainCDMM, make_ring

from .common import emit, timeit

WORD = 4  # bytes per Z_{2^32} element


def _volumes(N, R, tb, rb, sb, m, out_tb, out_sb):
    up = N * (tb * rb + rb * sb) * m * WORD
    down = R * out_tb * out_sb * m * WORD
    return up, down


def bench_one(N: int, uvw, sizes, iters: int = 3):
    u, v, w = uvw
    base = make_ring(2, 32, ())
    plain = PlainCDMM(base, N=N, u=u, v=v, w=w)
    t1 = EPRMFE_I(base, n=2, N=N, u=u, v=v, w=w)
    t2 = EPRMFE_II(base, n=2, N=N, u=u, v=v, w=w, split_a=False)
    m = plain.ext.D
    rng = np.random.default_rng(0)

    for size in sizes:
        t = r = s = size
        A = base.random(rng, (t, r))
        B = base.random(rng, (r, s))
        idx = jnp.arange(plain.R, dtype=jnp.int32)

        # ---- plain EP (Lemma III.1 baseline) ----
        eA = plain.ext.embed_base(A, base)
        eB = plain.ext.embed_base(B, base)
        enc = jax.jit(lambda a, b: (plain.code.encode_a(a), plain.code.encode_b(b)))
        FA, GB = enc(eA, eB)
        worker = jax.jit(lambda fa, gb: plain.ext.matmul(fa, gb))
        H = plain.code.worker_compute(FA, GB)
        dec = jax.jit(lambda h: plain.code.decode(h, idx))
        e_us = timeit(enc, eA, eB, iters=iters)
        w_us = timeit(worker, FA[0], GB[0], iters=iters)
        d_us = timeit(dec, H[: plain.R], iters=iters)
        up, down = _volumes(N, plain.R, t // u, r // w, s // v, m, t // u, s // v)
        emit(f"ep_plain_N{N}_s{size}_encode", e_us, upload_B=up, m=m)
        emit(f"ep_plain_N{N}_s{size}_worker", w_us, m=m)
        emit(f"ep_plain_N{N}_s{size}_decode", d_us, download_B=down)

        # ---- EP_RMFE-I ----
        enc1 = jax.jit(lambda a, b: t1.batch.encode(*t1.split(a, b)))
        FA1, GB1 = enc1(A, B)
        worker1 = jax.jit(lambda fa, gb: t1.ext.matmul(fa, gb))
        H1 = t1.batch.worker_compute(FA1, GB1)

        def dec1(h):
            Cs = t1.batch.decode(h, idx)
            acc = Cs[0]
            for i in range(1, t1.n):
                acc = base.add(acc, Cs[i])
            return acc

        dec1 = jax.jit(dec1)
        e_us = timeit(enc1, A, B, iters=iters)
        w_us = timeit(worker1, FA1[0], GB1[0], iters=iters)
        d_us = timeit(dec1, H1[: t1.R], iters=iters)
        up1, down1 = _volumes(N, t1.R, t // u, (r // 2) // w, s // v, m, t // u, s // v)
        emit(f"ep_rmfe1_N{N}_s{size}_encode", e_us, upload_B=up1, m=m)
        emit(f"ep_rmfe1_N{N}_s{size}_worker", w_us, m=m)
        emit(f"ep_rmfe1_N{N}_s{size}_decode", d_us, download_B=down1)

        # ---- EP_RMFE-II (paper §V configuration) ----
        enc2 = jax.jit(lambda a, b: (t2.code.encode_a(t2.pack_a(a)),
                                     t2.code.encode_b(t2.pack_b(b))))
        FA2, GB2 = enc2(A, B)
        worker2 = jax.jit(lambda fa, gb: t2.top.matmul(fa, gb))
        H2 = t2.code.worker_compute(FA2, GB2)
        dec2 = jax.jit(lambda h: t2.unpack(t2.code.decode(h, idx)))
        e_us = timeit(enc2, A, B, iters=iters)
        w_us = timeit(worker2, FA2[0], GB2[0], iters=iters)
        d_us = timeit(dec2, H2[: t2.R], iters=iters)
        up2, down2 = _volumes(
            N, t2.R, t // u, r // w, (s // 2) // v, m, t // u, (s // 2) // v
        )
        emit(f"ep_rmfe2_N{N}_s{size}_encode", e_us, upload_B=up2, m=m)
        emit(f"ep_rmfe2_N{N}_s{size}_worker", w_us, m=m)
        emit(f"ep_rmfe2_N{N}_s{size}_decode", d_us, download_B=down2)


def run(full: bool = False):
    sizes = [128, 256, 512] if not full else [256, 512, 1024, 2048]
    bench_one(8, (2, 2, 1), sizes)
    bench_one(16, (2, 2, 2), sizes)
