"""Paper Figs 2-5: plain EP vs EP_RMFE-I vs EP_RMFE-II over Z_{2^32}.

Measures master encode/decode time, per-worker compute time (wall clock,
XLA-CPU uint32 matmuls) and counts upload/download volume (bytes), for the
paper's two regimes:
  * N=8  workers -> GR(2^32, 3), u=v=2, w=1, R=4
  * N=16 workers -> GR(2^32, 4), u=v=w=2, R=9
n = 2 for both optimized variants, exactly as in §V (type II uses the
paper's measured configuration: B packed via phi1, A embedded).

All three schemes run through the unified CdmmScheme surface
(encode_a/encode_b/worker_compute/decode + costs(spec)) — the volumes come
straight from the shared analytic cost model.
"""
from __future__ import annotations

import queue

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.cdmm.api import (
    EPRMFE1Adapter,
    EPRMFE2Adapter,
    PlainCDMMAdapter,
    ProblemSpec,
)
from repro.cdmm.elastic import worker_closures
from repro.compat import shard_map
from repro.core import make_ring

from .common import emit, timeit

WORD = 4  # bytes per Z_{2^32} element


def bench_one(N: int, uvw, sizes, iters: int = 3):
    u, v, w = uvw
    base = make_ring(2, 32, ())
    schemes = {
        "ep_plain": PlainCDMMAdapter(base, N, u, v, w),
        "ep_rmfe1": EPRMFE1Adapter(base, 2, N, u, v, w),
        "ep_rmfe2": EPRMFE2Adapter(base, 2, N, u, v, w),  # §V: split_a=False
    }
    rng = np.random.default_rng(0)

    for size in sizes:
        t = r = s = size
        A = base.random(rng, (t, r))
        B = base.random(rng, (r, s))
        spec = ProblemSpec(t=t, r=r, s=s, n=1, ring=base, N=N)
        for name, sch in schemes.items():
            m = sch.ring.D
            idx = jnp.arange(sch.R, dtype=jnp.int32)
            enc = jax.jit(lambda a, b, sch=sch: (sch.encode_a(a), sch.encode_b(b)))
            FA, GB = enc(A, B)
            worker = jax.jit(
                lambda fa, gb, sch=sch: sch.worker_compute(fa, gb)
            )
            H = sch.worker_compute(FA, GB)
            dec = jax.jit(lambda h, sch=sch, idx=idx: sch.decode(h, idx))
            e_us = timeit(enc, A, B, iters=iters)
            w_us = timeit(worker, FA[:1], GB[:1], iters=iters)
            d_us = timeit(dec, H[: sch.R], iters=iters)
            # master<->worker transfer proxy: host round-trip of the share
            # stack (memcpy bandwidth on this box) — the communication term
            # the calibration fit grounds its upload/download coefficient on
            comm = jax.jit(lambda fa: fa + jnp.uint32(0))
            c_us = timeit(lambda fa: np.asarray(comm(fa)), FA, iters=iters)
            c = sch.costs(spec)
            # every stage row carries its cost-model features + backend tag
            # so repro.cdmm.calibrate can fit wall-time coefficients from
            # the emitted JSON (backend="local": stages are the same jitted
            # calls the LocalSim/ShardMap masters run)
            emit(f"{name}_N{N}_s{size}_encode", e_us,
                 upload_B=int(c.upload * WORD), m=m,
                 encode_ops=c.encode_ops, backend="local")
            emit(f"{name}_N{N}_s{size}_worker", w_us, m=m,
                 worker_ops=c.worker_ops, backend="local")
            emit(f"{name}_N{N}_s{size}_decode", d_us,
                 download_B=int(c.download * WORD),
                 decode_ops=c.decode_ops, backend="local")
            emit(f"{name}_N{N}_s{size}_comm", c_us,
                 comm_elems=c.upload + c.download, backend="local")


def _bench_elastic_stages(N, schemes, size, spec, A, B, iters):
    """Stage rows through the elastic master's actual code path: the serial
    per-worker ``encode_*_at`` dispatch loop, one threaded worker's jitted
    compute closure, the LRU-cached per-subset ``decode_op``, and the
    in-process response handoff (queue put/get of a share stack) — so
    ``repro.cdmm.calibrate`` fits the elastic backend its own coefficients
    instead of falling back to "local"."""
    for name, sch in schemes.items():
        m = sch.ring.D
        c = sch.costs(spec)
        encode_at, compute = worker_closures(sch)

        def enc_all(a, b, _enc=encode_at, _n=N):
            return [_enc(a, b, jnp.int32(i)) for i in range(_n)]

        FA = sch.encode_a(A)
        GB = sch.encode_b(B)
        H = sch.worker_compute(FA, GB)
        dec = sch.decode_op(tuple(range(sch.R)))
        e_us = timeit(enc_all, A, B, iters=iters)
        w_us = timeit(compute, FA[0], GB[0], iters=iters)
        d_us = timeit(dec, H[: sch.R], iters=iters)
        # the elastic "transfer" is an in-process queue handoff of the
        # response buffers (workers share the master's address space)
        q: "queue.Queue" = queue.Queue()

        def handoff(h, _q=q):
            _q.put(h)
            return _q.get()

        c_us = timeit(handoff, H, iters=iters)
        tag = f"{name}_N{N}_s{size}_elastic"
        emit(f"{tag}_encode", e_us, upload_B=int(c.upload * WORD), m=m,
             encode_ops=c.encode_ops, backend="elastic")
        emit(f"{tag}_worker", w_us, m=m, worker_ops=c.worker_ops,
             backend="elastic")
        emit(f"{tag}_decode", d_us, download_B=int(c.download * WORD),
             decode_ops=c.decode_ops, backend="elastic")
        emit(f"{tag}_comm", c_us, comm_elems=c.upload + c.download,
             backend="elastic")


def _bench_shard_map_stages(N, schemes, size, spec, A, B, iters):
    """Stage rows through real SPMD programs over an N-device mesh: encode
    runs at-worker (each shard computes its own codeword pair), compute is
    the per-shard block product, the transfer is the ``all_gather``
    collective the sync backend pays, and decode is the replicated master
    decode.  Skipped when the host exposes fewer than N devices."""
    if len(jax.devices()) < N:
        # never skip silently: a calibration refit from this run would
        # quietly lose the shard_map coefficients
        print(f"# shard_map stage rows SKIPPED: need {N} devices, have "
              f"{len(jax.devices())} (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={N})")
        return
    mesh = Mesh(np.array(jax.devices()[:N]).reshape(N), ("workers",))
    rep = P()
    shard = P("workers")
    for name, sch in schemes.items():
        m = sch.ring.D
        c = sch.costs(spec)

        def enc_body(a, b, _sch=sch):
            i = lax.axis_index("workers")
            return (_sch.encode_a_at(a, i)[None], _sch.encode_b_at(b, i)[None])

        enc = shard_map(enc_body, mesh=mesh, in_specs=(rep, rep),
                        out_specs=(shard, shard), check=False)

        def cmp_body(fa, gb, _sch=sch):
            return _sch.worker_compute(fa, gb)

        cmp = shard_map(cmp_body, mesh=mesh, in_specs=(shard, shard),
                        out_specs=shard, check=False)

        def gather_body(h):
            return lax.all_gather(h[0], "workers")

        gather = shard_map(gather_body, mesh=mesh, in_specs=(shard,),
                           out_specs=rep, check=False)

        FA, GB = jax.jit(enc)(A, B)
        H = sch.worker_compute(FA, GB)
        idx = jnp.arange(sch.R, dtype=jnp.int32)
        dec = jax.jit(lambda h, _sch=sch, _idx=idx: _sch.decode(h, _idx))
        e_us = timeit(jax.jit(enc), A, B, iters=iters)
        w_us = timeit(jax.jit(cmp), FA, GB, iters=iters)
        d_us = timeit(dec, H[: sch.R], iters=iters)
        c_us = timeit(jax.jit(gather), H, iters=iters)
        tag = f"{name}_N{N}_s{size}_shard_map"
        emit(f"{tag}_encode", e_us, upload_B=int(c.upload * WORD), m=m,
             encode_ops=c.encode_ops, backend="shard_map")
        emit(f"{tag}_worker", w_us, m=m, worker_ops=c.worker_ops,
             backend="shard_map")
        emit(f"{tag}_decode", d_us, download_B=int(c.download * WORD),
             decode_ops=c.decode_ops, backend="shard_map")
        emit(f"{tag}_comm", c_us, comm_elems=c.upload + c.download,
             backend="shard_map")


def _bench_pool_stages(pool, N, schemes, size, spec, A, B, iters):
    """Stage rows for the multi-process pool backend, with the comm term
    measured from REAL socket round-trips: ``Master.echo`` bounces a
    payload sized to the scheme's upload+download volume off a live worker
    through the negotiated wire codec, so the fitted ``comm`` coefficient
    prices what pool execution actually pays (framing, codec, kernel
    socket path) instead of a memcpy proxy.  Encode/decode/compute stages
    are the same jitted calls the pool master and workers run."""
    master = pool.master
    for name, sch in schemes.items():
        m = sch.ring.D
        c = sch.costs(spec)
        encode_at, compute = worker_closures(sch)

        def enc_all(a, b, _enc=encode_at, _n=N):
            return [_enc(a, b, jnp.int32(i)) for i in range(_n)]

        FA = sch.encode_a(A)
        GB = sch.encode_b(B)
        H = sch.worker_compute(FA, GB)
        dec = sch.decode_op(tuple(range(sch.R)))
        e_us = timeit(enc_all, A, B, iters=iters)
        w_us = timeit(compute, FA[0], GB[0], iters=iters)
        d_us = timeit(dec, H[: sch.R], iters=iters)
        nbytes = max(int((c.upload + c.download) * WORD), 4)
        rtts = [master.echo(nbytes)["rtt_s"] for _ in range(max(iters, 2))]
        c_us = float(np.median(rtts) * 1e6)
        tag = f"{name}_N{N}_s{size}_pool"
        emit(f"{tag}_encode", e_us, upload_B=int(c.upload * WORD), m=m,
             encode_ops=c.encode_ops, backend="pool")
        emit(f"{tag}_worker", w_us, m=m, worker_ops=c.worker_ops,
             backend="pool")
        emit(f"{tag}_decode", d_us, download_B=int(c.download * WORD),
             decode_ops=c.decode_ops, backend="pool")
        emit(f"{tag}_comm", c_us, comm_elems=c.upload + c.download,
             backend="pool")


def bench_backends(N: int, uvw, sizes, iters: int = 3):
    """Per-backend calibration rows (shard_map / elastic / pool), mirroring
    ``bench_one``'s scheme grid so every backend's coefficients are fitted
    from the same problem family."""
    from repro.dist import LocalPool, PoolConfig

    u, v, w = uvw
    base = make_ring(2, 32, ())
    schemes = {
        "ep_plain": PlainCDMMAdapter(base, N, u, v, w),
        "ep_rmfe1": EPRMFE1Adapter(base, 2, N, u, v, w),
        "ep_rmfe2": EPRMFE2Adapter(base, 2, N, u, v, w),
    }
    rng = np.random.default_rng(0)
    # one real worker pool for the socket-measured comm rows (echo probes
    # need a live worker, not a full execute, so 2 workers suffice)
    with LocalPool(config=PoolConfig(workers=2)) as pool:
        for size in sizes:
            t = r = s = size
            A = base.random(rng, (t, r))
            B = base.random(rng, (r, s))
            spec = ProblemSpec(t=t, r=r, s=s, n=1, ring=base, N=N)
            _bench_elastic_stages(N, schemes, size, spec, A, B, iters)
            _bench_shard_map_stages(N, schemes, size, spec, A, B, iters)
            _bench_pool_stages(pool, N, schemes, size, spec, A, B, iters)


def run(full: bool = False):
    sizes = [128, 256, 512] if not full else [256, 512, 1024, 2048]
    bench_one(8, (2, 2, 1), sizes)
    bench_one(16, (2, 2, 2), sizes)
    # per-backend stage rows so calibrate.py fits shard_map/elastic their
    # own coefficients (the ROADMAP follow-up from the calibration PR);
    # N=8 keeps the mesh inside the CI host-device simulation
    bench_backends(8, (2, 2, 1), sizes)
