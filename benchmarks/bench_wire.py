"""Bytes-on-the-wire: raw vs compressed share transport on a real pool.

Comm-dominated point: Z_{2^16} entries ride uint32 carriers, so bit-packing
to the ring's true width alone halves the on-wire volume, and zlib framing
takes more when the shares compress.  Each row is one full coded matmul on
a live multi-process pool under a pinned transport, recording the pre-codec
payload bytes (``raw_B``), what actually crossed the sockets (``wire_B``)
and the time until the R-th response landed — so the bench-history gate
tracks both the compression ratio and the latency it buys.

Row names carry the transport (``wire_raw_*`` / ``wire_pack_zlib_*``); the
suffix is ``_roundtrip``, NOT a calibration stage suffix, so these rows
never pollute the fitted per-stage coefficients (the pool's ``comm``
coefficient comes from ``bench_single_cdmm``'s echo probes instead).
"""
from __future__ import annotations

import numpy as np

from repro.cdmm import ProblemSpec, plan
from repro.core import make_ring

from .common import emit

TRANSPORTS = ("raw", "pack", "pack+zlib")


def _one(transport: str, size: int, workers: int) -> dict:
    from repro.dist import LocalPool, PoolConfig

    ring = make_ring(2, 16, ())
    spec = ProblemSpec(t=size, r=size, s=size, n=1, ring=ring, N=workers,
                       straggler_budget=1)
    scheme = plan(spec, objective="threshold").instantiate()
    rng = np.random.default_rng(0)
    A = ring.random(rng, (size, size))
    B = ring.random(rng, (size, size))
    cfg = PoolConfig(workers=workers, transport=transport)
    with LocalPool(config=cfg) as pool:
        pool.execute(scheme, A, B, timeout=300.0)  # warm: workers jit
        C, st = pool.execute(scheme, A, B, timeout=300.0)
    oracle = np.asarray(ring.matmul(A, B))
    assert np.array_equal(np.asarray(C), oracle), (
        f"pool decode mismatch under transport={transport!r}"
    )
    raw = st.raw_bytes_out + st.raw_bytes_in
    wire = st.bytes_out + st.bytes_in
    return {
        "us": st.time_to_R_ms * 1e3,
        "raw_B": raw,
        "wire_B": wire,
        "codecs": "|".join(st.codecs),
    }


def _pool_stage_rows(full: bool):
    """CI-sized pool stage rows (socket-measured comm via echo probes) so
    the bench-history gate tracks the pool backend's calibration inputs on
    every run — the full-size equivalents live in ``bench_single_cdmm``'s
    figs section, which CI doesn't run.  s=64 is a size figs never uses,
    so the row names can't collide with a figs-generated history."""
    from repro.cdmm.api import (
        EPRMFE1Adapter,
        EPRMFE2Adapter,
        PlainCDMMAdapter,
    )
    from repro.dist import LocalPool, PoolConfig

    from .bench_single_cdmm import _bench_pool_stages

    N, u, v, w = 8, 2, 2, 1
    base = make_ring(2, 32, ())
    schemes = {
        "ep_plain": PlainCDMMAdapter(base, N, u, v, w),
        "ep_rmfe1": EPRMFE1Adapter(base, 2, N, u, v, w),
        "ep_rmfe2": EPRMFE2Adapter(base, 2, N, u, v, w),
    }
    rng = np.random.default_rng(0)
    sizes = (64, 96) if full else (64,)
    with LocalPool(config=PoolConfig(workers=2)) as pool:
        for size in sizes:
            A = base.random(rng, (size, size))
            B = base.random(rng, (size, size))
            spec = ProblemSpec(t=size, r=size, s=size, n=1, ring=base, N=N)
            _bench_pool_stages(pool, N, schemes, size, spec, A, B, iters=2)


def run(full: bool = False):
    size = 192 if full else 96
    workers = 4
    results = {}
    for transport in TRANSPORTS:
        r = _one(transport, size, workers)
        results[transport] = r
        tag = transport.replace("+", "_")
        emit(f"wire_{tag}_s{size}_roundtrip", r["us"], raw_B=r["raw_B"],
             wire_B=r["wire_B"], codecs=r["codecs"], backend="pool")
    # ratio row: on-wire bytes under the raw transport vs the strongest
    # compressed one, x1000 so the integer-ish metric column stays readable
    best = results["pack+zlib"]
    ratio = results["raw"]["wire_B"] / max(best["wire_B"], 1)
    emit(f"wire_ratio_s{size}", ratio * 1e3, raw_wire_B=results["raw"]["wire_B"],
         zlib_wire_B=best["wire_B"], backend="pool")
    print(f"# wire reduction raw->pack+zlib: {ratio:.2f}x "
          f"({results['raw']['wire_B']} -> {best['wire_B']} B)")
    _pool_stage_rows(full)


if __name__ == "__main__":
    run()
