"""gr_matmul kernel benchmark: XLA-CPU reference path wall-clock (the
executable baseline here) + interpret-mode kernel equivalence + the TPU
roofline estimate for the kernel's blocked schedule.

On this CPU container the Pallas kernel runs in interpret mode (python),
so its wall-clock is meaningless; what we measure is the jnp reference (the
same algorithm XLA-compiled) and we DERIVE the kernel's TPU roofline from
its block schedule: per (bt x bs) output tile the kernel moves
(bt*br + br*bs + bt*bs) * D words and computes 2*bt*br*bs*D^2 int-ops.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import make_ring
from repro.kernels import cached_blocks, gr_matmul, gr_matmul_ref, pick_blocks
from repro.kernels.autotune import autotune

from .common import emit, timeit

STATIC_BLOCKS = (128, 128, 128)  # the pre-autotuner hard-coded default


def run(full: bool = False):
    rng = np.random.default_rng(0)
    sizes = [128, 256] if not full else [256, 512, 1024]
    for degs, label in [((), "Z2e32"), ((3,), "GR3"), ((4,), "GR4")]:
        ring = make_ring(2, 32, degs)
        for size in sizes:
            A = ring.random(rng, (size, size))
            B = ring.random(rng, (size, size))
            ref = jax.jit(lambda a, b: gr_matmul_ref(a, b, ring))
            us = timeit(ref, A, B)
            D = ring.D
            intops = 2 * size**3 * D * D
            emit(
                f"grmm_ref_{label}_s{size}", us,
                intops=intops, gops_s=round(intops / us / 1e3, 2),
            )
            # kernel blocked-schedule roofline (TPU target, analytic)
            bt, bs, br = pick_blocks(size, size, size)
            tiles = (size // bt) * (size // bs) * (size // br)
            vmem_words = (bt * br + br * bs + bt * bs) * D + ring.K * bt * bs
            hbm_bytes = tiles * (bt * br + br * bs) * D * 4 + (size * size) * D * 4
            emit(
                f"grmm_kernel_sched_{label}_s{size}", 0.0,
                block=f"{bt}x{bs}x{br}", vmem_KiB=vmem_words * 4 // 1024,
                hbm_bytes=hbm_bytes,
                arith_intensity=round(intops / hbm_bytes, 1),
            )
    run_tuned(full)


def run_tuned(full: bool = False):
    """Measured tuned-vs-static kernel schedules (the autotuner's payoff).

    Both configurations run the identical kernel body on the executing
    device (interpret mode on CPU — real wall-clock of the same schedule,
    compiled Mosaic on TPU); the static 128^3 default pays its padding for
    real, so the committed tuned cache must match or beat it.  The rows
    land in BENCH_ci.json and the regression gate, making tuning
    regressions (a stale cache, a broken candidate filter) visible in the
    perf trajectory.
    """
    rng = np.random.default_rng(7)
    sizes = [16, 64] if not full else [16, 64, 128]
    for degs, label in [((), "Z2e32"), ((3,), "GR3")]:
        ring = make_ring(2, 32, degs)
        for size in sizes:
            A = ring.random(rng, (size, size))
            B = ring.random(rng, (size, size))
            tuned = cached_blocks(ring, size, size, size)
            if tuned is None:  # cold cache (new device): tune in-process
                tuned = autotune(ring, size, size, size, budget=6,
                                 iters=2).blocks
            static_call = jax.jit(
                lambda a, b: gr_matmul(a, b, ring, blocks=STATIC_BLOCKS)
            )
            tuned_call = jax.jit(
                lambda a, b: gr_matmul(a, b, ring, blocks=tuned)
            )
            # micro-rows (tens of us in interpret mode) need more samples
            # for a stable median — these feed the >25% regression gate
            s_us = timeit(static_call, A, B, iters=7)
            t_us = timeit(tuned_call, A, B, iters=7)
            bt, bs, br = tuned
            emit(f"grmm_kernel_static_{label}_s{size}", s_us,
                 block="x".join(map(str, STATIC_BLOCKS)))
            emit(f"grmm_kernel_tuned_{label}_s{size}", t_us,
                 block=f"{bt}x{bs}x{br}",
                 speedup_vs_static=round(s_us / t_us, 2))


def verify():
    """Interpret-mode equivalence spot check (fast)."""
    rng = np.random.default_rng(1)
    ring = make_ring(2, 32, (3,))
    A = ring.random(rng, (64, 64))
    B = ring.random(rng, (64, 64))
    out = gr_matmul(A, B, ring, interpret=True)
    ref = gr_matmul_ref(A, B, ring)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
