"""gr_matmul kernel benchmark: XLA-CPU reference path wall-clock (the
executable baseline here) + interpret-mode kernel equivalence + the TPU
roofline estimate for the kernel's blocked schedule.

On this CPU container the Pallas kernel runs in interpret mode (python),
so its wall-clock is meaningless; what we measure is the jnp reference (the
same algorithm XLA-compiled) and we DERIVE the kernel's TPU roofline from
its block schedule: per (bt x bs) output tile the kernel moves
(bt*br + br*bs + bt*bs) * D words and computes 2*bt*br*bs*D^2 int-ops.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import make_ring
from repro.kernels import gr_matmul, gr_matmul_ref, pick_blocks

from .common import emit, timeit


def run(full: bool = False):
    rng = np.random.default_rng(0)
    sizes = [128, 256] if not full else [256, 512, 1024]
    for degs, label in [((), "Z2e32"), ((3,), "GR3"), ((4,), "GR4")]:
        ring = make_ring(2, 32, degs)
        for size in sizes:
            A = ring.random(rng, (size, size))
            B = ring.random(rng, (size, size))
            ref = jax.jit(lambda a, b: gr_matmul_ref(a, b, ring))
            us = timeit(ref, A, B)
            D = ring.D
            intops = 2 * size**3 * D * D
            emit(
                f"grmm_ref_{label}_s{size}", us,
                intops=intops, gops_s=round(intops / us / 1e3, 2),
            )
            # kernel blocked-schedule roofline (TPU target, analytic)
            bt, bs, br = pick_blocks(size, size, size)
            tiles = (size // bt) * (size // bs) * (size // br)
            vmem_words = (bt * br + br * bs + bt * bs) * D + ring.K * bt * bs
            hbm_bytes = tiles * (bt * br + br * bs) * D * 4 + (size * size) * D * 4
            emit(
                f"grmm_kernel_sched_{label}_s{size}", 0.0,
                block=f"{bt}x{bs}x{br}", vmem_KiB=vmem_words * 4 // 1024,
                hbm_bytes=hbm_bytes,
                arith_intensity=round(intops / hbm_bytes, 1),
            )


def verify():
    """Interpret-mode equivalence spot check (fast)."""
    rng = np.random.default_rng(1)
    ring = make_ring(2, 32, (3,))
    A = ring.random(rng, (64, 64))
    B = ring.random(rng, (64, 64))
    out = gr_matmul(A, B, ring, interpret=True)
    ref = gr_matmul_ref(A, B, ring)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
