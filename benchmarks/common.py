"""Benchmark helpers: wall-clock timing of jitted callables + CSV/JSON rows."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import jax
import numpy as np

ROWS: List[Dict] = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, **derived):
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    ROWS.append({"name": name, "us": us_per_call, "derived": d})
    print(f"{name},{us_per_call:.1f},{d}")


def header():
    print("name,us_per_call,derived")


def write_json(path: str) -> None:
    """Dump every emitted row as machine-readable JSON (the CSV's twin):
    ``[{"name": ..., "us": ..., "derived": {k: v-as-string}}, ...]``.
    tools/check_bench.py diffs these files across commits."""
    rows = []
    for r in ROWS:
        derived = dict(
            kv.split("=", 1) for kv in r["derived"].split(";") if "=" in kv
        )
        rows.append({"name": r["name"], "us": r["us"], "derived": derived})
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
        f.write("\n")
