"""Generate EXPERIMENTS.md from dry-run artifacts (baseline + optimized)."""
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASE = os.path.join(ROOT, "artifacts", "dryrun")
OPT = os.path.join(ROOT, "artifacts", "dryrun_opt")


def load(d, mesh):
    out = {}
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def row(r, opt=None):
    if r["status"] == "skipped":
        return f"| {r['arch']} | {r['shape']} | — | — | — | skipped¹ | — | — | — |"
    t = r["roofline"]
    mem = r.get("bytes_per_device", 0) / 2**30
    frac_b = t["roofline_fraction"]
    cells = (
        f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.4g} | "
        f"{t['t_memory_s']:.4g} | {t['t_collective_s']:.4g} | {t['dominant']} | "
        f"{frac_b:.3f} | {t.get('useful_ratio', 0):.2f} | {mem:.1f} |"
    )
    return cells


def table(recs, title):
    lines = [
        f"#### {title}",
        "",
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "frac² | 6ND/HLO³ | GiB/dev⁴ |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(recs):
        lines.append(row(recs[k]))
    lines.append("")
    return "\n".join(lines)


def compare_table(base, opt):
    lines = [
        "| arch | shape | coll (s) base → opt | frac base → opt | GiB/dev base → opt |",
        "|---|---|---|---|---|",
    ]
    for k in sorted(base):
        b, o = base[k], opt.get(k)
        if b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        tb, to = b["roofline"], o["roofline"]
        mb = b.get("bytes_per_device", 0) / 2**30
        mo = o.get("bytes_per_device", 0) / 2**30
        imp = tb["t_collective_s"] / max(to["t_collective_s"], 1e-9)
        star = " **(×%.0f)**" % imp if imp >= 10 else ""
        lines.append(
            f"| {k[0]} | {k[1]} | {tb['t_collective_s']:.4g} → "
            f"{to['t_collective_s']:.4g}{star} | {tb['roofline_fraction']:.3f} → "
            f"{to['roofline_fraction']:.3f} | {mb:.1f} → {mo:.1f} |"
        )
    return "\n".join(lines)


def dryrun_summary(d):
    n_ok = n_skip = n_err = 0
    comp = []
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        if r["status"] == "ok":
            n_ok += 1
            comp.append(r.get("compile_s", 0))
        elif r["status"] == "skipped":
            n_skip += 1
        else:
            n_err += 1
    return n_ok, n_skip, n_err, (sum(comp) / max(len(comp), 1))


PERF_NARRATIVE = open(os.path.join(ROOT, "tools", "perf_narrative.md")).read()


def main():
    base_s = load(BASE, "single")
    base_m = load(BASE, "multi")
    opt_s = load(OPT, "single")
    opt_m = load(OPT, "multi")
    ok_b, sk_b, er_b, _ = dryrun_summary(BASE)
    ok_o, sk_o, er_o, avg_c = dryrun_summary(OPT)

    doc = f"""# EXPERIMENTS

All numbers below are REPRODUCIBLE from this repo:

```
PYTHONPATH=src python -m repro.launch.dryrun            # artifacts/dryrun_opt (current rules)
PYTHONPATH=src python -m repro.launch.roofline          # tables
PYTHONPATH=src python -m benchmarks.run                 # CDMM measured benches
PYTHONPATH=src pytest tests/                            # correctness
```

Baseline artifacts (pre-optimization rules) are frozen in `artifacts/dryrun/`;
the optimized run lives in `artifacts/dryrun_opt/` (env `REPRO_DRYRUN_DIR`).

---

## §Dry-run

Every (architecture × shape × mesh) cell was `jit(step).lower().compile()`d
for BOTH production meshes — single pod (16, 16) = 256 chips, axes
(data, model), and multi-pod (2, 16, 16) = 512 chips, axes (pod, data,
model) — with 512 forced host devices and NO array allocation
(ShapeDtypeStructs + NamedShardings end-to-end).

* baseline sweep: **{ok_b} compiled OK, {sk_b} documented skips, {er_b} failures**
* optimized sweep: **{ok_o} compiled OK, {sk_o} documented skips, {er_o} failures**
  (mean compile {avg_c:.0f}s/cell on the CPU container)

Step kinds per shape: `train_4k` lowers the full production `train_step`
(loss + bwd + optimizer update, donated params/opt state); `prefill_32k`
lowers the forward; `decode_32k`/`long_500k` lower `serve_step` (one token
against a seq_len KV/state cache, cache donated).

¹ `long_500k` is skipped for pure quadratic-attention archs and runs for
the SSM/hybrid archs (mamba2-370m, zamba2-7b) per the assignment note
(DESIGN.md §4).

Memory-fit notes (from `compiled.memory_analysis()`): bytes/device in the
tables below include a ~2× inflation from the CPU backend's bf16→f32
emulation of matmuls/collectives (conversions are materialised); TPU-real
estimates are roughly half the reported GiB. kimi-k2 train is the only cell
whose parameters+grads (4.1 TB bf16) genuinely exceed a single pod
(256×16 GB = 4 TB) — it trains on the multi-pod mesh with ZeRO-3 over
(pod, data), which is exactly why the config sets `fsdp_axes=("pod","data")`.

## §Roofline

Terms (per chip, per step): `t_comp = FLOPs/(197e12)`, `t_mem =
HBM_bytes/(819e9)`, `t_coll = collective_bytes/(50e9)`.

* FLOPs/HBM bytes come from the analytic per-arch cost model
  (`launch/costmodel.py`) because XLA's `cost_analysis()` counts a `while`
  body ONCE, not ×trip-count — verified on gemma2-2b: raw 2.05e13 vs
  corrected 8.8e13 flops/chip, ratio = the 13-unit layer scan.  Raw XLA
  numbers are kept in every artifact under `hlo_flops_per_chip_raw`.
* Collective bytes are parsed from the compiled per-device HLO **with
  while-trip multipliers** (`launch/hlo_analysis.py`, validated by
  `tests/test_hlo_analysis.py`: a psum in a 10-trip loop is charged 10×).
* `frac` = t_comp / max(all three) — the roofline fraction when the
  dominant term is compute; for decode cells the meaningful statement is
  `dominant == memory` (decode is weight/cache-read bound by construction,
  t_comp ≈ 0 at batch ≤ 128×1 token).
* 6ND/HLO = MODEL_FLOPS / analytic total FLOPs: 6·N_active·D for train,
  2·N_active·D forward — catches remat & capacity-factor waste (MoE cells
  show ~0.5 because top-8/384 routing pays capacity 1.25 and remat ~4/3).

### Baseline (single pod, 256 chips) — initial GSPMD rules

{table(base_s, "baseline / single-pod")}

### Optimized (single pod, 256 chips) — after §Perf iterations

{table(opt_s, "optimized / single-pod")}

### Optimized (multi-pod, 512 chips)

{table(opt_m, "optimized / multi-pod")}

### Baseline → optimized per cell

{compare_table(base_s, opt_s)}

**Reading the optimized table:** train/prefill cells are compute- or
collective-bound with fractions 0.1–0.5 (the residual collective cost is
ZeRO weight gathers + SP↔TP transitions — see Perf log for what each is);
every decode cell is **memory-dominant**, i.e. serving latency sits at the
HBM weight/cache-read bound, which is the correct roofline regime for
batch-decode.

---

{PERF_NARRATIVE}
"""
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(doc)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
