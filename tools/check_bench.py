#!/usr/bin/env python
"""Benchmark regression gate: diff a fresh BENCH_*.json against a committed
baseline and exit 1 when any timed row regresses beyond the threshold.

    python tools/check_bench.py --baseline benchmarks/baseline.json \
        --current BENCH_ci.json [--threshold 0.25]

Rows are matched by ``name`` on the ``us`` (median microseconds per call)
field.  Analytic rows (us == 0) and rows present in only one file are
reported but never fail the gate — new benchmarks should not need a
baseline update to land, and retired ones should not block forever.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: float(r["us"]) for r in rows}


def compare(baseline: dict, current: dict, threshold: float):
    """Returns (regressions, improvements, skipped) name lists."""
    regressions, improvements, skipped = [], [], []
    for name in sorted(baseline):
        if name not in current:
            skipped.append((name, "missing from current"))
            continue
        old, new = baseline[name], current[name]
        if old <= 0.0 or new <= 0.0:
            skipped.append((name, "analytic/untimed row"))
            continue
        ratio = new / old
        if ratio > 1.0 + threshold:
            regressions.append((name, old, new, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, old, new, ratio))
    for name in sorted(set(current) - set(baseline)):
        skipped.append((name, "new benchmark (no baseline)"))
    return regressions, improvements, skipped


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--current", default="BENCH_ci.json")
    ap.add_argument(
        "--threshold", type=float, default=0.25,
        help="fail when new > old * (1 + threshold), default 0.25",
    )
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    current = load_rows(args.current)
    regressions, improvements, skipped = compare(
        baseline, current, args.threshold
    )

    for name, why in skipped:
        print(f"SKIP {name}: {why}")
    for name, old, new, ratio in improvements:
        print(f"FASTER {name}: {old:.1f}us -> {new:.1f}us ({ratio:.2f}x)")
    for name, old, new, ratio in regressions:
        print(
            f"REGRESSION {name}: {old:.1f}us -> {new:.1f}us "
            f"({ratio:.2f}x > {1 + args.threshold:.2f}x allowed)"
        )
    if regressions:
        print(f"FAIL: {len(regressions)} benchmark(s) regressed "
              f">{args.threshold:.0%} vs {args.baseline}")
        return 1
    print(f"OK: {len(baseline)} baseline rows checked, no regression "
          f">{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
