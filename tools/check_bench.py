#!/usr/bin/env python
"""Benchmark regression gate: diff a fresh BENCH_*.json against a committed
baseline — and, optionally, against a rolling window of previous CI runs —
and exit 1 when any timed row regresses beyond the threshold.

    python tools/check_bench.py --baseline benchmarks/baseline.json \
        --current BENCH_ci.json [--threshold 0.25] \
        [--history bench_history.json --commit $GITHUB_SHA]

Rows are matched by ``name`` on the ``us`` (median microseconds per call)
field.  Analytic rows (us == 0) and rows present in only one file are
reported but never fail the gate — new benchmarks should not need a
baseline update to land, and retired ones should not block forever.

``--history`` makes the perf trajectory durable: the file is a JSON list of
``{"sha": ..., "rows": {name: us}}`` entries (newest last) that CI chains
through a ``bench-history`` artifact.  The current run is gated against the
median of the last ``--window`` entries per row (so a regression against
where the code has *recently* been fails even after the committed baseline
goes stale), then appended (keyed by ``--commit``) and written back.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HISTORY_MAX_ENTRIES = 50  # cap the chained artifact's growth


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: float(r["us"]) for r in rows}


def compare(baseline: dict, current: dict, threshold: float):
    """Returns (regressions, improvements, skipped) name lists."""
    regressions, improvements, skipped = [], [], []
    for name in sorted(baseline):
        if name not in current:
            skipped.append((name, "missing from current"))
            continue
        old, new = baseline[name], current[name]
        if old <= 0.0 or new <= 0.0:
            skipped.append((name, "analytic/untimed row"))
            continue
        ratio = new / old
        if ratio > 1.0 + threshold:
            regressions.append((name, old, new, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, old, new, ratio))
    for name in sorted(set(current) - set(baseline)):
        skipped.append((name, "new benchmark (no baseline)"))
    return regressions, improvements, skipped


def load_history(path: str) -> list:
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            hist = json.load(f)
    except (json.JSONDecodeError, OSError):
        return []  # corrupt chain: restart it rather than wedge CI forever
    return hist if isinstance(hist, list) else []


def rolling_reference(history: list, window: int) -> dict:
    """Per-row median us over each row's last ``window`` SAMPLES (only
    rows timed in at least two runs — a single sample is no trend).

    Samples are collected newest-first across the whole retained history,
    not just the last ``window`` entries: rows withheld from recent
    entries (persistent rolling regressions) keep their last-known-good
    reference instead of starving out of the window after ``window`` runs
    and letting the regression ratchet in un-gated.  A row only ages out
    with the HISTORY_MAX_ENTRIES cap — a much longer human-attention
    horizon."""
    samples: dict = {}
    for entry in reversed(history):
        for name, us in entry.get("rows", {}).items():
            if us > 0.0 and len(samples.setdefault(name, [])) < window:
                samples[name].append(float(us))
    ref = {}
    for name, vals in samples.items():
        if len(vals) >= 2:
            vals = sorted(vals)
            mid = len(vals) // 2
            ref[name] = (
                vals[mid] if len(vals) % 2
                else 0.5 * (vals[mid - 1] + vals[mid])
            )
    return ref


def append_history(history: list, sha: str, current: dict, path: str) -> None:
    history = [e for e in history if e.get("sha") != sha]  # re-runs replace
    history.append({"sha": sha, "rows": current})
    history = history[-HISTORY_MAX_ENTRIES:]
    with open(path, "w") as f:
        json.dump(history, f, indent=1, sort_keys=True)
        f.write("\n")


def report(tag: str, regressions, improvements, skipped, threshold: float):
    for name, why in skipped:
        print(f"SKIP {name}: {why}")
    for name, old, new, ratio in improvements:
        print(f"FASTER {name}: {old:.1f}us -> {new:.1f}us ({ratio:.2f}x)")
    for name, old, new, ratio in regressions:
        print(
            f"REGRESSION[{tag}] {name}: {old:.1f}us -> {new:.1f}us "
            f"({ratio:.2f}x > {1 + threshold:.2f}x allowed)"
        )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--current", default="BENCH_ci.json")
    ap.add_argument(
        "--threshold", type=float, default=0.25,
        help="fail when new > old * (1 + threshold), default 0.25",
    )
    ap.add_argument(
        "--history", default=None, metavar="PATH",
        help="rolling bench-history JSON: gate against the recent-run "
             "median, then append the current rows and write back",
    )
    ap.add_argument(
        "--commit", default=os.environ.get("GITHUB_SHA", "local"),
        help="commit SHA keying the appended history entry",
    )
    ap.add_argument(
        "--window", type=int, default=5,
        help="history entries the rolling median is computed over",
    )
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    current = load_rows(args.current)
    regressions, improvements, skipped = compare(
        baseline, current, args.threshold
    )
    report("baseline", regressions, improvements, skipped, args.threshold)

    roll_regressions = []
    failed = {name for name, *_ in regressions}
    if args.history is not None:
        history = load_history(args.history)
        ref = rolling_reference(history, args.window)
        if ref:
            roll_regressions, roll_faster, _ = compare(
                ref, current, args.threshold
            )
            report("rolling", roll_regressions, roll_faster, [],
                   args.threshold)
            print(f"rolling window: {min(len(history), args.window)} run(s), "
                  f"{len(ref)} comparable row(s)")
        else:
            print("rolling window: no usable history yet (chain starts here)")
        failed |= {name for name, *_ in roll_regressions}
        # rows that regressed AGAINST THE ROLLING WINDOW are withheld from
        # the appended entry: otherwise a persistent regression would
        # ratchet into the median after ~window/2 runs and silently disarm
        # the very gate that caught it.  Baseline-only regressions are NOT
        # withheld — the committed baseline's absolute timings are
        # machine-specific, and starving the window of rows a slower
        # runner class can never match would defeat the window's whole
        # purpose (tracking where the code has *recently* been).
        roll_failed = {name for name, *_ in roll_regressions}
        kept = {k: v for k, v in current.items() if k not in roll_failed}
        append_history(history, args.commit, kept, args.history)
        withheld = (
            f", {len(roll_failed)} regressed row(s) withheld"
            if roll_failed else ""
        )
        print(f"history: appended {args.commit[:12]} -> {args.history} "
              f"({len(load_history(args.history))} entries{withheld})")
    if failed:
        print(f"FAIL: {len(failed)} benchmark(s) regressed "
              f">{args.threshold:.0%} (baseline and/or rolling window)")
        return 1
    print(f"OK: {len(baseline)} baseline rows checked, no regression "
          f">{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
