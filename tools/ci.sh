#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a short benchmark smoke.
#
#   tools/ci.sh          # full tier-1 + table1 smoke
#   tools/ci.sh --fast   # tier-1 only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== benchmark smoke: Table 1 (analytic + measured CSA head-to-head) =="
  python -m benchmarks.run --only table1
fi

echo "CI OK"
