#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a short benchmark smoke.
#
#   tools/ci.sh              # full tier-1 + bench smoke -> BENCH_ci.json + gate
#   tools/ci.sh --fast       # quick local gate: tier-1 minus `slow`-marked
#                            # multi-process smokes (test_dist/test_serve),
#                            # reduced hypothesis examples, no bench smoke
#   tools/ci.sh --bench-only # bench smoke + gate only (CI's bench-smoke job,
#                            # which already ran tier-1 via its `needs:`)
#
# The bench smoke writes machine-readable rows to BENCH_ci.json (uploaded as
# a CI artifact so the perf trajectory accumulates across commits) and fails
# if any timed row regresses >25% against benchmarks/baseline.json.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--bench-only" ]]; then
  echo "== tier-1 tests =="
  pytest_args=(-x -q)
  if [[ "${1:-}" == "--fast" ]]; then
    # reduced-example hypothesis profile: the property-based conformance
    # suite (tests/test_conformance.py) stays under the fast-tier budget
    export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-ci-fast}"
    # deselect the `slow`-marked multi-process dist/serve smokes (marker
    # registered in tests/conftest.py): they dominate tier-1 wall time.
    # Bare `python -m pytest -x -q` stays the full tier-1 gate.
    pytest_args+=(-m "not slow")
  fi
  # tier-1 plans must be deterministic: rank by the analytic cost model,
  # not by whatever timing data benchmarks/calibration.json was last
  # regenerated from (tests that want calibration pin it explicitly)
  REPRO_CALIBRATION="${REPRO_CALIBRATION:-off}" python -m pytest "${pytest_args[@]}"
fi

if [[ "${1:-}" != "--fast" ]]; then
  echo "== benchmark smoke: Table 1 + straggler/elastic + secure + kernels + serving + wire =="
  python -m benchmarks.run --only table1,straggler,secure,kernels,serving,wire \
    --json BENCH_ci.json
  if [[ -f benchmarks/baseline.json ]]; then
    echo "== benchmark regression gate (>25% vs benchmarks/baseline.json) =="
    # the committed baseline's absolute timings are machine-specific, so the
    # gate is blocking only in CI (or with BENCH_STRICT=1); on an arbitrary
    # dev box a slower CPU must not fail the local entry point.
    # BENCH_HISTORY names a rolling bench-history chain (the CI bench-smoke
    # job downloads the previous artifact into it): the gate then also
    # compares against the recent-run median and appends this run.
    gate_args=(--baseline benchmarks/baseline.json --current BENCH_ci.json)
    if [[ -n "${BENCH_HISTORY:-}" ]]; then
      gate_args+=(--history "$BENCH_HISTORY")
    fi
    if [[ -n "${CI:-}" || -n "${BENCH_STRICT:-}" ]]; then
      python tools/check_bench.py "${gate_args[@]}"
    else
      python tools/check_bench.py "${gate_args[@]}" \
        || echo "WARNING: bench gate failed (advisory outside CI)"
    fi
  fi
fi

echo "CI OK"
