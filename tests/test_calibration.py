"""Planner calibration: coefficient fitting from benchmark rows, JSON
round-trip, env-var gating, and the headline property — rankings follow
the fitted wall-time coefficients (perturbing them flips the plan)."""
import json

import pytest

from repro.cdmm import ProblemSpec, plan
from repro.cdmm import calibrate as cal_mod
from repro.cdmm.calibrate import (
    Calibration,
    CalibrationSet,
    fit_rows,
    load_calibration,
    save_calibration,
)
from repro.core import make_ring

Z32 = make_ring(2, 32, ())


@pytest.fixture(autouse=True)
def no_ambient_calibration(monkeypatch):
    """Tests pin their calibration explicitly; the committed
    benchmarks/calibration.json must not leak into plan() calls here."""
    monkeypatch.setenv("REPRO_CALIBRATION", "off")
    cal_mod.invalidate_calibration_cache()
    yield
    cal_mod.invalidate_calibration_cache()


def _row(name, us, **derived):
    return {"name": name, "us": us, "derived": derived}


# ------------------------------------------------------------------ fitting


def test_fit_rows_recovers_exact_coefficients():
    rows = [
        _row("a_encode", 200.0, encode_ops=1000.0, backend="local"),
        _row("a_worker", 50.0, worker_ops=500.0, backend="local"),
        _row("a_decode", 30.0, decode_ops=100.0, backend="local"),
        _row("a_comm", 10.0, comm_elems=2000.0, backend="local"),
    ]
    cal = fit_rows(rows).for_backend("local")
    assert cal.coef == pytest.approx(
        {"encode": 0.2, "compute": 0.1, "decode": 0.3, "comm": 0.005}
    )
    assert cal.nrows == 4


def test_fit_rows_least_squares_through_origin():
    # two noisy observations: slope = sum(xy)/sum(x^2)
    rows = [
        _row("a_worker", 10.0, worker_ops=100.0, backend="local"),
        _row("b_worker", 30.0, worker_ops=200.0, backend="local"),
    ]
    cal = fit_rows(rows).for_backend("local")
    assert cal.coef["compute"] == pytest.approx(
        (10 * 100 + 30 * 200) / (100**2 + 200**2)
    )


def test_fit_rows_skips_untimed_unknown_and_featureless():
    rows = [
        _row("a_encode", 0.0, encode_ops=10.0),        # untimed (analytic)
        _row("a_mystery", 5.0, encode_ops=10.0),       # unknown stage suffix
        _row("a_decode", 5.0),                          # feature missing
        _row("a_worker", -1.0, worker_ops=10.0),        # negative us
    ]
    assert fit_rows(rows).backends == {}


def test_fit_rows_separates_backends_with_local_fallback():
    rows = [
        _row("a_worker", 10.0, worker_ops=100.0, backend="local"),
        _row("b_worker", 40.0, worker_ops=100.0, backend="elastic"),
    ]
    cs = fit_rows(rows)
    assert cs.for_backend("elastic").coef["compute"] == pytest.approx(0.4)
    assert cs.for_backend("local").coef["compute"] == pytest.approx(0.1)
    # unknown backend falls back to local's coefficients
    assert cs.for_backend("shard_map").coef["compute"] == pytest.approx(0.1)


# ---------------------------------------------------------------- JSON I/O


def test_calibration_roundtrip(tmp_path):
    cs = fit_rows([
        _row("a_encode", 7.0, encode_ops=10.0, backend="local"),
        _row("a_comm", 3.0, comm_elems=6.0, backend="local"),
    ])
    path = tmp_path / "calibration.json"
    save_calibration(cs, path)
    loaded = load_calibration(path, cache=False)
    assert loaded.for_backend("local").coef == pytest.approx(
        cs.for_backend("local").coef
    )


def test_load_calibration_rejects_bad_payloads(tmp_path):
    bad_version = tmp_path / "v.json"
    bad_version.write_text(json.dumps({"version": 999, "backends": {}}))
    assert load_calibration(bad_version, cache=False) is None
    bad_coef = tmp_path / "c.json"
    bad_coef.write_text(json.dumps({
        "version": cal_mod.CALIBRATION_VERSION,
        "backends": {"local": {"coef": {"quantum": 1.0}}},
    }))
    assert load_calibration(bad_coef, cache=False) is None
    assert load_calibration(tmp_path / "missing.json", cache=False) is None


def test_env_var_disables_autoload(monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION", "off")
    assert load_calibration(cache=False) is None


def test_committed_calibration_loads():
    """The committed benchmarks/calibration.json must parse and carry at
    least the local backend with positive coefficients."""
    cs = load_calibration(cal_mod.DEFAULT_CALIBRATION_PATH, cache=False)
    assert cs is not None, "committed calibration.json missing or invalid"
    local = cs.for_backend("local")
    assert local is not None and local.coef
    assert all(v >= 0.0 for v in local.coef.values())


# ------------------------------------------------------- planner semantics


def _single_coef_set(name, value=1.0):
    return CalibrationSet(backends={
        "local": Calibration(backend="local", coef={name: value})
    })


def test_plan_ranks_by_fitted_coefficients_and_perturbation_flips():
    """The acceptance property: with a calibration present, "latency" ranks
    by predicted wall time — so swinging the fitted coefficients between
    two cost terms must flip which candidate (here: which scheme family)
    wins.  encode-dominated coefficients favor GCSA's cheap encode at this
    spec; compute-dominated ones favor Batch-EP_RMFE."""
    spec = ProblemSpec(32, 32, 32, n=4, ring=Z32, N=16)
    p_enc = plan(spec, objective="latency",
                 calibration=_single_coef_set("encode"))
    p_comp = plan(spec, objective="latency",
                  calibration=_single_coef_set("compute"))
    # either GCSA variant qualifies: gcsa_general at (1,1,1, kappa=1)
    # has the same cheap encode with an even lower threshold
    assert p_enc.best.scheme in ("gcsa", "gcsa_general")
    assert p_comp.best.scheme == "batch_ep_rmfe"
    assert p_enc.best.scheme != p_comp.best.scheme

    # and the scores are exactly the fitted linear model
    for p, term in ((p_enc, "encode_ops"), (p_comp, "worker_ops")):
        for c in p.candidates:
            assert c.score == pytest.approx(getattr(c.costs, term))


def test_plan_calibration_false_is_analytic():
    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    p = plan(spec, objective="latency", calibration=False)
    for c in p.candidates:
        co = c.costs
        assert c.score == pytest.approx(
            co.encode_ops + co.worker_ops + co.decode_ops
            + co.upload + co.download
        )


def test_plan_time_to_R_uses_calibrated_serial_tiebreak():
    from math import log1p

    from repro.cdmm.planner import expected_time_to_R

    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    cal = _single_coef_set("decode", 1000.0)
    p = plan(spec, objective="time_to_R", calibration=cal)
    for c in p.candidates:
        assert c.score == pytest.approx(
            expected_time_to_R(c.costs.N, c.costs.R)
            + 1e-6 * log1p(c.costs.decode_ops * 1000.0)
        )
    # the order statistic must stay the leading term: no candidate's
    # calibrated tie-break comes close to the smallest E[t_R] gap
    ts = sorted({expected_time_to_R(c.costs.N, c.costs.R)
                 for c in p.candidates})
    min_gap = min(b - a for a, b in zip(ts, ts[1:]))
    worst_tiebreak = max(
        1e-6 * log1p(c.costs.decode_ops * 1000.0) for c in p.candidates
    )
    assert worst_tiebreak < min_gap


def test_plan_empty_calibration_falls_back_to_analytic():
    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    empty = CalibrationSet(backends={
        "local": Calibration(backend="local", coef={})
    })
    p = plan(spec, objective="latency", calibration=empty)
    p0 = plan(spec, objective="latency", calibration=False)
    assert [c.score for c in p.candidates] == [c.score for c in p0.candidates]


def _full_coef_set(device=None):
    return CalibrationSet(
        backends={"local": Calibration(
            backend="local",
            # NOT all-ones: that would coincide with the analytic proxy sum
            coef={"encode": 2.0, "compute": 1.0, "decode": 1.0, "comm": 1.0},
        )},
        device=device,
    )


def test_autoloaded_calibration_requires_device_match(tmp_path, monkeypatch):
    """A committed file fitted on different hardware must not rank plans
    here: auto-load falls back to the analytic proxy on device mismatch
    (an explicitly pinned CalibrationSet remains the caller's business)."""
    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    path = tmp_path / "foreign.json"
    save_calibration(_full_coef_set(device="not-this-device"), path)
    monkeypatch.setenv("REPRO_CALIBRATION", str(path))
    cal_mod.invalidate_calibration_cache()
    p = plan(spec, objective="latency")
    p0 = plan(spec, objective="latency", calibration=False)
    assert [c.score for c in p.candidates] == [c.score for c in p0.candidates]
    # same file pinned explicitly: trusted as-is
    pinned = load_calibration(path, cache=False)
    pp = plan(spec, objective="latency", calibration=pinned)
    assert pp.candidates[0].score != p0.candidates[0].score


def test_autoloaded_partial_calibration_falls_back(tmp_path, monkeypatch):
    """An auto-loaded fit missing a cost term would silently score it as
    free — the planner must reject it and keep the analytic proxy."""
    import jax

    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    partial = CalibrationSet(
        backends={"local": Calibration(backend="local",
                                       coef={"encode": 123.0})},
        device=jax.default_backend(),
    )
    path = tmp_path / "partial.json"
    save_calibration(partial, path)
    monkeypatch.setenv("REPRO_CALIBRATION", str(path))
    cal_mod.invalidate_calibration_cache()
    p = plan(spec, objective="latency")
    p0 = plan(spec, objective="latency", calibration=False)
    assert [c.score for c in p.candidates] == [c.score for c in p0.candidates]


def test_objectives_without_calibration_semantics_unchanged():
    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    cal = _single_coef_set("compute", 999.0)
    for objective in ("threshold", "download", "upload"):
        pc = plan(spec, objective=objective, calibration=cal)
        pa = plan(spec, objective=objective, calibration=False)
        assert [c.score for c in pc.candidates] == [
            c.score for c in pa.candidates
        ]
