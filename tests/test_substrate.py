"""Substrate tests: optimizers, compression, data determinism, checkpoint
save/restore + elastic re-mesh, pipeline parallelism."""
import os

import numpy as np
import pytest

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import Checkpointer  # noqa: E402
from repro.compat import shard_map  # noqa: E402
from repro.configs import ARCHS, smoke_shape  # noqa: E402
from repro.data import DataConfig, TokenPipeline  # noqa: E402
from repro.optim import (  # noqa: E402
    OptConfig,
    compress_tree,
    compressed_psum,
    init_ef,
    opt_init,
    opt_update,
    schedule,
)
from repro.runtime.elastic import elastic_restore, replan_batch  # noqa: E402
from repro.runtime.pipeline import pipelined_apply  # noqa: E402
from repro.runtime.sharding import (  # noqa: E402
    ParamSpec,
    axis_rules,
    materialize,
    shard,
    sharding_tree,
    spec_for,
)

NDEV = len(jax.devices())
needs8 = pytest.mark.skipif(NDEV < 8, reason="needs 8 devices")


# ------------------------------------------------------------- optimizers


def quad_params():
    return {
        "w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 4)), jnp.float32),
        "b": jnp.zeros((4,), jnp.float32),
    }


def quad_loss(p):
    return jnp.sum(jnp.square(p["w"])) + jnp.sum(jnp.square(p["b"] - 1.0))


@pytest.mark.parametrize("name,sdtype", [
    ("adamw", "float32"), ("adamw", "bfloat16"), ("adamw", "int8"),
    ("adafactor", "float32"),
])
def test_optimizer_descends(name, sdtype):
    cfg = OptConfig(name=name, lr=5e-2, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, state_dtype=sdtype)
    params = quad_params()
    state = opt_init(cfg, params)
    l0 = float(quad_loss(params))

    @jax.jit
    def step(params, state):
        grads = jax.grad(quad_loss)(params)
        return opt_update(cfg, grads, state, params)

    for _ in range(60):
        params, state, metrics = step(params, state)
    assert float(quad_loss(params)) < 0.5 * l0, (name, sdtype)
    assert np.isfinite(float(metrics["gnorm"]))


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.1 + 1e-6


# ------------------------------------------------------------- compression


def test_compression_error_feedback_converges():
    """EF quantization: mean of compressed grads ~ mean of true grads."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 0.01
    ef = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for i in range(50):
        out, ef = compress_tree(g_true, ef)
        acc = acc + out
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true), atol=1e-4)


@needs8
def test_compressed_psum():
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("pod",))
    rng = np.random.default_rng(1)
    gs = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)

    def body(g):
        ef = jnp.zeros_like(g[0])
        mean, _ = compressed_psum(g[0], ef, "pod")
        return mean[None]

    out = jax.jit(
        shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                      check=False)
    )(gs)
    expect = np.mean(np.asarray(gs), axis=0)
    np.testing.assert_allclose(np.asarray(out)[0], expect, atol=2e-2)


# ------------------------------------------------------------------- data


def test_data_deterministic_replay():
    cfg = ARCHS["gemma2-2b"].smoke()
    pipe = TokenPipeline(DataConfig(seed=7), cfg, smoke_shape("train"), shard=2, num_shards=4)
    b1 = pipe.batch_at(13)
    b2 = pipe.batch_at(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipe.batch_at(14)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shards are disjoint streams
    pipe0 = TokenPipeline(DataConfig(seed=7), cfg, smoke_shape("train"), shard=0, num_shards=4)
    assert not np.array_equal(pipe0.batch_at(13)["tokens"], b1["tokens"])
    # labels are next-token shifted
    full = pipe.batch_at(5)
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_data_binfile(tmp_path):
    toks = np.arange(10000, dtype=np.uint16)
    path = tmp_path / "tokens.bin"
    toks.tofile(path)
    cfg = ARCHS["gemma2-2b"].smoke()
    pipe = TokenPipeline(
        DataConfig(source="binfile", path=str(path)), cfg, smoke_shape("train")
    )
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (2, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_frontend_batches():
    cfg = ARCHS["internvl2-2b"].smoke()
    pipe = TokenPipeline(DataConfig(), cfg, smoke_shape("train"))
    b = pipe.with_frontend(pipe.batch_at(0), 0)
    assert b["patches"].shape == (2, cfg.frontend_len, cfg.frontend_dim)


# ------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)}, "step": jnp.asarray(5)}
    ck.save(5, tree)
    ck.save(7, tree, blocking=False)
    ck.wait()
    assert ck.all_steps() == [5, 7]
    out = ck.restore(5)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.arange(12).reshape(3, 4))


def test_checkpoint_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = {"x": jnp.zeros(3)}
    for s in [1, 2, 3, 4]:
        ck.save(s, t)
    assert ck.all_steps() == [3, 4]


@needs8
def test_elastic_restore_new_mesh(tmp_path):
    """Save from one mesh shape, restore onto another — values identical."""
    specs = {"w": ParamSpec((8, 16), ("fsdp", "ffn"), jnp.float32)}
    mesh_a = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    params = materialize(specs, jax.random.PRNGKey(0))
    params = jax.device_put(params, sharding_tree(specs, mesh_a))
    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"params": params})
    mesh_b = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
    out = elastic_restore(ck, specs, mesh_b)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(params["w"]))
    got = out["params"]["w"].sharding
    assert got.mesh.shape == dict(mesh_b.shape) or got.mesh.axis_names == mesh_b.axis_names


def test_replan_batch():
    assert replan_batch(256, 16) == 16
    assert replan_batch(256, 15) == 18  # grow per-shard batch after failure


# ---------------------------------------------------------------- pipeline


@needs8
def test_pipeline_matches_sequential():
    """GPipe over 4 stages == sequential scan over the full layer stack."""
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("pod",))
    L, B, D = 8, 8, 16
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.standard_normal((L, D, D)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

    def layer(w, h):
        return jnp.tanh(h @ w)

    def stage_fn(ws, h):  # ws: (L/stages, D, D) this stage's slice
        def body(h, w):
            return layer(w, h), None
        out, _ = jax.lax.scan(body, h, ws)
        return out

    y_pipe = jax.jit(
        lambda W, xx: pipelined_apply(stage_fn, W, xx, mesh, axis="pod", microbatches=4)
    )(Ws, x)

    def seq(h, w):
        return layer(w, h), None
    y_ref, _ = jax.lax.scan(seq, x, Ws)
    y_ref = y_ref  # scan returns (carry, ys); carry is final h
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ sharding unit


def test_spec_for_divisibility_fallback():
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
    # 24 % 4 == 0 -> sharded; 30 % 4 != 0 -> replicated
    assert spec_for((24,), ("ffn",), mesh) == P("model")
    assert spec_for((30,), ("ffn",), mesh) == P(None)
    # multi-axis batch: 8 % (2) ok only if product divides
    assert spec_for((8, 16), ("batch", "ffn"), mesh) == P("data", "model")


def test_shard_noop_outside_context():
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x
