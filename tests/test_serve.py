"""repro.serve: continuous batching, tested from policy to pool bits.

Covers the pure coalescer (synthetic clock: cap fills, wait expiry,
adaptive idle, per-key isolation), the ServeStats surface, the planner's
``"amortized"`` cross-arity decision, the PoolScheduler submit-deadline
fix, and — against a real worker pool — the serving engine's headline
properties: coalesced batches decode bit-identically to the plain oracle,
partial batches pad correctly at fill 1 and pack−1, mixed-spec streams
never share a codeword, and a coalesced secure batch under a fixed key
matches sequential single requests bit for bit.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# serve tests assert the analytic amortized decision (coalesce at n=2 over
# Z_2^32); a host-specific calibration fit must not re-rank it
os.environ.setdefault("REPRO_CALIBRATION", "off")

import time

import numpy as np
import pytest

import jax

from repro.cdmm import ProblemSpec, plan
from repro.cdmm.api import get_scheme
from repro.core import make_ring
from repro.dist import LocalPool, PoolScheduler
from repro.serve import BatchCoalescer, CoalescePolicy, ServeScheduler
from repro.serve.stats import ServeStats

# multi-process pool smokes dominate tier-1 wall time; deselected by
# `tools/ci.sh --fast` (see tests/conftest.py for the marker)
pytestmark = pytest.mark.slow

Z32 = make_ring(2, 32, ())
KEY = jax.random.PRNGKey(11)
POOL_WORKERS = 4


# --------------------------------------------------------------------------
# coalescer policy (pure logic, synthetic clock)
# --------------------------------------------------------------------------


def test_policy_validation():
    with pytest.raises(ValueError):
        CoalescePolicy(target_batch_n=0).validate()
    with pytest.raises(ValueError):
        CoalescePolicy(max_wait_ms=-1.0).validate()
    with pytest.raises(ValueError):
        BatchCoalescer(CoalescePolicy(adaptive_idle_ms=-0.1))
    CoalescePolicy().validate()  # defaults are sane


def test_coalescer_fills_at_cap():
    c = BatchCoalescer(CoalescePolicy(max_wait_ms=1000.0))
    assert c.add("k", "a", cap=3, now_s=0.0) is None
    assert c.add("k", "b", cap=3, now_s=0.001) is None
    assert c.pending() == 2
    full = c.add("k", "c", cap=3, now_s=0.002)
    assert full == ["a", "b", "c"]
    assert c.pending() == 0
    assert c.due(now_s=100.0) == []  # buffer was consumed, nothing expires


def test_coalescer_wait_expiry_from_oldest_member():
    c = BatchCoalescer(CoalescePolicy(max_wait_ms=10.0))
    c.add("k", "a", cap=8, now_s=0.0)
    c.add("k", "b", cap=8, now_s=0.005)  # newer member must NOT extend
    assert c.due(now_s=0.0099) == []
    assert c.next_wait_s(now_s=0.0099) == pytest.approx(0.0001)
    assert c.due(now_s=0.010) == [("k", ["a", "b"])]
    assert c.next_wait_s(now_s=0.011) is None


def test_coalescer_adaptive_idle_flush():
    c = BatchCoalescer(
        CoalescePolicy(max_wait_ms=100.0, adaptive=True, adaptive_idle_ms=1.0)
    )
    c.add("k", "a", cap=8, now_s=0.0)
    # arrivals keep refreshing the idle clock
    c.add("k", "b", cap=8, now_s=0.0008)
    assert c.due(now_s=0.0015, queue_empty=True) == []
    # queue not empty: more arrivals are coming, hold for them
    assert c.due(now_s=0.003, queue_empty=False) == []
    # queue drained and idle passed: flush the partial batch early
    assert c.due(now_s=0.003, queue_empty=True) == [("k", ["a", "b"])]


def test_coalescer_keys_isolated_and_flush_all():
    c = BatchCoalescer(CoalescePolicy(max_wait_ms=10.0))
    assert c.add("spec1", "a", cap=2, now_s=0.0) is None
    assert c.add("spec2", "x", cap=2, now_s=0.0) is None
    # same count as spec1's cap, but under a different key: no batch
    full = c.add("spec1", "b", cap=2, now_s=0.001)
    assert full == ["a", "b"]  # only spec1's members, never spec2's
    assert c.pending() == 1
    assert c.flush_all() == [("spec2", ["x"])]
    assert c.pending() == 0


# --------------------------------------------------------------------------
# stats surfaces
# --------------------------------------------------------------------------


def test_serve_stats_snapshot_derived_fields():
    s = ServeStats()
    s.bump("submitted", 3)
    s.record_batch("b[8]", fill=2, pad=0, wall_ms=10.0, waits_ms=[0.4, 3.0])
    s.record_batch("b[8]", fill=1, pad=1, wall_ms=5.0, waits_ms=[40.0])
    snap = s.snapshot()
    assert isinstance(snap, dict)
    assert snap["submitted"] == 3
    assert snap["batches"] == 2 and snap["coalesced_batches"] == 1
    assert snap["total_fill"] == 3 and snap["total_pad"] == 1
    assert snap["mean_fill"] == pytest.approx(1.5)
    assert snap["amortized_us_per_request"] == pytest.approx(15.0 * 1e3 / 3)
    assert snap["wait_ms_hist"]["<=0.5"] == 1
    assert snap["wait_ms_hist"]["<=5"] == 1
    assert snap["wait_ms_hist"]["<=50"] == 1
    assert snap["wait_ms_p50"] == 5.0
    assert snap["wait_ms_p99"] == 50.0
    assert [b["fill"] for b in snap["recent_batches"]] == [2, 1]


def test_serve_stats_empty_snapshot():
    snap = ServeStats().snapshot()
    assert snap["mean_fill"] == 0.0
    assert snap["amortized_us_per_request"] is None
    assert snap["wait_ms_p50"] is None


def test_scheduler_stats_snapshot_is_plain_dict():
    from repro.dist.scheduler import SchedulerStats

    st = SchedulerStats()
    st._bump("submitted")
    st._bump("timed_out")
    snap = st.snapshot()
    # counters in the shared scheduler_-prefixed schema, plus the
    # request-latency histogram family (repro.stats: _hist/_p50/_p99/_sum)
    assert {
        k: v for k, v in snap.items()
        if not k.startswith("scheduler_request_ms")
    } == {
        "scheduler_submitted": 1, "scheduler_rejected": 0,
        "scheduler_completed": 0, "scheduler_failed": 0,
        "scheduler_timed_out": 1, "scheduler_plan_cache_hits": 0,
        "scheduler_plan_cache_misses": 0,
    }
    assert set(snap) >= {
        "scheduler_request_ms_hist", "scheduler_request_ms_p50",
        "scheduler_request_ms_p99",
    }
    assert snap["scheduler_request_ms_p50"] is None  # nothing observed yet
    # legacy unprefixed reads still resolve (one DeprecationWarning)
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert snap["submitted"] == 1
    # live attribute reads track the registry; snapshots are copies
    st._bump("submitted")
    assert st.submitted == 2
    assert snap["scheduler_submitted"] == 1


# --------------------------------------------------------------------------
# the amortized objective (planner decision, no pool needed)
# --------------------------------------------------------------------------


def test_with_batch_validation():
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=6)
    assert spec.with_batch(4).n == 4
    assert spec.with_batch(4).t == spec.t
    with pytest.raises(ValueError):
        spec.with_batch(0)


def test_amortized_coalescing_wins_at_n2_loses_at_n4():
    # the Z_2^32 exceptional-point shortage: the embedding extension the
    # single schemes already pay for doubles as RMFE packing space at n=2,
    # so one batch job undercuts two singles; at n=4 the two-level tower
    # overwhelms the amortization and singles win back
    spec = ProblemSpec(t=16, r=16, s=16, n=1, ring=Z32, N=6,
                       straggler_budget=1)
    p1 = plan(spec, objective="amortized", backend="pool")
    p2 = plan(spec.with_batch(2), objective="amortized", backend="pool")
    p4 = plan(spec.with_batch(4), objective="amortized", backend="pool")
    assert not get_scheme(p1.best.scheme).batched
    assert get_scheme(p2.best.scheme).batched
    assert p2.best.score < p1.best.score
    assert not get_scheme(p4.best.scheme).batched  # singles won back
    assert p4.best.score == pytest.approx(p1.best.score)


def test_amortized_scan_considers_gcsa_general():
    # the executable general-GCSA family rides the registry into the
    # amortized cross-arity scan with zero serve-side plumbing: at n=2 a
    # (u=v=w=1, kappa) configuration fits the R <= 5 budget and is ranked
    # (it loses to batch_ep_rmfe on cost, which keeps the pinned decisions
    # in test_amortized_coalescing_wins_at_n2_loses_at_n4 intact)
    spec = ProblemSpec(t=16, r=16, s=16, n=2, ring=Z32, N=6,
                       straggler_budget=1)
    p = plan(spec, objective="amortized", backend="pool")
    g = p.by_scheme("gcsa_general")
    assert g is not None and (g.u, g.v, g.w) == (1, 1, 1)
    b = p.by_scheme("batch_ep_rmfe")
    assert b.score < g.score


def test_amortized_objective_requires_registration():
    # non-amortized objectives keep the strict arity filter: a batched spec
    # only ranks batched families
    spec = ProblemSpec(t=16, r=16, s=16, n=2, ring=Z32, N=6,
                       straggler_budget=1)
    p = plan(spec, objective="latency", backend="pool")
    assert all(get_scheme(c.scheme).batched for c in p.candidates)


def test_engine_entry_decision_without_pool():
    # entry_for is pure planning: no master interaction until dispatch
    sched = ServeScheduler(master=None, policy=CoalescePolicy(
        target_batch_n=8, max_wait_ms=1.0))
    try:
        spec = ProblemSpec(t=16, r=16, s=16, n=1, ring=Z32, N=6,
                           straggler_budget=1)
        entry = sched.entry_for(spec)
        assert entry.scheme.name == "batch_ep_rmfe"
        assert entry.cap == entry.scheme.batch == 2
        # cached: second lookup is a hit
        assert sched.entry_for(spec) is entry
        snap = sched.stats.snapshot()
        assert snap["plan_cache_misses"] == 1
        assert snap["plan_cache_hits"] == 1
        # a target below the winning arity forbids coalescing entirely
        lone = ServeScheduler(master=None, policy=CoalescePolicy(
            target_batch_n=1, max_wait_ms=1.0))
        try:
            assert lone.entry_for(spec).cap == 1
        finally:
            lone.close()
    finally:
        sched.close()


def test_engine_rejects_batched_specs():
    sched = ServeScheduler(master=None)
    try:
        spec = ProblemSpec(t=8, r=8, s=8, n=2, ring=Z32, N=6)
        with pytest.raises(ValueError, match="per-request"):
            sched.submit(None, None, spec=spec)
    finally:
        sched.close()


# --------------------------------------------------------------------------
# real worker processes (one pool for the whole module)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pool():
    with LocalPool(workers=POOL_WORKERS) as p:
        yield p


def _pairs(rng, count, size):
    return [
        (Z32.random(rng, (size, size)), Z32.random(rng, (size, size)))
        for _ in range(count)
    ]


def test_serve_coalesces_bit_identical_to_oracle(pool):
    spec = ProblemSpec(t=16, r=16, s=16, n=1, ring=Z32, N=6,
                       straggler_budget=1)
    rng = np.random.default_rng(0)
    pairs = _pairs(rng, 8, 16)
    with ServeScheduler(
        pool.master, CoalescePolicy(target_batch_n=8, max_wait_ms=200.0),
        max_queue=16, seed=0,
    ) as sched:
        futs = [sched.submit(A, B, spec=spec) for A, B in pairs]
        for fut, (A, B) in zip(futs, pairs):
            np.testing.assert_array_equal(
                np.asarray(fut.result(120)), np.asarray(Z32.matmul(A, B))
            )
        snap = sched.stats.snapshot()
    assert snap["completed"] == 8
    assert snap["batches"] == 4  # 8 requests at cap 2
    assert snap["coalesced_batches"] == 4
    assert snap["mean_fill"] == pytest.approx(2.0)
    assert snap["total_pad"] == 0


def test_serve_partial_batch_padding_fill_one(pool):
    # a lone request against cap 2: the batch pads one zero slot (which is
    # both fill=1 AND pack_size-1 for this cap) and must still decode to
    # the exact product; the pad row is sliced off before delivery
    spec = ProblemSpec(t=16, r=16, s=16, n=1, ring=Z32, N=6,
                       straggler_budget=1)
    rng = np.random.default_rng(1)
    with ServeScheduler(
        pool.master, CoalescePolicy(target_batch_n=8, max_wait_ms=5.0),
        max_queue=16, seed=1,
    ) as sched:
        (A, B), = _pairs(rng, 1, 16)
        fut = sched.submit(A, B, spec=spec)
        np.testing.assert_array_equal(
            np.asarray(fut.result(120)), np.asarray(Z32.matmul(A, B))
        )
        # odd stream: 3 requests -> one full batch + one padded partial
        trio = _pairs(rng, 3, 16)
        futs = [sched.submit(A, B, spec=spec) for A, B in trio]
        for fut, (A, B) in zip(futs, trio):
            np.testing.assert_array_equal(
                np.asarray(fut.result(120)), np.asarray(Z32.matmul(A, B))
            )
        snap = sched.stats.snapshot()
    assert snap["completed"] == 4
    assert snap["total_pad"] == 2  # the lone request + the odd one out
    fills = sorted(b["fill"] for b in snap["recent_batches"])
    assert fills == [1, 1, 2]


def test_serve_mixed_specs_never_coalesce(pool):
    # interleaved shapes must land in separate codewords: a coalesced
    # batch is one ProblemSpec by construction
    spec_a = ProblemSpec(t=16, r=16, s=16, n=1, ring=Z32, N=6,
                         straggler_budget=1)
    spec_b = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=6,
                         straggler_budget=1)
    rng = np.random.default_rng(2)
    pa = _pairs(rng, 2, 16)
    pb = _pairs(rng, 2, 8)
    with ServeScheduler(
        pool.master, CoalescePolicy(target_batch_n=8, max_wait_ms=200.0),
        max_queue=16, seed=2,
    ) as sched:
        futs = []
        for (Aa, Ba), (Ab, Bb) in zip(pa, pb):  # interleave submission
            futs.append((sched.submit(Aa, Ba, spec=spec_a), Aa, Ba))
            futs.append((sched.submit(Ab, Bb, spec=spec_b), Ab, Bb))
        for fut, A, B in futs:
            np.testing.assert_array_equal(
                np.asarray(fut.result(120)), np.asarray(Z32.matmul(A, B))
            )
        snap = sched.stats.snapshot()
    assert snap["completed"] == 4
    assert snap["batches"] == 2  # one per spec, never across
    labels = {b["spec"] for b in snap["recent_batches"]}
    assert len(labels) == 2  # distinct shapes stayed distinct
    assert all(b["fill"] == 2 for b in snap["recent_batches"])


def test_serve_secure_coalesced_matches_sequential_fixed_key(pool):
    # one key masks one codeword: a coalesced secure batch under a fixed
    # key must be bit-identical to the same requests served one by one
    # (exact any-R decode makes both equal the plain oracle)
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=8,
                       straggler_budget=1, privacy_t=1)
    rng = np.random.default_rng(3)
    pairs = _pairs(rng, 2, 8)
    with ServeScheduler(
        pool.master, CoalescePolicy(target_batch_n=2, max_wait_ms=200.0),
        max_queue=8, seed=3,
    ) as sched:
        assert sched.entry_for(spec).scheme.name == "ep_rmfe_secure"
        futs = [sched.submit(A, B, spec=spec, key=KEY) for A, B in pairs]
        coalesced = [np.asarray(f.result(120)) for f in futs]
        assert sched.stats.snapshot()["coalesced_batches"] == 1
    # sequential singles: same engine surface, coalescing forbidden
    with ServeScheduler(
        pool.master, CoalescePolicy(target_batch_n=1, max_wait_ms=1.0),
        max_queue=8, seed=3,
    ) as sched:
        assert sched.entry_for(spec).cap == 1
        sequential = [
            np.asarray(sched.submit(A, B, spec=spec, key=KEY).result(120))
            for A, B in pairs
        ]
        assert sched.stats.snapshot()["coalesced_batches"] == 0
    for got, seq, (A, B) in zip(coalesced, sequential, pairs):
        np.testing.assert_array_equal(got, seq)
        np.testing.assert_array_equal(got, np.asarray(Z32.matmul(A, B)))


def test_pool_scheduler_timeout_is_deadline_from_submit(pool):
    # satellite fix: queue wait draws down request_timeout — a request
    # stuck behind a slow one must fail at the promised deadline without
    # ever reaching the pool
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=4)
    scheme = plan(spec, backend="pool").instantiate()
    rng = np.random.default_rng(4)
    A = Z32.random(rng, (8, 8))
    B = Z32.random(rng, (8, 8))
    # warm the jit/socket path so the parked delay dominates the timing
    with PoolScheduler(pool.master, max_inflight=1) as sched:
        sched.submit(A, B, scheme=scheme).result(120)
    for wid in pool.master.live_workers():
        pool.master.task_delay_ms[wid] = 400.0
    try:
        with PoolScheduler(
            pool.master, max_queue=4, max_inflight=1, request_timeout=0.25,
        ) as sched:
            f1 = sched.submit(A, B, scheme=scheme)
            f2 = sched.submit(A, B, scheme=scheme)  # waits behind f1
            with pytest.raises(TimeoutError):
                f2.result(120)
            assert sched.stats.snapshot()["timed_out"] >= 1
            # f1 had the whole budget for execution; parked at 400ms it
            # blows the 250ms deadline inside the pool instead
            with pytest.raises(TimeoutError):
                f1.result(120)
    finally:
        pool.master.task_delay_ms.clear()
        # the parked tasks are still draining on the workers; give the
        # pool a beat so later tests see a quiet pool
        time.sleep(0.5)
