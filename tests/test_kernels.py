"""Pallas gr_matmul kernel vs pure-jnp oracle: shape/ring sweeps + hypothesis.

hypothesis is optional: the deterministic sweeps always run; the
property-based tests skip cleanly when it is not installed.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.core.galois import make_ring
from repro.kernels import gr_matmul, gr_matmul_ref, kernel_supported

RINGS = [
    make_ring(2, 32, ()),      # Z_{2^32}, D=1
    make_ring(2, 32, (3,)),    # GR(2^32, 3) — paper's 8-worker ring
    make_ring(2, 32, (4,)),    # GR(2^32, 4) — paper's 16-worker ring
    make_ring(2, 16, (5,)),    # e<32 mask path
    make_ring(2, 8, (2, 3)),   # tower, D=6
]

SHAPES = [
    (8, 8, 8),
    (16, 32, 8),
    (128, 128, 128),
    (7, 13, 5),     # ragged -> exercises padding
    (1, 64, 1),
    (130, 17, 129),  # just past block boundaries
]


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(3)


@pytest.mark.parametrize("ring", RINGS, ids=repr)
@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_ref(ring, shape, rng):
    t, r, s = shape
    A = ring.random(rng, (t, r))
    B = ring.random(rng, (r, s))
    out = gr_matmul(A, B, ring, interpret=True)
    ref = gr_matmul_ref(A, B, ring)
    assert out.shape == ref.shape == (t, s, ring.D)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_kernel_block_sweep(rng):
    ring = make_ring(2, 32, (3,))
    A = ring.random(rng, (32, 64))
    B = ring.random(rng, (64, 16))
    ref = np.asarray(gr_matmul_ref(A, B, ring))
    for blocks in [(8, 8, 8), (16, 16, 64), (32, 16, 32), (8, 16, 64)]:
        out = gr_matmul(A, B, ring, blocks=blocks, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), ref, err_msg=str(blocks))


def test_kernel_fallback_odd_p(rng):
    ring = make_ring(3, 2, (2,))
    assert not kernel_supported(ring)
    A = ring.random(rng, (4, 4))
    B = ring.random(rng, (4, 4))
    out = gr_matmul(A, B, ring)  # silently uses the reference
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(gr_matmul_ref(A, B, ring))
    )


def test_kernel_jit(rng):
    ring = make_ring(2, 32, (3,))

    @jax.jit
    def f(A, B):
        return gr_matmul(A, B, ring, interpret=True)

    A = ring.random(rng, (16, 16))
    B = ring.random(rng, (16, 16))
    np.testing.assert_array_equal(
        np.asarray(f(A, B)), np.asarray(gr_matmul_ref(A, B, ring))
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        t=st.integers(1, 40),
        r=st.integers(1, 40),
        s=st.integers(1, 40),
        ringix=st.integers(0, len(RINGS) - 1),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_kernel_property(t, r, s, ringix, seed):
        ring = RINGS[ringix]
        g = np.random.default_rng(seed)
        A = ring.random(g, (t, r))
        B = ring.random(g, (r, s))
        out = gr_matmul(A, B, ring, interpret=True)
        ref = gr_matmul_ref(A, B, ring)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        t=st.integers(1, 16),
        r=st.integers(1, 16),
        s=st.integers(1, 16),
    )
    def test_matmul_distributes_property(seed, t, r, s):
        """Hypothesis: ring matmul is bilinear — (A+A')B = AB + A'B."""
        ring = make_ring(2, 32, (3,))
        g = np.random.default_rng(seed)
        A, A2 = ring.random(g, (t, r)), ring.random(g, (t, r))
        B = ring.random(g, (r, s))
        lhs = gr_matmul(ring.add(A, A2), B, ring, interpret=True)
        rhs = ring.add(
            gr_matmul(A, B, ring, interpret=True),
            gr_matmul(A2, B, ring, interpret=True),
        )
        np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_kernel_property():
        pytest.importorskip("hypothesis")

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_matmul_distributes_property():
        pytest.importorskip("hypothesis")
