"""Tests for EP / Polynomial / MatDot codes, Batch-EP_RMFE, EP_RMFE-I/II,
plain-embedding baseline and CSA — including any-R straggler recovery."""
import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    BatchEPRMFE,
    CSACode,
    EPCode,
    EPRMFE_I,
    EPRMFE_II,
    PlainCDMM,
    gr_solve,
    make_ring,
    select_workers,
    simulate_stragglers,
)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(7)


def ref_matmul(ring, A, B):
    """Independent dense reference over the ring."""
    return ring.matmul(A, B)


# ---------------------------------------------------------------- EP codes


EP_CASES = [
    # (ring args, N, u, v, w, t, r, s)
    ((2, 8, (4,)), 10, 2, 2, 2, 4, 4, 4),   # general EP, R=9
    ((2, 32, (3,)), 8, 2, 2, 1, 4, 4, 4),    # polynomial-style w=1, R=4
    ((2, 32, (3,)), 8, 1, 1, 4, 4, 8, 4),    # MatDot u=v=1, R=7
    ((3, 2, (3,)), 9, 2, 2, 2, 4, 4, 4),     # odd p
]


@pytest.mark.parametrize("ringargs,N,u,v,w,t,r,s", EP_CASES)
def test_ep_code_exact(ringargs, N, u, v, w, t, r, s, rng):
    ring = make_ring(*ringargs)
    code = EPCode(ring, N, u, v, w)
    A = ring.random(rng, (t, r))
    B = ring.random(rng, (r, s))
    C = code.run(A, B)
    expect = ref_matmul(ring, A, B)
    assert np.array_equal(np.asarray(C), np.asarray(expect))


def test_ep_any_R_subset(rng):
    """EVERY R-subset of workers must decode correctly (the defining property)."""
    ring = make_ring(2, 8, (3,))
    code = EPCode(ring, N=7, u=2, v=2, w=1)  # R = 4
    A = ring.random(rng, (4, 4))
    B = ring.random(rng, (4, 4))
    expect = np.asarray(ref_matmul(ring, A, B))
    FA, GB = code.encode_a(A), code.encode_b(B)
    H = code.worker_compute(FA, GB)

    @jax.jit
    def dec(idx):
        return code.decode(jnp.take(H, idx, axis=0), idx)

    for subset in itertools.combinations(range(7), 4):
        C = dec(jnp.asarray(subset, dtype=jnp.int32))
        assert np.array_equal(np.asarray(C), expect), subset


def test_ep_decode_jit_with_dynamic_idx(rng):
    ring = make_ring(2, 32, (3,))
    code = EPCode(ring, N=8, u=2, v=2, w=1)
    A = ring.random(rng, (2, 2))
    B = ring.random(rng, (2, 2))
    FA, GB = code.encode_a(A), code.encode_b(B)
    H = code.worker_compute(FA, GB)

    @jax.jit
    def dec(H, idx):
        return code.decode(jnp.take(H, idx, axis=0), idx)

    expect = np.asarray(ref_matmul(ring, A, B))
    for subset in [(0, 1, 2, 3), (4, 5, 6, 7), (1, 3, 5, 7)]:
        idx = jnp.asarray(subset, dtype=jnp.int32)
        assert np.array_equal(np.asarray(dec(H, idx)), expect)


def test_ep_threshold_validation():
    ring = make_ring(2, 8, (3,))
    with pytest.raises(ValueError):
        EPCode(ring, N=3, u=2, v=2, w=1)  # R=4 > N
    with pytest.raises(ValueError):
        EPCode(ring, N=20, u=2, v=2, w=1)  # N > |T| = 8


# ------------------------------------------------------------ plain baseline


def test_plain_cdmm_over_z2e(rng):
    base = make_ring(2, 32, ())
    plain = PlainCDMM(base, N=8, u=2, v=2, w=1)
    assert plain.ext.D >= 3
    A = base.random(rng, (4, 4))
    B = base.random(rng, (4, 4))
    C = plain.run(A, B)
    expect = ref_matmul(base, A, B)
    assert np.array_equal(np.asarray(C), np.asarray(expect))


# ------------------------------------------------------------ Batch-EP_RMFE


BATCH_CASES = [
    # (ring args, n, N, u, v, w)
    ((2, 32, ()), 2, 8, 2, 2, 1),    # the paper's 8-worker experiment shape
    ((2, 32, ()), 2, 16, 2, 2, 2),   # paper's 16-worker shape, R=9
    ((2, 16, (2,)), 3, 16, 1, 1, 3), # MatDot inside, n=3
    ((3, 2, (2,)), 4, 9, 2, 2, 1),   # odd p
]


@pytest.mark.parametrize("ringargs,n,N,u,v,w", BATCH_CASES)
def test_batch_rmfe(ringargs, n, N, u, v, w, rng):
    base = make_ring(*ringargs)
    sch = BatchEPRMFE(base, n=n, N=N, u=u, v=v, w=w)
    assert sch.R == u * v * w + w - 1  # paper Thm III.2
    t, r, s = 2 * u, 2 * w * max(1, w), 2 * v
    As = base.random(rng, (sch.rmfe.n, t, r))
    Bs = base.random(rng, (sch.rmfe.n, r, s))
    Cs = sch.run(As, Bs)
    for i in range(sch.rmfe.n):
        expect = ref_matmul(base, As[i], Bs[i])
        assert np.array_equal(np.asarray(Cs[i]), np.asarray(expect)), i


def test_batch_rmfe_straggler_subsets(rng):
    base = make_ring(2, 32, ())
    sch = BatchEPRMFE(base, n=2, N=8, u=2, v=2, w=1)  # R = 4
    As = base.random(rng, (2, 4, 4))
    Bs = base.random(rng, (2, 4, 4))
    FA, GB = sch.encode(As, Bs)
    H = sch.worker_compute(FA, GB)
    expects = [np.asarray(ref_matmul(base, As[i], Bs[i])) for i in range(2)]

    @jax.jit
    def dec(idx):
        return sch.decode(jnp.take(H, idx, axis=0), idx)

    subsets = list(itertools.combinations(range(8), 4))
    for subset in subsets[::7] + [subsets[-1]]:  # sampled + extremes
        Cs = dec(jnp.asarray(subset, dtype=jnp.int32))
        for i in range(2):
            assert np.array_equal(np.asarray(Cs[i]), expects[i]), subset


def test_batch_rmfe_threshold_beats_gcsa():
    """Table 1: R_RMFE = uvw + w - 1 vs R_GCSA = uvw(n + kappa - 1) + w - 1."""
    from repro.core import gcsa_cost_model

    base = make_ring(2, 32, ())
    for n in [2, 4, 8]:
        sch = BatchEPRMFE(base, n=n, N=64, u=2, v=2, w=2)
        g = gcsa_cost_model(8, 8, 8, 2, 2, 2, n, n, 64, m_eff=6)
        assert sch.R < g.R
        assert g.R >= n * sch.R * 0.5  # factor ~ 1/(2n) at kappa=n


# ------------------------------------------------------------- EP_RMFE-I/II


def test_eprmfe1(rng):
    base = make_ring(2, 32, ())
    sch = EPRMFE_I(base, n=2, N=8, u=2, v=2, w=1)
    assert sch.R == 4
    A = base.random(rng, (4, 8))
    B = base.random(rng, (8, 4))
    C = sch.run(A, B)
    assert np.array_equal(np.asarray(C), np.asarray(ref_matmul(base, A, B)))


def test_eprmfe1_matdot_inside(rng):
    base = make_ring(2, 16, ())
    sch = EPRMFE_I(base, n=2, N=16, u=1, v=1, w=4)  # R = 7
    A = base.random(rng, (4, 16))
    B = base.random(rng, (16, 4))
    C = sch.run(A, B)
    assert np.array_equal(np.asarray(C), np.asarray(ref_matmul(base, A, B)))


def test_eprmfe2(rng):
    base = make_ring(2, 32, ())
    sch = EPRMFE_II(base, n=2, N=8, u=2, v=2, w=1)
    assert sch.R == 4
    A = base.random(rng, (8, 4))
    B = base.random(rng, (4, 8))
    C = sch.run(A, B)
    assert np.array_equal(np.asarray(C), np.asarray(ref_matmul(base, A, B)))


def test_eprmfe2_straggler(rng):
    base = make_ring(2, 32, ())
    sch = EPRMFE_II(base, n=2, N=8, u=2, v=2, w=1)
    A = base.random(rng, (4, 4))
    B = base.random(rng, (4, 4))
    idx = jnp.asarray([2, 4, 6, 7], dtype=jnp.int32)
    C = sch.run(A, B, idx)
    assert np.array_equal(np.asarray(C), np.asarray(ref_matmul(base, A, B)))


# --------------------------------------------------------------------- CSA


def test_gr_solve(rng):
    ring = make_ring(2, 16, (3,))
    n = 5
    # random invertible matrix: triangular with unit diagonal times another
    M = np.asarray(ring.random(rng, (n, n))).astype(np.uint32)
    for i in range(n):
        M[i, i, 0] |= 1  # make diagonal odd => unit
        for j in range(i + 1, n):
            M[i, j] = 0
    Mj = jnp.asarray(M)
    X = ring.random(rng, (n, 3))
    Y = ring.matmul(Mj, X)
    sol = gr_solve(ring, Mj, Y)
    assert np.array_equal(np.asarray(sol), np.asarray(X))


def test_csa_batch(rng):
    ring = make_ring(2, 16, (4,))  # |T| = 16 >= L + N = 3 + 8
    code = CSACode(ring, L=3, N=8)
    assert code.R == 5
    As = ring.random(rng, (3, 4, 4))
    Bs = ring.random(rng, (3, 4, 4))
    Cs = code.run(As, Bs)
    for i in range(3):
        assert np.array_equal(
            np.asarray(Cs[i]), np.asarray(ref_matmul(ring, As[i], Bs[i]))
        ), i


def test_csa_any_subset(rng):
    ring = make_ring(2, 16, (4,))
    code = CSACode(ring, L=2, N=6)  # R = 3
    As = ring.random(rng, (2, 2, 2))
    Bs = ring.random(rng, (2, 2, 2))
    FA, GB = code.encode_a(As), code.encode_b(Bs)
    H = code.worker_compute(FA, GB)
    expects = [np.asarray(ref_matmul(ring, As[i], Bs[i])) for i in range(2)]

    @jax.jit
    def dec(idx):
        return code.decode(jnp.take(H, idx, axis=0), idx)

    for subset in itertools.combinations(range(6), 3):
        Cs = dec(jnp.asarray(subset, dtype=jnp.int32))
        for i in range(2):
            assert np.array_equal(np.asarray(Cs[i]), expects[i]), subset


# --------------------------------------------------------------- stragglers


def test_select_workers():
    mask = jnp.asarray([True, False, True, True, False, True])
    idx = select_workers(mask, 4)
    assert list(np.asarray(idx)) == [0, 2, 3, 5]


def test_simulate_stragglers():
    key = jax.random.PRNGKey(0)
    mask, enough = simulate_stragglers(key, 16, fail_prob=0.3, min_live=9)
    assert int(jnp.sum(mask)) >= 9


def test_end_to_end_with_simulated_stragglers(rng):
    base = make_ring(2, 32, ())
    sch = BatchEPRMFE(base, n=2, N=8, u=2, v=2, w=1)
    As = base.random(rng, (2, 4, 4))
    Bs = base.random(rng, (2, 4, 4))

    @jax.jit
    def go(key, As, Bs):
        mask, _ = simulate_stragglers(key, 8, fail_prob=0.4, min_live=sch.R)
        idx = select_workers(mask, sch.R)
        FA, GB = sch.encode(As, Bs)
        H = sch.worker_compute(FA, GB)
        return sch.decode(jnp.take(H, idx, axis=0), idx)

    for seed in range(3):
        Cs = go(jax.random.PRNGKey(seed), As, Bs)
        for i in range(2):
            assert np.array_equal(
                np.asarray(Cs[i]), np.asarray(ref_matmul(base, As[i], Bs[i]))
            )


def test_eprmfe2_lite_paper_config(rng):
    """The exact §V experimental config: n=2, A embedded, B phi1-packed."""
    base = make_ring(2, 32, ())
    for N, (u, v, w) in [(8, (2, 2, 1)), (16, (2, 2, 2))]:
        sch = EPRMFE_II(base, n=2, N=N, u=u, v=v, w=w, split_a=False)
        assert sch.top.D in (3, 4)  # GR(2^32, 3) / GR(2^32, 4), as in the paper
        A = base.random(rng, (4, 8))
        B = base.random(rng, (8, 4))
        C = sch.run(A, B)
        assert np.array_equal(np.asarray(C), np.asarray(ref_matmul(base, A, B)))
