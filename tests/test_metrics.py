"""The live telemetry plane: metrics registry, health scores, HTTP
admin endpoints, and the hedged re-dispatch they drive.

Fast sections exercise the in-process pieces (instruments, EWMA health
scoring, the Prometheus exporter/parser pair, the stdlib HTTP server,
the settings knobs).  The ``slow``-marked section runs a real worker
pool and proves the hedging plane's correctness properties: duplicate
replies are discarded idempotently, hedged secure decodes stay
bit-identical to the local keyed oracle, and a SIGKILLed-then-hedged
worker still satisfies the pool-smoke oracle.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import json
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import parse_prometheus, to_prometheus
from repro.obs.health import DISPATCH_THRESHOLD, HealthTracker
from repro.obs.metrics import Counter, Gauge, MetricsRegistry, Series

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# --------------------------------------------------------------------------
# instruments
# --------------------------------------------------------------------------


def test_counter_accumulates_and_keeps_ints():
    c = Counter("requests")
    for _ in range(3):
        c.inc()
    c.inc(2)
    assert c.value == 5 and isinstance(c.value, int)
    c.inc(0.5)
    assert c.value == 5.5


def test_gauge_plain_and_labeled_snapshots():
    g = Gauge("mean_fill")
    g.set(3.5)
    assert g.snapshot_items() == {"mean_fill": 3.5}
    h = Gauge("worker_health", label="wid")
    h.set(1.0, key=0)
    h.set(0.25, key=3)
    assert h.snapshot_items() == {
        "worker_health_by_wid": {"0": 1.0, "3": 0.25}
    }
    h.clear_labels(keep=[3])
    assert h.snapshot_items() == {"worker_health_by_wid": {"3": 0.25}}
    with pytest.raises(ValueError):
        g.set(1.0, key=7)  # no label declared


def test_series_retention_capacity_quantile_and_clear():
    s = Series("rtt", retention_s=5.0)
    now = time.monotonic()
    s.add(1.0, t=now - 10.0)  # outside the window: pruned on next touch
    s.add(2.0, t=now)
    assert s.values() == [2.0]
    small = Series("rtt", retention_s=1e6, capacity=4)
    for v in range(6):
        small.add(float(v))
    assert len(small) == 4 and small.values() == [2.0, 3.0, 4.0, 5.0]
    assert small.quantile(0.0) == 2.0
    assert small.quantile(0.95) == 5.0
    small.clear()
    assert len(small) == 0 and small.quantile(0.5) is None


def test_registry_snapshot_prefixes_types_docs_and_extras():
    reg = MetricsRegistry("pool")
    reg.counter("requests", doc="requests accepted").inc(4)
    reg.gauge("oddness", doc="an unsuffixed gauge").set(4.2)
    reg.gauge("worker_health", label="wid").set(0.5, key=1)
    reg.histogram("wall_ms").observe(2.0)
    series = reg.series("share_ms")
    for v in range(10):
        series.add(float(v))
    assert reg.counter("requests") is reg.counter("requests")  # idempotent
    snap = reg.snapshot(extra={"derived": 7})
    assert snap["pool_requests"] == 4
    assert snap["pool_derived"] == 7
    assert snap["pool_worker_health_by_wid"] == {"1": 0.5}
    assert snap["pool_share_ms_window_count"] == 10
    assert snap["pool_share_ms_window_p50"] == 5.0
    assert snap._types["pool_requests"] == "counter"
    assert snap._types["pool_oddness"] == "gauge"
    assert "requests accepted" in snap._docs["pool_requests"]
    # the _types annotation overrides the exporter's suffix heuristic:
    # "oddness" has no gauge-ish suffix yet exports as a gauge
    text = to_prometheus(snap)
    assert "# TYPE repro_pool_oddness gauge" in text
    assert "# HELP repro_pool_requests requests accepted" in text
    parse_prometheus(text)  # and the whole exposition is strictly valid


# --------------------------------------------------------------------------
# exporter / parser (the satellite fixes: escaping, collisions, strictness)
# --------------------------------------------------------------------------


def test_prometheus_label_values_escape_and_roundtrip():
    weird = 'we"ird\\wid\nx'
    text = to_prometheus({"pool_worker_health_by_wid": {weird: 0.5}})
    fams = parse_prometheus(text)
    ((_, labels, value),) = fams["repro_pool_worker_health"]["samples"]
    assert labels["wid"] == weird and value == 0.5


def test_prometheus_collision_guard_keeps_first_key():
    # "wall.ms" and "wall_ms" both sanitize to repro_wall_ms; the first
    # (sorted) key wins and the exposition stays parseable
    text = to_prometheus({"wall.ms": 1, "wall_ms": 2})
    assert text.count("# TYPE repro_wall_ms ") == 1
    assert "collision" in text
    fams = parse_prometheus(text)
    assert [s[2] for s in fams["repro_wall_ms"]["samples"]] == [1.0]


def test_prometheus_histograms_are_cumulative():
    snap = {
        "pool_wall_ms_hist": {"<=1": 1, "<=5": 2, "inf": 3},
        "pool_wall_ms_sum": 12.5,
    }
    fams = parse_prometheus(to_prometheus(snap))
    fam = fams["repro_pool_wall_ms"]
    assert fam["type"] == "histogram"
    buckets = {
        labels["le"]: v for n, labels, v in fam["samples"]
        if n.endswith("_bucket")
    }
    assert buckets == {"1": 1.0, "5": 3.0, "+Inf": 6.0}
    by_name = {n: v for n, labels, v in fam["samples"] if not labels}
    assert by_name["repro_pool_wall_ms_sum"] == 12.5
    assert by_name["repro_pool_wall_ms_count"] == 6.0


@pytest.mark.parametrize("bad", [
    'dup 1\ndup 2\n',                                    # duplicate sample
    '# TYPE h histogram\nh_bucket{le="1"} 1\nh_count 1\n',  # no +Inf
    '# TYPE h histogram\nh_bucket{le="1"} 5\n'
    'h_bucket{le="+Inf"} 3\n',                           # not cumulative
    '# TYPE h histogram\nh_bucket{le="+Inf"} 3\nh_count 4\n',  # count drift
    'metric{l="unterminated} 1\n',                       # bad label block
    'metric nope\n',                                     # unparsable value
])
def test_parse_prometheus_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_prometheus(bad)


# --------------------------------------------------------------------------
# health scoring + hedge deadline
# --------------------------------------------------------------------------


def test_health_scores_normalize_rtt_against_pool_median():
    ht = HealthTracker()
    for _ in range(5):
        ht.record_share(0, 10.0)
        ht.record_share(1, 100.0)
    s = ht.scores()
    assert s[0] == 1.0  # at/below the median: healthy
    assert s[1] == pytest.approx(55.0 / 100.0)
    assert s[1] > 0 and s[1] > DISPATCH_THRESHOLD


def test_health_heartbeat_jitter_lowers_score():
    ht = HealthTracker(alpha=0.5)
    t = 100.0
    for k in range(12):  # perfectly steady 0.5 s heartbeats
        ht.record_heartbeat(0, t=t + 0.5 * k)
    stutter = 100.0
    for k in range(12):  # alternating 0.1 / 0.9 s inter-arrivals
        stutter += 0.1 if k % 2 else 0.9
        ht.record_heartbeat(1, t=stutter)
    s = ht.scores()
    assert s[0] == 1.0
    assert s[1] < s[0]


def test_health_reset_scores_keeps_share_window():
    ht = HealthTracker()
    for _ in range(10):
        ht.record_share(0, 10.0)
    assert ht.scores()
    ht.reset_scores()
    assert ht.scores() == {}
    assert ht.score(0) == 1.0  # innocent until measured again
    assert len(ht.share_ms) == 10  # the pooled window survives


def test_hedge_deadline_gating_and_floor():
    ht = HealthTracker(min_hedge_samples=8)
    assert ht.hedge_deadline_ms(2.0) is None  # no evidence
    for _ in range(7):
        ht.record_share(0, 10.0)
    assert ht.hedge_deadline_ms(2.0) is None  # under min samples
    ht.record_share(0, 10.0)
    time.sleep(0.06)  # past the deadline quantile's staleness TTL
    assert ht.hedge_deadline_ms(0.0) is None  # hedging off
    assert ht.hedge_deadline_ms(2.0) == pytest.approx(20.0)
    ht.clear_window()
    assert ht.hedge_deadline_ms(2.0) is None  # window (and cache) gone
    for _ in range(8):
        ht.record_share(0, 1e-4)
    time.sleep(0.06)
    assert ht.hedge_deadline_ms(2.0) == 1.0  # min_ms floor


# --------------------------------------------------------------------------
# settings knobs + HTTP plane
# --------------------------------------------------------------------------


def test_settings_cli_lists_telemetry_knobs():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.settings"],
        capture_output=True, text=True, env=env, check=True,
    ).stdout
    for knob in ("REPRO_OBS_HTTP_PORT", "REPRO_HEDGE_FACTOR",
                 "REPRO_HEALTH_EWMA", "REPRO_OBS_RETENTION"):
        assert knob in out, f"{knob} missing from settings listing"


def _get(url: str):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.read().decode()


def test_http_endpoints_serve_registered_sources():
    from repro import obs
    from repro.obs import http as obs_http

    reg = MetricsRegistry("unit")
    reg.counter("requests").inc(3)
    reg.gauge("workers_live").set(2)
    name = obs_http.register_source("unit", reg.snapshot)
    dup = obs_http.register_source("unit", reg.snapshot)
    assert dup == "unit#2"  # second registrant deduplicates, both scrape
    obs_http.unregister_source(dup)

    obs.set_enabled(True)
    ctx = obs.TraceContext.new("unit")
    t0 = obs.now()
    obs.tracer().add(ctx, "compute", "worker", t0, obs.now(), wid=0)
    timeline = obs.tracer().timeline(ctx.trace_id)

    def resolver(key):
        return timeline if key == "42" else None

    obs_http.register_trace_resolver(resolver)
    srv = obs_http.start_server(port=0)
    try:
        assert obs_http.start_server(port=0) is srv  # process singleton
        fams = parse_prometheus(_get(f"{srv.url}/metrics"))
        assert "repro_unit_requests" in fams
        healthz = json.loads(_get(f"{srv.url}/healthz"))
        assert healthz["ok"] and name in healthz["sources"]
        stats = json.loads(_get(f"{srv.url}/stats"))
        assert stats["unit_requests"] == 3
        doc = json.loads(_get(f"{srv.url}/trace/42"))
        assert doc["spans"] and doc["spans"][0]["name"] == "compute"
        chrome = json.loads(_get(f"{srv.url}/trace/42?format=chrome"))
        assert any(e.get("ph") == "X" for e in chrome["traceEvents"])
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{srv.url}/trace/no-such-request")
        assert ei.value.code == 404
    finally:
        obs.set_enabled(None)
        obs_http.stop_server()
        obs_http.unregister_source(name)
        obs_http.unregister_trace_resolver(resolver)


def test_top_renders_rates_and_worker_table():
    from repro.obs import top

    snap0 = {
        "pool_requests": 100, "pool_workers_live": 2, "pool_hedged": 1,
        "pool_worker_health_by_wid": {"0": 1.0, "1": 0.25},
        "pool_worker_tasks_done_by_wid": {"0": 9, "1": 3},
    }
    first = top.render(snap0, prev=None, now=1000.0)
    assert "req/s -" in first  # no rate on the first frame
    snap1 = dict(snap0, pool_requests=150)
    frame = top.render(snap1, prev=(1000.0, snap0), now=1010.0)
    assert "req/s 5.0" in frame
    assert "hedged=1" in frame
    lines = [ln for ln in frame.splitlines() if ln.strip().startswith(("0", "1"))]
    assert len(lines) == 2 and "#" in lines[0]


# --------------------------------------------------------------------------
# hedging correctness against a real pool (slow: worker OS processes)
# --------------------------------------------------------------------------

POOL_WORKERS = 4
SIZE = 32


def _zero_slack(workers: int, size: int = SIZE):
    from repro.cdmm import ProblemSpec, coded_matmul, plan
    from repro.core import make_ring

    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(
        t=size, r=size, s=size, n=1, ring=Z32, N=workers,
        straggler_budget=0,
    )
    p = plan(spec, objective="threshold")
    rank = max(range(len(p.candidates)),
               key=lambda i: p.candidates[i].costs.R)
    scheme = p.instantiate(rank)
    assert scheme.R == scheme.N == workers
    rng = np.random.default_rng(0)
    A = Z32.random(rng, (size, size))
    B = Z32.random(rng, (size, size))
    oracle = np.asarray(coded_matmul(A, B, scheme, backend="local"))
    return scheme, A, B, oracle


def _warm_and_seed(master, scheme, A, B):
    """Jit-warm the workers, then purge the compile-era round-trips and
    re-seed the hedge window with steady-state samples (>= 8 needed)."""
    master.hedge_factor = 0.0
    for _ in range(3):
        master.execute(scheme, A, B)
    master.health.clear_window()
    for _ in range(2):
        master.execute(scheme, A, B)


@pytest.fixture(scope="module")
def hedge_pool():
    from repro.dist import LocalPool

    with LocalPool(workers=POOL_WORKERS) as p:
        scheme, A, B, oracle = _zero_slack(POOL_WORKERS)
        _warm_and_seed(p.master, scheme, A, B)
        yield p, scheme, A, B, oracle


@pytest.mark.slow
def test_aggressive_hedging_discards_duplicates_idempotently(hedge_pool):
    """Every worker parked + an aggressive factor: every share hedges,
    and both replies (original + replica) eventually arrive for every
    share.  Each decode must stay bit-identical and the master must come
    out clean — the duplicate-discard paths ran dozens of times."""
    pool, scheme, A, B, oracle = hedge_pool
    master = pool.master
    before = master.stats()
    try:
        for _ in range(3):
            # each race poisons the share window with parked round-trips
            # (they dwarf the park of the NEXT race), so re-seed per race
            _warm_and_seed(master, scheme, A, B)
            for wid in master.live_workers():
                master.task_delay_ms[wid] = 150.0
            master.hedge_factor = 1.05
            C, st = master.execute(scheme, A, B)
            master.hedge_factor = 0.0
            master.task_delay_ms.clear()
            np.testing.assert_array_equal(np.asarray(C), oracle)
            assert st.hedged >= 1
    finally:
        master.hedge_factor = 0.0
        master.task_delay_ms.clear()
    time.sleep(0.8)  # let every late twin land and be discarded
    after = master.stats()
    assert after["pool_hedged"] >= before["pool_hedged"] + 3
    assert after["pool_hedge_wasted"] >= 0
    # the pool is not poisoned: a clean request still decodes exactly
    C, st = master.execute(scheme, A, B)
    np.testing.assert_array_equal(np.asarray(C), oracle)
    assert st.hedged == 0
    master.health.clear_window()
    _warm_and_seed(master, scheme, A, B)  # re-seed for the next test


@pytest.mark.slow
def test_hedged_secure_decode_bit_identical_under_fixed_key(hedge_pool):
    """Secure scheme, fixed key, every worker parked so shares hedge:
    the replica re-ships the SAME keyed encoding, so the decode must
    equal the local keyed oracle bit for bit despite duplicate replies
    taking different worker paths."""
    import jax

    from repro.cdmm import ProblemSpec, coded_matmul, plan
    from repro.core import make_ring
    from repro.dist import PoolBackend

    pool = hedge_pool[0]
    master = pool.master
    Z32 = make_ring(2, 32, ())
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=8, privacy_t=1)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(2)
    A = Z32.random(rng, (8, 8))
    B = Z32.random(rng, (8, 8))
    key = jax.random.PRNGKey(7)
    be = PoolBackend(pool)
    C_local = np.asarray(coded_matmul(A, B, scheme, backend="local", key=key))
    # unhedged pool run (also jit-warms the 8x8 keyed path), then the
    # hedged run with every worker parked past the deadline
    C_plain = np.asarray(coded_matmul(A, B, scheme, backend=be, key=key))
    np.testing.assert_array_equal(C_plain, C_local)
    # that first pool run compiled the keyed path worker-side; purge its
    # round-trips and re-seed so the hedge deadline arms at steady state
    master.health.clear_window()
    for _ in range(2):
        np.testing.assert_array_equal(
            np.asarray(coded_matmul(A, B, scheme, backend=be, key=key)),
            C_local,
        )
    for wid in master.live_workers():
        master.task_delay_ms[wid] = 150.0
    try:
        master.hedge_factor = 1.05
        C_hedged = np.asarray(
            coded_matmul(A, B, scheme, backend=be, key=key)
        )
    finally:
        master.hedge_factor = 0.0
        master.task_delay_ms.clear()
    np.testing.assert_array_equal(C_hedged, C_local)
    assert be.last_stats.hedged >= 1


@pytest.mark.slow
def test_sigkilled_then_hedged_worker_still_satisfies_oracle():
    """A worker is SIGKILLed after its share was already speculatively
    hedged: the replica (or the death re-dispatch, whichever lands
    first) must complete the zero-slack decode bit-identically."""
    from repro.dist import LocalPool

    with LocalPool(workers=POOL_WORKERS, heartbeat_s=0.5,
                   heartbeat_timeout=30.0) as fresh:
        scheme, A, B, oracle = _zero_slack(POOL_WORKERS)
        master = fresh.master
        _warm_and_seed(master, scheme, A, B)
        for wid in master.live_workers():
            master.task_delay_ms[wid] = 400.0
        result = {}

        def _request():
            try:
                C, result["stats"] = master.execute(scheme, A, B)
                result["C"] = np.asarray(C)
            except Exception as e:  # surfaced below
                result["err"] = e

        master.hedge_factor = 2.0
        t = threading.Thread(target=_request)
        t.start()
        time.sleep(0.15)  # shares dispatched; overdue shares hedged
        assert len(fresh.kill(1)) == 1
        t.join(timeout=120)
        master.hedge_factor = 0.0
        master.task_delay_ms.clear()
        assert not t.is_alive(), "request hung after SIGKILL"
        assert "err" not in result, f"request failed: {result.get('err')!r}"
        np.testing.assert_array_equal(result["C"], oracle)
        assert result["stats"].hedged >= 1
        # the hedge plane resolved the race long before the 30 s
        # heartbeat deadline could have
        assert result["stats"].wall_ms < 20_000
        assert fresh.alive_count() == POOL_WORKERS - 1
