"""tools/check_bench.py: baseline diffing and the rolling-history gate."""
import importlib.util
import json
from pathlib import Path

_spec = importlib.util.spec_from_file_location(
    "check_bench",
    Path(__file__).resolve().parents[1] / "tools" / "check_bench.py",
)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def test_compare_flags_regressions_and_improvements():
    baseline = {"a": 100.0, "b": 100.0, "c": 100.0, "gone": 5.0,
                "analytic": 0.0}
    current = {"a": 130.0, "b": 70.0, "c": 101.0, "new": 9.0,
               "analytic": 0.0}
    reg, imp, skip = check_bench.compare(baseline, current, 0.25)
    assert [r[0] for r in reg] == ["a"]
    assert [i[0] for i in imp] == ["b"]
    skipped_names = {s[0] for s in skip}
    assert {"gone", "analytic", "new"} <= skipped_names


def test_rolling_reference_median_needs_two_samples():
    history = [
        {"sha": "s1", "rows": {"a": 100.0, "b": 50.0}},
        {"sha": "s2", "rows": {"a": 120.0}},
        {"sha": "s3", "rows": {"a": 80.0}},
    ]
    ref = check_bench.rolling_reference(history, window=5)
    assert ref == {"a": 100.0}  # median of [80, 100, 120]; b has 1 sample
    # the window counts samples per row from the newest end
    ref2 = check_bench.rolling_reference(history, window=2)
    assert ref2 == {"a": 100.0}  # median of [80, 120]


def test_rolling_reference_survives_withheld_recent_entries():
    """A row withheld from every recent entry (persistent regression) must
    keep its last-known-good reference: samples are gathered per row
    across the retained history, not just the last `window` entries."""
    history = (
        [{"sha": "g1", "rows": {"x": 100.0}},
         {"sha": "g2", "rows": {"x": 104.0}}]
        + [{"sha": f"w{i}", "rows": {}} for i in range(10)]  # x withheld
    )
    ref = check_bench.rolling_reference(history, window=5)
    assert ref == {"x": 102.0}  # the regression stays gated


def test_history_append_replaces_rerun_and_caps(tmp_path):
    path = tmp_path / "hist.json"
    history = [{"sha": f"s{i}", "rows": {"a": float(i)}} for i in range(3)]
    check_bench.append_history(history, "s1", {"a": 99.0}, str(path))
    out = json.loads(path.read_text())
    assert [e["sha"] for e in out] == ["s0", "s2", "s1"]  # s1 re-run moved
    assert out[-1]["rows"] == {"a": 99.0}

    big = [{"sha": f"c{i}", "rows": {}} for i in range(200)]
    check_bench.append_history(big, "tip", {}, str(path))
    out = json.loads(path.read_text())
    assert len(out) == check_bench.HISTORY_MAX_ENTRIES
    assert out[-1]["sha"] == "tip"


def test_load_history_tolerates_missing_and_corrupt(tmp_path):
    assert check_bench.load_history(str(tmp_path / "none.json")) == []
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert check_bench.load_history(str(bad)) == []
    notalist = tmp_path / "obj.json"
    notalist.write_text('{"sha": "x"}')
    assert check_bench.load_history(str(notalist)) == []


def test_end_to_end_gate_with_history(tmp_path, monkeypatch, capsys):
    """A row that regresses only against the rolling window (the committed
    baseline is stale-slow) must still fail the gate."""
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "current.json"
    hist = tmp_path / "hist.json"
    # baseline recorded on a slow machine: 1000us; recent runs: ~100us
    baseline.write_text(json.dumps([{"name": "x", "us": 1000.0,
                                     "derived": {}}]))
    current.write_text(json.dumps([{"name": "x", "us": 300.0,
                                    "derived": {}}]))
    hist.write_text(json.dumps([
        {"sha": "a", "rows": {"x": 100.0}},
        {"sha": "b", "rows": {"x": 110.0}},
    ]))
    monkeypatch.setattr("sys.argv", [
        "check_bench.py", "--baseline", str(baseline), "--current",
        str(current), "--history", str(hist), "--commit", "deadbeef",
    ])
    rc = check_bench.main()
    out = capsys.readouterr().out
    assert rc == 1
    assert "REGRESSION[rolling] x" in out
    # the run was still appended so the chain keeps moving — but the
    # rolling-regressed row is withheld, so the rolling median cannot
    # ratchet toward the regression and disarm the gate
    entry = json.loads(hist.read_text())[-1]
    assert entry["sha"] == "deadbeef"
    assert "x" not in entry["rows"]


def test_baseline_only_regression_still_feeds_history(tmp_path, monkeypatch,
                                                      capsys):
    """A row slower than the machine-specific committed baseline but in
    line with recent runs must keep flowing into the rolling history —
    otherwise a slower runner class could never build a usable window."""
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "current.json"
    hist = tmp_path / "hist.json"
    baseline.write_text(json.dumps([{"name": "x", "us": 100.0,
                                     "derived": {}}]))
    current.write_text(json.dumps([{"name": "x", "us": 300.0,
                                    "derived": {}}]))  # 3x the baseline...
    hist.write_text(json.dumps([
        {"sha": "a", "rows": {"x": 290.0}},  # ...but normal for this runner
        {"sha": "b", "rows": {"x": 310.0}},
    ]))
    monkeypatch.setattr("sys.argv", [
        "check_bench.py", "--baseline", str(baseline), "--current",
        str(current), "--history", str(hist), "--commit", "cafe",
    ])
    rc = check_bench.main()
    out = capsys.readouterr().out
    assert rc == 1  # baseline gate still fires (advisory job surfaces it)
    assert "REGRESSION[baseline] x" in out
    assert "REGRESSION[rolling]" not in out
    entry = json.loads(hist.read_text())[-1]
    assert entry["sha"] == "cafe" and entry["rows"] == {"x": 300.0}
