"""Unit tests for the Galois ring core (host + jnp paths)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.galois import (
    Ring,
    make_ring,
    find_irreducible_gfp,
    is_irreducible_gfp,
    _poly_mulmod,
)

RINGS = [
    make_ring(2, 32, ()),          # Z_{2^32}
    make_ring(2, 32, (3,)),        # GR(2^32, 3)
    make_ring(2, 8, (4,)),         # GR(2^8, 4)
    make_ring(2, 32, (3, 5)),      # tower GR(2^32, 15)
    make_ring(3, 2, (2,)),         # GR(9, 2), odd p general path
    make_ring(5, 1, (3,)),         # GF(125): e=1 field case
]


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def test_find_irreducible():
    for p, d in [(2, 3), (2, 8), (3, 4), (5, 2), (2, 15)]:
        f = np.array(find_irreducible_gfp(p, d), dtype=np.int64)
        assert len(f) == d + 1 and f[-1] == 1
        assert is_irreducible_gfp(f, p)


def test_reducible_detected():
    # x^2 over GF(2) is reducible; x^2+1 = (x+1)^2 over GF(2) reducible
    assert not is_irreducible_gfp(np.array([0, 0, 1], dtype=np.int64), 2)
    assert not is_irreducible_gfp(np.array([1, 0, 1], dtype=np.int64), 2)
    # x^2+1 irreducible over GF(3)
    assert is_irreducible_gfp(np.array([1, 0, 1], dtype=np.int64), 3)


@pytest.mark.parametrize("ring", RINGS, ids=repr)
def test_ring_axioms_host(ring, rng):
    for _ in range(10):
        a = np.array(rng.integers(0, ring.q, ring.D), dtype=object)
        b = np.array(rng.integers(0, ring.q, ring.D), dtype=object)
        c = np.array(rng.integers(0, ring.q, ring.D), dtype=object)
        ab = ring.s_mul(a, b)
        ba = ring.s_mul(b, a)
        assert np.array_equal(ab, ba)
        assert np.array_equal(ring.s_mul(ab, c), ring.s_mul(a, ring.s_mul(b, c)))
        lhs = ring.s_mul(a, ring.s_add(b, c))
        rhs = ring.s_add(ring.s_mul(a, b), ring.s_mul(a, c))
        assert np.array_equal(lhs, rhs)
        assert np.array_equal(ring.s_mul(a, ring.s_one()), a % ring.q)


@pytest.mark.parametrize("ring", RINGS, ids=repr)
def test_jnp_matches_host_mul(ring, rng):
    a = ring.random(rng, (4, 3))
    b = ring.random(rng, (4, 3))
    out = np.asarray(ring.mul(a, b))
    an, bn = np.asarray(a), np.asarray(b)
    for i in range(4):
        for j in range(3):
            expect = ring.s_mul(
                an[i, j].astype(object), bn[i, j].astype(object)
            ).astype(np.uint64) % ring.q
            assert np.array_equal(out[i, j].astype(np.uint64), expect), (i, j)


@pytest.mark.parametrize("ring", RINGS, ids=repr)
def test_jnp_matmul_matches_host(ring, rng):
    t, r, s = 3, 4, 2
    A = ring.random(rng, (t, r))
    B = ring.random(rng, (r, s))
    C = np.asarray(ring.matmul(A, B)).astype(object)
    Ch = ring.s_matmul(np.asarray(A).astype(object), np.asarray(B).astype(object))
    assert np.array_equal(C % ring.q, Ch % ring.q)


def test_field_case_matches_poly_mulmod(rng):
    """For e=1 single-level rings, ring mult == GF(p)[x] mulmod (independent path)."""
    ring = make_ring(5, 1, (3,))
    f = np.array(ring.moduli[0], dtype=np.int64)
    for _ in range(20):
        a = rng.integers(0, 5, 3).astype(np.int64)
        b = rng.integers(0, 5, 3).astype(np.int64)
        expect = _poly_mulmod(a, b, f, 5)
        got = ring.s_mul(a.astype(object), b.astype(object)).astype(np.int64)
        assert np.array_equal(got, expect)


@pytest.mark.parametrize("ring", RINGS, ids=repr)
def test_inverse_host_and_jnp(ring, rng):
    a = ring.random_units(rng, (5,))
    ah = np.asarray(a).astype(object)
    one = ring.s_one()
    for i in range(5):
        inv = ring.s_inv(ah[i])
        assert np.array_equal(ring.s_mul(ah[i], inv), one)
    inv_j = ring.inv(a)
    prod = np.asarray(ring.mul(a, inv_j)).astype(np.uint64)
    expect = np.zeros((5, ring.D), dtype=np.uint64)
    expect[:, 0] = 1
    assert np.array_equal(prod % ring.q, expect)


@pytest.mark.parametrize("ring", RINGS, ids=repr)
def test_exceptional_points(ring):
    n = min(16, ring.p ** ring.D)
    pts = ring.exceptional_points(n)
    assert pts.shape == (n, ring.D)
    # all pairwise differences must be units (inverse exists)
    for i in range(n):
        for j in range(i):
            d = ring.s_sub(pts[i].astype(object), pts[j].astype(object))
            inv = ring.s_inv(d)  # raises if not a unit
            assert np.array_equal(ring.s_mul(d, inv), ring.s_one())


def test_exceptional_points_exhausted():
    ring = make_ring(2, 32, ())
    with pytest.raises(ValueError):
        ring.exceptional_points(3)  # |T| = 2 for Z_{2^e}


def test_embed_base_is_ring_hom(rng):
    base = make_ring(2, 32, (3,))
    ext = base.extend(4)
    assert ext.degrees == (3, 4)
    a = base.random(rng, (4,))
    b = base.random(rng, (4,))
    ea, eb = ext.embed_base(a, base), ext.embed_base(b, base)
    lhs = ext.mul(ea, eb)
    rhs = ext.embed_base(base.mul(a, b), base)
    assert np.array_equal(np.asarray(lhs), np.asarray(rhs))


def test_extend_coprime_adjustment():
    base = make_ring(2, 32, (3,))
    ext = base.extend(3)  # gcd(3,3)!=1 -> bumps to 4
    assert ext.degrees == (3, 4)
    ext2 = base.extend(5)
    assert ext2.degrees == (3, 5)


def test_tower_coeffs_roundtrip(rng):
    base = make_ring(2, 16, (3,))
    ext = base.extend(5)
    a = ext.random(rng, (2, 2))
    c = ext.tower_coeffs(a, base)
    assert c.shape == (2, 2, 5, 3)
    back = ext.from_tower_coeffs(c)
    assert np.array_equal(np.asarray(a), np.asarray(back))


def test_pow_scalar(rng):
    ring = make_ring(2, 32, (3,))
    a = ring.random(rng, (3,))
    a3 = ring.pow(a, 3)
    expect = ring.mul(ring.mul(a, a), a)
    assert np.array_equal(np.asarray(a3), np.asarray(expect))


def test_scale_and_sub(rng):
    ring = make_ring(3, 2, (2,))
    a = ring.random(rng, (4,))
    z = ring.sub(a, a)
    assert np.all(np.asarray(z) == 0)
    s = ring.scale(a, ring.q - 1)  # == -a
    assert np.array_equal(np.asarray(ring.add(s, a)), np.zeros_like(np.asarray(a)))


def test_jit_traceable(rng):
    ring = make_ring(2, 32, (3,))

    @jax.jit
    def f(a, b):
        return ring.matmul(a, b)

    A = ring.random(rng, (4, 4))
    B = ring.random(rng, (4, 4))
    out = f(A, B)
    assert np.array_equal(np.asarray(out), np.asarray(ring.matmul(A, B)))

    @jax.jit
    def g(a):
        return ring.inv(a)

    a = ring.random_units(rng, (3,))
    assert np.array_equal(np.asarray(g(a)), np.asarray(ring.inv(a)))
