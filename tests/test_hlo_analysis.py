"""Validate the trip-aware collective-bytes parser against known programs."""
import os

import numpy as np
import pytest

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import shard_map  # noqa: E402
from repro.launch.hlo_analysis import _shape_bytes, collective_bytes  # noqa: E402

NDEV = len(jax.devices())
needs8 = pytest.mark.skipif(NDEV < 8, reason="needs 8 devices")


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[16,16] blah u32[4]") == 16 * 16 * 2 + 16
    assert _shape_bytes("(f32[8], s8[8])") == 32 + 8
    assert _shape_bytes("pred[]") == 1


@needs8
def test_collectives_simple_psum():
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(8), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    m = jax.jit(
        shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P(), check=False)
    )
    text = m.lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    coll = collective_bytes(text)
    # one all-reduce of the local (1,128) f32 block -> 512 bytes
    assert coll["all-reduce"] >= 512
    assert coll["count"] >= 1


@needs8
def test_collectives_inside_scan_multiplied():
    """A psum inside a 10-trip scan must be charged 10x."""
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(8), ("x",))
    TRIPS = 10

    def f(a):
        def body(c, _):
            return c + jax.lax.psum(a, "x"), None

        out, _ = jax.lax.scan(body, jnp.zeros_like(a), None, length=TRIPS)
        return out

    m = jax.jit(
        shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P(), check=False)
    )
    text = m.lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    coll = collective_bytes(text)
    # scan body all-reduce: 128 f32 = 512B, x10 trips (XLA may hoist the
    # loop-invariant psum — accept either exactly 1x or the full 10x)
    assert coll["all-reduce"] in (512, 512 * TRIPS), coll

    def g(a):
        def body(c, x):
            return c + jax.lax.psum(x * c, "x"), None

        out, _ = jax.lax.scan(
            body, jnp.ones_like(a), jnp.ones((TRIPS,) + a.shape)
        )
        return out

    m2 = jax.jit(
        shard_map(g, mesh=mesh, in_specs=P("x"), out_specs=P(), check=False)
    )
    text2 = m2.lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    coll2 = collective_bytes(text2)
    # loop-carried dependence: cannot be hoisted -> must be multiplied by 10
    assert coll2["all-reduce"] == 512 * TRIPS, coll2
