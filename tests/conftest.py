"""Suite-wide fixtures.

The one below works around a native crash in the pinned jaxlib: once a
single CPU-client process has accumulated roughly 125 live compiled
programs, the next XLA ``backend_compile`` segfaults (no Python
traceback; faulthandler shows the main thread inside
``jax/_src/compiler.py:backend_compile``).  The full suite compiles well
past that across its ~20 modules, so whichever compile-heavy test file
runs around the threshold took the whole session down — historically
``test_conformance.py``'s sweeps (see the quarantine note there), but
the crash site just moves when any one test is isolated.  Dropping every
jit/pjit cache at module boundaries releases the finished modules'
executables and keeps the live-program count bounded for the whole run,
at the cost of re-tracing shared helpers in later modules.
"""
import pytest


def pytest_configure(config):
    # no pytest.ini/pyproject in this repo, so the marker registers here.
    # `tools/ci.sh --fast` deselects `slow` (the inline dist/serve smokes)
    # to keep a sub-5-minute local gate; bare `python -m pytest -x -q`
    # remains the full tier-1 run.
    config.addinivalue_line(
        "markers", "slow: multi-process / serving smokes skipped by ci.sh --fast"
    )


@pytest.fixture(autouse=True, scope="module")
def _bound_live_xla_programs():
    yield
    import jax

    jax.clear_caches()
