"""End-to-end behaviour tests for the paper's system.

The full paper pipeline in one test: machine-word matrices -> RMFE packing
-> EP-coded distribution -> worker failures -> exact recovery -> unpacking,
plus the serving integration (coded quantized matmul) and the cost-model
claims (Thm III.2 / Table 1).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    BatchEPRMFE,
    EPRMFE_I,
    PlainCDMM,
    gcsa_cost_model,
    make_ring,
    select_workers,
    simulate_stragglers,
)
from repro.cdmm import CodedQuantMatmul


def test_paper_pipeline_end_to_end():
    """Fig. 1 framework over Z_{2^32} with random failures, exact recovery."""
    Z32 = make_ring(2, 32, ())
    sch = BatchEPRMFE(Z32, n=2, N=8, u=2, v=2, w=1)  # paper's 8-worker regime
    assert sch.ext.D == 3 and sch.R == 4  # GR(2^32,3), R=4 — §V setup
    rng = np.random.default_rng(0)
    As = Z32.random(rng, (2, 32, 32))
    Bs = Z32.random(rng, (2, 32, 32))

    @jax.jit
    def serve(key, As, Bs):
        mask, _ = simulate_stragglers(key, 8, fail_prob=0.45, min_live=sch.R)
        idx = select_workers(mask, sch.R)
        FA, GB = sch.encode(As, Bs)
        H = sch.worker_compute(FA, GB)
        return sch.decode(jnp.take(H, idx, axis=0), idx), mask

    for seed in range(4):
        Cs, mask = serve(jax.random.PRNGKey(seed), As, Bs)
        assert int(jnp.sum(mask)) >= sch.R
        for i in range(2):
            np.testing.assert_array_equal(
                np.asarray(Cs[i]), np.asarray(Z32.matmul(As[i], Bs[i]))
            )


def test_amortization_beats_plain_embedding():
    """Thm III.2: Batch-EP_RMFE amortized costs ~1/m of plain CDMM."""
    Z32 = make_ring(2, 32, ())
    plain = PlainCDMM(Z32, N=8, u=2, v=2, w=1)
    batch = BatchEPRMFE(Z32, n=2, N=8, u=2, v=2, w=1)
    cp = plain.costs(256, 256, 256)
    cb = batch.costs(256, 256, 256)
    assert cb.upload < cp.upload  # amortized by n
    assert cb.worker_ops < cp.worker_ops
    assert cb.R == cp.R  # same recovery threshold


def test_threshold_vs_gcsa_table1():
    Z32 = make_ring(2, 32, ())
    for n in (2, 4):
        sch = BatchEPRMFE(Z32, n=n, N=64, u=2, v=2, w=2)
        g = gcsa_cost_model(64, 64, 64, 2, 2, 2, n, n, 64, m_eff=6)
        assert g.R / sch.R >= n  # >= n x smaller threshold at kappa = n


def test_coded_serving_bit_exact_under_failures():
    """The serving-plane integration: int8 matmul, 4/8 workers dead, zero drift."""
    cm = CodedQuantMatmul(N=8, axis_name=None)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((16, 64)).astype(np.float32)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    ref = np.asarray(cm(jnp.asarray(x), jnp.asarray(w), mask=None))
    mask = np.ones(8, bool)
    mask[[1, 2, 5, 7]] = False
    out = np.asarray(cm(jnp.asarray(x), jnp.asarray(w), mask=jnp.asarray(mask)))
    np.testing.assert_array_equal(out, ref)


def test_single_dmm_type1_splits_work():
    """EP_RMFE-I computes a single product via the batch framework."""
    Z16 = make_ring(2, 16, ())
    sch = EPRMFE_I(Z16, n=2, N=8, u=2, v=2, w=1)
    rng = np.random.default_rng(2)
    A = Z16.random(rng, (8, 16))
    B = Z16.random(rng, (16, 8))
    C = sch.run(A, B, idx=jnp.asarray([1, 3, 4, 6], jnp.int32))
    np.testing.assert_array_equal(np.asarray(C), np.asarray(Z16.matmul(A, B)))
