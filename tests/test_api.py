"""Unified CDMM API tests: registry conformance, planner ranking, backends.

Conformance: every registered scheme family, driven purely through the
shared surface (encode_a -> worker_compute -> decode on a random any-R
worker subset), must reproduce the plain data-ring matmul bit-exactly.
"""
import os

import numpy as np
import pytest

# must happen before jax initializes its backends (ShardMapBackend test)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import CSACode, make_ring  # noqa: E402
from repro.cdmm import (  # noqa: E402
    LocalSimBackend,
    ProblemSpec,
    ShardMapBackend,
    coded_matmul,
    get_scheme,
    plan,
    registered_schemes,
)

Z32 = make_ring(2, 32, ())
NDEV = len(jax.devices())
needs8 = pytest.mark.skipif(NDEV < 8, reason=f"needs 8 devices, have {NDEV}")
KEY = jax.random.PRNGKey(0)  # keyed-encode seam (required by secure schemes)

# one feasible configuration per registered family:
# (name, spec, (u, v, w), packing n)
CONFORMANCE_CASES = [
    ("ep", ProblemSpec(8, 8, 8, n=1, ring=make_ring(2, 32, (3,)), N=8), (2, 2, 1), 1),
    ("plain", ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8), (2, 2, 1), 1),
    ("ep_rmfe1", ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8), (2, 2, 1), 2),
    ("ep_rmfe2", ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8), (2, 2, 1), 2),
    ("batch_ep_rmfe", ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8), (2, 2, 1), 2),
    ("gcsa", ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8), (1, 1, 1), 2),
    # gcsa_general's packing slot carries kappa: (2,2,1,kappa=1) -> R = 8
    ("gcsa_general", ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8), (2, 2, 1), 1),
    ("ep_secure",
     ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8, privacy_t=1), (1, 2, 1), 1),
    ("ep_rmfe_secure",
     ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8, privacy_t=1), (1, 1, 1), 2),
]


def _random_inputs(scheme, spec, rng):
    base = scheme.base
    if scheme.batch > 1:
        A = base.random(rng, (scheme.batch, spec.t, spec.r))
        B = base.random(rng, (scheme.batch, spec.r, spec.s))
    else:
        A = base.random(rng, (spec.t, spec.r))
        B = base.random(rng, (spec.r, spec.s))
    return A, B


def _reference(scheme, A, B):
    base = scheme.base
    if scheme.batch > 1:
        return jnp.stack([base.matmul(A[i], B[i]) for i in range(scheme.batch)])
    return base.matmul(A, B)


def test_every_family_has_a_conformance_case():
    assert sorted(registered_schemes()) == sorted(c[0] for c in CONFORMANCE_CASES)


@pytest.mark.parametrize("name,spec,uvw,n", CONFORMANCE_CASES,
                         ids=[c[0] for c in CONFORMANCE_CASES])
def test_scheme_conformance_any_R_subset(name, spec, uvw, n):
    """encode -> worker -> decode on random any-R subsets == plain matmul."""
    fam = get_scheme(name)
    u, v, w = uvw
    assert fam.predict(spec, u, v, w, n) is not None, "case must be feasible"
    scheme = fam.build(spec, u, v, w, n)
    assert scheme.name == name and scheme.N == spec.N
    assert 1 <= scheme.R <= spec.N

    rng = np.random.default_rng(7)
    A, B = _random_inputs(scheme, spec, rng)
    expect = np.asarray(_reference(scheme, A, B))

    # the keyed-encode seam: secure schemes consume the key, the rest must
    # tolerate (and ignore) it
    FA, GB = scheme.encode_a(A, key=KEY), scheme.encode_b(B, key=KEY)
    assert FA.shape[0] == GB.shape[0] == spec.N
    # encode-at-worker agrees with the master-side encode, share by share
    for i in (0, spec.N - 1):
        np.testing.assert_array_equal(
            np.asarray(scheme.encode_a_at(A, i, key=KEY)), np.asarray(FA[i])
        )
        np.testing.assert_array_equal(
            np.asarray(scheme.encode_b_at(B, i, key=KEY)), np.asarray(GB[i])
        )
    H = scheme.worker_compute(FA, GB)
    for trial in range(3):
        idx = jnp.asarray(
            np.sort(rng.choice(spec.N, size=scheme.R, replace=False)), jnp.int32
        )
        C = scheme.decode(jnp.take(H, idx, axis=0), idx)
        np.testing.assert_array_equal(np.asarray(C), expect, err_msg=f"{name} {idx}")


@pytest.mark.parametrize("name,spec,uvw,n", CONFORMANCE_CASES,
                         ids=[c[0] for c in CONFORMANCE_CASES])
def test_scheme_costs_spec_signature(name, spec, uvw, n):
    u, v, w = uvw
    scheme = get_scheme(name).build(spec, u, v, w, n)
    c = scheme.costs(spec)
    assert c.N == spec.N and c.R == scheme.R
    assert c.upload > 0 and c.download > 0


def test_csa_costs_legacy_shim_warns():
    ring16 = make_ring(2, 16, (4,))
    csa = CSACode(ring16, L=2, N=8)
    spec = ProblemSpec(8, 8, 8, n=2, ring=make_ring(2, 16, ()), N=8)
    fresh = csa.costs(spec)
    with pytest.warns(DeprecationWarning):
        legacy = csa.costs(8, 8, 8, make_ring(2, 16, ()))
    assert legacy == fresh


# --------------------------------------------------------------- planner


def test_plan_batched_picks_batch_rmfe_over_gcsa():
    """Table 1: Batch-EP_RMFE wins threshold AND download at every batch n."""
    for n in (2, 4):
        spec = ProblemSpec(64, 64, 64, n=n, ring=Z32, N=16)
        for objective in ("download", "threshold"):
            p = plan(spec, objective=objective)
            assert p.best.scheme == "batch_ep_rmfe", p.summary()
        p = plan(spec, objective="download")
        g = p.by_scheme("gcsa")
        b = p.best
        assert g is not None
        # GCSA's R = 2n-1 vs 1: download worse by ~the batch factor (the
        # concat-RMFE extension dilutes the exact 2n-1 ratio for larger n)
        assert g.costs.download / b.costs.download >= 0.7 * n
        assert g.costs.R >= 2 * n - 1 > b.costs.R


def test_plan_ranks_executable_gcsa_general_vs_batch_rmfe():
    """The executable gcsa_general participates in every batched plan, and
    at a matched (N, ring, partition) its threshold trails batch_ep_rmfe
    by at least the paper's 1/n factor (R_GCSA ~ n * R_RMFE)."""
    for n in (2, 4):
        spec = ProblemSpec(64, 64, 64, n=n, ring=Z32, N=64)
        p = plan(spec, objective="threshold")
        g = p.by_scheme("gcsa_general")
        b = p.by_scheme("batch_ep_rmfe")
        assert g is not None and b is not None
        # best gcsa_general threshold config is u=v=w=1 with kappa=1
        # (R = n + kappa - 1 minimized at kappa=1); RMFE reaches R = 1 at
        # (1,1,1) — the gap is exactly the paper's factor n
        assert (g.u, g.v, g.w, g.n) == (1, 1, 1, 1)
        assert g.costs.R == n
        assert g.costs.R >= n * b.costs.R
        # matched non-trivial partition: compare at (2, 2, 1) via predict
        gf, bf = get_scheme("gcsa_general"), get_scheme("batch_ep_rmfe")
        gc = gf.predict(spec, 2, 2, 1, n)  # kappa = n
        bc = bf.predict(spec, 2, 2, 1, n)
        assert gc.R == 4 * (2 * n - 1) and bc.R == 4
        assert gc.R >= n * bc.R  # the 1/n headline, partitioned
        # executable: the planned configuration builds and carries its
        # analytic R for real
        sch = gf.build(spec, g.u, g.v, g.w, g.n)
        assert sch.R == g.costs.R


def test_plan_sweeps_gcsa_general_group_sizes():
    """The family's packing hook exposes every kappa | n to the planner."""
    spec = ProblemSpec(16, 16, 16, n=4, ring=Z32, N=32)
    p = plan(spec, objective="threshold", schemes=["gcsa_general"])
    kappas = {c.n for c in p.candidates if (c.u, c.v, c.w) == (1, 1, 1)}
    assert kappas == {1, 2, 4}  # R = n + kappa - 1 all feasible at N = 32


def test_plan_respects_straggler_budget():
    spec = ProblemSpec(16, 16, 16, n=1, ring=Z32, N=8, straggler_budget=4)
    p = plan(spec, objective="latency")
    assert all(c.costs.R <= 8 - 4 for c in p.candidates)


def test_plan_rejects_R_greater_than_N():
    # every configuration needs R >= 1 > N - budget = 0
    spec = ProblemSpec(16, 16, 16, n=1, ring=Z32, N=4, straggler_budget=3)
    with pytest.raises(ValueError, match="no feasible scheme"):
        plan(ProblemSpec(9, 9, 9, n=3, ring=Z32, N=4, straggler_budget=3))
    plan(spec)  # budget 3 of 4 still admits R=1 single schemes


def test_plan_validates_spec():
    with pytest.raises(ValueError, match="ring"):
        plan(ProblemSpec(8, 8, 8))
    with pytest.raises(ValueError, match="straggler_budget"):
        plan(ProblemSpec(8, 8, 8, ring=Z32, N=4, straggler_budget=4))
    with pytest.raises(ValueError, match="objective"):
        plan(ProblemSpec(8, 8, 8, ring=Z32), objective="vibes")


def test_plan_instantiate_is_memoized_and_executable():
    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8)
    p = plan(spec, objective="download")
    s1, s2 = p.instantiate(), p.instantiate()
    assert s1 is s2
    rng = np.random.default_rng(3)
    As = Z32.random(rng, (s1.batch, 16, 16))
    Bs = Z32.random(rng, (s1.batch, 16, 16))
    Cs = coded_matmul(As, Bs, p)
    for i in range(s1.batch):
        np.testing.assert_array_equal(
            np.asarray(Cs[i]), np.asarray(Z32.matmul(As[i], Bs[i]))
        )


# --------------------------------------------------------------- backends


@needs8
def test_backends_bit_identical_under_stragglers():
    """LocalSimBackend and ShardMapBackend produce identical bits under a
    simulated straggler mask — and both equal the direct product."""
    spec = ProblemSpec(16, 16, 16, n=2, ring=Z32, N=8, straggler_budget=3)
    p = plan(spec, objective="download")
    scheme = p.instantiate()
    rng = np.random.default_rng(5)
    As = Z32.random(rng, (scheme.batch, 16, 16))
    Bs = Z32.random(rng, (scheme.batch, 16, 16))
    mask = np.ones(8, dtype=bool)
    mask[[1, 4, 6]] = False
    mask = jnp.asarray(mask)

    C_local = coded_matmul(As, Bs, scheme, backend="local", mask=mask)
    C_spmd = coded_matmul(As, Bs, scheme, backend=ShardMapBackend(), mask=mask)
    np.testing.assert_array_equal(np.asarray(C_local), np.asarray(C_spmd))
    for i in range(scheme.batch):
        np.testing.assert_array_equal(
            np.asarray(C_local[i]), np.asarray(Z32.matmul(As[i], Bs[i]))
        )


@needs8
def test_backends_bit_identical_single_scheme():
    spec = ProblemSpec(16, 16, 16, n=1, ring=Z32, N=8, straggler_budget=2)
    scheme = plan(spec, objective="latency").instantiate()
    rng = np.random.default_rng(9)
    A = Z32.random(rng, (16, 16))
    B = Z32.random(rng, (16, 16))
    mask = np.ones(8, dtype=bool)
    mask[[0, 5]] = False
    mask = jnp.asarray(mask)
    C_local = coded_matmul(A, B, scheme, backend=LocalSimBackend(), mask=mask)
    C_spmd = coded_matmul(A, B, scheme, backend="shard_map", mask=mask)
    np.testing.assert_array_equal(np.asarray(C_local), np.asarray(C_spmd))
    np.testing.assert_array_equal(
        np.asarray(C_local), np.asarray(Z32.matmul(A, B))
    )


def test_unknown_backend_and_scheme_raise():
    with pytest.raises(ValueError, match="unknown backend"):
        coded_matmul(None, None, None, backend="quantum")
    with pytest.raises(KeyError, match="unknown scheme"):
        get_scheme("nope")
